"""Msgpack checkpointing for param/optimizer pytrees (offline container:
no orbax). Arrays serialize as (dtype, shape, raw bytes); bfloat16 round-
trips via a uint16 view."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _pack_leaf(x):
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {"dt": "bfloat16", "sh": list(arr.shape),
                "b": arr.view(np.uint16).tobytes()}
    return {"dt": arr.dtype.str, "sh": list(arr.shape), "b": arr.tobytes()}


def _unpack_leaf(d):
    if d["dt"] == "bfloat16":
        arr = np.frombuffer(d["b"], dtype=np.uint16).reshape(d["sh"])
        return jnp.asarray(arr.view(jnp.bfloat16))
    return jnp.asarray(np.frombuffer(d["b"], dtype=np.dtype(d["dt"]))
                       .reshape(d["sh"]))


def save_checkpoint(path: str, tree, step: int = 0, extra: dict = None):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    payload = {
        "step": step,
        "extra": extra or {},
        "leaves": [[jax.tree_util.keystr(k), _pack_leaf(v)]
                   for k, v in flat],
    }
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str, like_tree):
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    by_key = {k: _unpack_leaf(v) for k, v in payload["leaves"]}
    leaves = []
    for k, old in flat:
        ks = jax.tree_util.keystr(k)
        if ks not in by_key:
            raise KeyError(f"checkpoint missing {ks}")
        new = by_key[ks]
        if new.shape != old.shape:
            raise ValueError(f"{ks}: shape {new.shape} != {old.shape}")
        leaves.append(new)
    return treedef.unflatten(leaves), payload["step"], payload["extra"]
