"""Training loop: jitted train_step (loss + grads + AdamW update), metrics
logging, periodic checkpointing."""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .checkpoint import save_checkpoint
from .optimizer import AdamWConfig, apply_updates, init_opt_state


def make_train_step(model, opt_cfg: AdamWConfig):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics
    return train_step


@dataclass
class TrainResult:
    losses: list = field(default_factory=list)
    metrics: list = field(default_factory=list)
    steps_per_sec: float = 0.0


def train(model, params, data_iter, steps: int,
          opt_cfg: AdamWConfig | None = None, log_every: int = 10,
          checkpoint_path: str | None = None, checkpoint_every: int = 0,
          verbose: bool = True) -> tuple:
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    result = TrainResult()
    t0 = time.time()
    for i in range(steps):
        # jnp.asarray may zero-copy alias host memory on CPU (the hazard
        # class fixed in serving/loop.py): safe here ONLY because every
        # pipeline's __next__ returns freshly allocated arrays, never a
        # reused staging buffer. The fresh-batch annotation is the
        # machine-readable form of that contract — RL001 waives the
        # opaque-producer check on its strength, and
        # tests/test_aliasing_guard.py holds the pipelines to it.
        # reprolint: fresh-batch tests/test_aliasing_guard.py pipeline-freshness tests enforce the contract
        batch = next(data_iter)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            result.losses.append(m["loss"])
            result.metrics.append(m)
            if verbose:
                print(f"step {i:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                      f"lr {m['lr']:.2e} gnorm {m['gnorm']:.2f}")
        if checkpoint_path and checkpoint_every and \
                (i + 1) % checkpoint_every == 0:
            save_checkpoint(checkpoint_path, params, step=i + 1)
    result.steps_per_sec = steps / max(time.time() - t0, 1e-9)
    if checkpoint_path:
        save_checkpoint(checkpoint_path, params, step=steps)
    return params, result
