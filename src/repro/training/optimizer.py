"""AdamW + cosine schedule + global-norm clipping (pure pytree impl)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state,
                  update_shardings=None, param_shardings=None):
    """AdamW step. With `update_shardings` (ZeRO-1): the f32 update math
    is constrained to the moments' data-sharded layout — gradients get
    reduce-scattered in, updated params all-gathered back out — so no
    param-sized f32 temp ever materializes per device."""
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu, us, ps):
        wsc = jax.lax.with_sharding_constraint
        g = g.astype(jnp.float32) * scale
        if us is not None:
            g = wsc(g, us)
        mu = cfg.beta1 * mu + (1 - cfg.beta1) * g
        nu = cfg.beta2 * nu + (1 - cfg.beta2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if us is not None:
            p32 = wsc(p32, us)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p32
        new_p = (p32 - lr * delta).astype(p.dtype)
        if ps is not None:
            new_p = wsc(new_p, ps)
        return new_p, mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_us = (treedef.flatten_up_to(update_shardings)
               if update_shardings is not None else [None] * len(flat_p))
    flat_ps = (treedef.flatten_up_to(param_shardings)
               if param_shardings is not None else [None] * len(flat_p))
    out = [upd(p, g, m, n, us, ps) for p, g, m, n, us, ps in
           zip(flat_p, flat_g, flat_mu, flat_nu, flat_us, flat_ps)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"gnorm": gnorm, "lr": lr}
