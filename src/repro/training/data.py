"""Synthetic data pipeline.

`GrammarDataPipeline` packs grammar-sampled valid strings (EOS-separated)
into fixed-length training batches — a data pipeline that is actually
*about* the paper: the LM learns the formal language whose grammar later
constrains decoding. `RandomTokenPipeline` supplies shape-correct random
batches for substrate benchmarks.

Aliasing contract: every `__next__` returns FRESHLY ALLOCATED arrays
(never a reused staging buffer). The training loop ships batches with
`jnp.asarray`, which may zero-copy alias host memory on CPU — a reused
buffer would be mutated under an in-flight async computation
(tests/test_aliasing_guard.py enforces this).
"""
from __future__ import annotations

import numpy as np

from repro.core.sampling import GrammarSampler
from repro.core.tokenizer import ByteTokenizer, EOS_ID


class GrammarDataPipeline:
    def __init__(self, grammar, tokenizer: ByteTokenizer, seq_len: int,
                 batch_size: int, seed: int = 0, budget: int = 18,
                 max_bytes: int = 400):
        self.sampler = GrammarSampler(grammar, seed=seed)
        self.tok = tokenizer
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.budget = budget
        self.max_bytes = max_bytes
        self._buf: list[int] = []

    def _fill(self, need: int):
        while len(self._buf) < need:
            s = self.sampler.sample(self.budget, max_bytes=self.max_bytes)
            self._buf.extend(self.tok.encode(s, add_eos=True))

    def __iter__(self):
        return self

    def __next__(self):
        S, B = self.seq_len, self.batch_size
        need = B * (S + 1)
        self._fill(need)
        flat = np.asarray(self._buf[:need], dtype=np.int32)
        self._buf = self._buf[need:]
        chunk = flat.reshape(B, S + 1)
        return {
            "tokens": chunk[:, :-1],
            "labels": chunk[:, 1:],
            "loss_mask": np.ones((B, S), np.float32),
        }


class RandomTokenPipeline:
    def __init__(self, cfg, seq_len: int, batch_size: int, seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)

    def __iter__(self):
        return self

    def __next__(self):
        cfg, S, B = self.cfg, self.seq_len, self.batch_size
        batch = {
            "tokens": self.rng.integers(0, cfg.vocab_size, (B, S),
                                        dtype=np.int32),
            "labels": self.rng.integers(0, cfg.vocab_size, (B, S),
                                        dtype=np.int32),
            "loss_mask": np.ones((B, S), np.float32),
        }
        if cfg.arch_type == "vlm":
            batch["image_embeds"] = self.rng.normal(
                size=(B, cfg.num_image_tokens, cfg.d_model)).astype("float32")
        if cfg.arch_type == "audio":
            batch["frames"] = self.rng.normal(
                size=(B, cfg.audio_frames, cfg.d_model)).astype("float32")
        return batch
