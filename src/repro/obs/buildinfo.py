"""Build identity: the probe that lets a scraped metric be correlated
with a bench artifact.

`build_info()` returns {git_sha, git_dirty, jax_version, device_kind,
device_count, python, platform, hostname} — the same run_meta fields
benchmarks/common.py stamps into every `BENCH_<sha>.json` row, so a
/stats snapshot and a bench artifact taken on the same checkout agree
byte-for-byte on identity. Served on `GET /healthz` and `GET /stats`.

Purity: repro.obs must never import jax or numpy (tests/test_obs.py
scans every file and the transitive import set). The jax fields are
therefore read from `sys.modules` — if the serving process already
imported jax (it always has by the time a server answers /healthz), we
report its version and device kind; in a process that never touched
jax, the fields read "absent" instead of dragging the device runtime
into an otherwise pure-obs import. The device probe is wrapped in a
broad except: identity reporting must never take down a health check.
"""
from __future__ import annotations

import functools
import os
import platform
import socket
import subprocess
import sys


def git_revision(cwd: str | None = None) -> tuple[str, bool]:
    """(short sha, dirty?) of the repo containing *cwd* — ("unknown",
    False) when git or the work tree is unavailable."""
    cwd = cwd or os.path.dirname(os.path.abspath(__file__))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip())
        return sha, dirty
    except Exception:
        return "unknown", False


def _jax_fields() -> dict:
    """jax version + device identity from sys.modules — never imports."""
    mod = sys.modules.get("jax")
    if mod is None:
        return {"jax_version": "absent", "device_kind": "absent",
                "device_count": 0}
    out = {"jax_version": getattr(mod, "__version__", "unknown"),
           "device_kind": "unknown", "device_count": 0}
    try:
        devs = mod.devices()
        out["device_kind"] = getattr(devs[0], "device_kind",
                                     devs[0].platform)
        out["device_count"] = len(devs)
    except Exception:
        pass
    return out


@functools.lru_cache(maxsize=1)
def _static_fields() -> dict:
    sha, dirty = git_revision()
    return {
        "git_sha": sha,
        "git_dirty": dirty,
        "python": platform.python_version(),
        "platform": f"{platform.system()}-{platform.machine()}",
        "hostname": socket.gethostname(),
    }


def build_info() -> dict:
    """Full identity dict; git/platform fields cached, jax fields live
    (device kind can change between import and first device use)."""
    out = dict(_static_fields())
    out.update(_jax_fields())
    return out


def run_meta_str(extra: dict | None = None) -> str:
    """Legacy ';'-joined `k=v` form used in bench CSV rows."""
    info = build_info()
    if extra:
        info = {**info, **extra}
    return ";".join(f"{k}={info[k]}" for k in sorted(info))
