"""Bounded span ring buffer + Perfetto/Chrome-trace JSON export.

The step loop records phase spans (`Tracer.add`) and per-token instants
(`Tracer.instant`) only while tracing is active (`start()`/`stop()`,
driven by POST /trace on the HTTP server). Spans land in a bounded
`deque` — steady-state tracing can run forever and the dump is always
the most recent `capacity` events, never an unbounded buffer.

`export_chrome()` emits the Chrome trace-event JSON flavour that
ui.perfetto.dev (and chrome://tracing) loads directly: complete events
(`"ph": "X"`) with microsecond timestamps, one *thread track per
span-track name* — loop phases each get their own track, every slot gets
a `slot N` track carrying its requests' lifetime spans and token
instants — named via `thread_name` metadata events.

Timestamps are raw `time.perf_counter()` values captured by the spans
themselves; the exporter rebases them to the earliest event so the trace
starts at t=0. Pure stdlib (no jax/numpy) — recording can never touch
the device.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

DEFAULT_CAPACITY = 65536

# stable tid ordering: known tracks first, in pipeline order; device
# tracks (devtime brackets + merged jax.profiler kernel threads) group
# after the host phases; anything else (slot tracks, custom tracks)
# sorts after them by name
_TRACK_ORDER = ("step", "admit", "plan", "feed_build", "ci_lookup",
                "cd_check", "mask_dispatch", "forward",
                "overlap_forward",
                "select_resolve", "host_oracle", "opportunistic",
                "device:forward", "device:overlap_forward",
                "device:mask_sample")


class Tracer:
    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self.active = False
        self.dropped = 0        # events pushed out of the ring (approx)
        self._seen = 0

    # ------------------------------ control ---------------------------

    def start(self) -> None:
        self.active = True

    def stop(self) -> None:
        self.active = False

    def clear(self) -> None:
        self._ring.clear()
        self.dropped = 0
        self._seen = 0

    def __len__(self) -> int:
        return len(self._ring)

    # ----------------------------- recording --------------------------
    # Callers are expected to gate on `self.active` before building args
    # dicts; add()/instant() re-check so a stop() between the check and
    # the call just drops the event.

    def add(self, track: str, name: str, t0: float, dur: float,
            args: Optional[dict] = None) -> None:
        if not self.active:
            return
        self._seen += 1
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(("X", track, name, t0, dur, args))

    def instant(self, track: str, name: str, t: float,
                args: Optional[dict] = None) -> None:
        if not self.active:
            return
        self._seen += 1
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(("i", track, name, t, 0.0, args))

    # ------------------------------ export ----------------------------

    def export_chrome(self, extra_events: Optional[list] = None) -> dict:
        """Chrome trace-event JSON: {"traceEvents": [...]} with one
        process ("repro engine") and one named thread per track.

        *extra_events* merges externally captured intervals — the
        jax.profiler device-thread slices collected by
        ProfilerSession.collect_chrome_events() — into the same
        timeline. Each is {"track", "name", "ts_us", "dur_us"} with
        ts_us already on the host perf_counter clock (µs), so both
        sources rebase against one shared origin and the host spans
        line up with the kernel executions they dispatched.
        """
        events = list(self._ring)       # snapshot; recording continues
        extra = list(extra_events or [])
        tracks = sorted({e[1] for e in events} |
                        {e["track"] for e in extra},
                        key=lambda t: (_TRACK_ORDER.index(t)
                                       if t in _TRACK_ORDER
                                       else len(_TRACK_ORDER), t))
        tid = {t: i + 1 for i, t in enumerate(tracks)}
        t_base_s = min((e[3] for e in events), default=None)
        t_base_us = min((e["ts_us"] for e in extra),
                        default=None)
        if t_base_s is not None:
            t_base_us = (t_base_s * 1e6 if t_base_us is None
                         else min(t_base_us, t_base_s * 1e6))
        elif t_base_us is None:
            t_base_us = 0.0
        out = [{"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
                "args": {"name": "repro engine"}}]
        for t in tracks:
            out.append({"ph": "M", "pid": 1, "tid": tid[t],
                        "name": "thread_name", "args": {"name": t}})
        for ph, track, name, t0, dur, args in events:
            ev = {"ph": ph, "pid": 1, "tid": tid[track], "name": name,
                  "cat": track, "ts": t0 * 1e6 - t_base_us}
            if ph == "X":
                ev["dur"] = dur * 1e6
            else:
                ev["s"] = "t"           # instant scoped to its thread
            if args:
                ev["args"] = args
            out.append(ev)
        for e in extra:
            out.append({"ph": "X", "pid": 1, "tid": tid[e["track"]],
                        "name": e["name"], "cat": e["track"],
                        "ts": e["ts_us"] - t_base_us,
                        "dur": e["dur_us"]})
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "captured_events": self._seen,
                              "merged_device_events": len(extra)}}
