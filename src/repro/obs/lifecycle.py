"""Per-request latency lifecycle: enqueue → admit → first token → finish.

One record per in-flight request, keyed by rid, updated from the step
loop thread (admit/token/finish) and the submitter's thread (enqueue).
A small lock guards the record dict only — the derived histograms live
in the shared `MetricsRegistry` and are scraped without ever touching
the step loop:

  * `repro_request_queue_wait_seconds` — enqueue → admission,
  * `repro_request_ttft_seconds`       — enqueue → first committed token
                                         (production TTFT: queue wait
                                         included; sync runs admit
                                         immediately so both ends align),
  * `repro_request_itl_seconds`        — gap between consecutive
                                         committed tokens (jump-forward
                                         commits count: they are real
                                         emitted tokens),
  * `repro_request_duration_seconds`, `repro_request_tokens`,
  * `repro_finished_requests_total{reason=...}`.

When the owning `Telemetry` is disabled every method is a no-op
(`NullLifecycle`). Pure stdlib — no jax/numpy.
"""
from __future__ import annotations

import threading
import time
from typing import Optional

from .registry import LATENCY_BUCKETS, MetricsRegistry, log_buckets

TOKEN_BUCKETS = log_buckets(1.0, 10000.0, per_decade=3)


class _Life:
    __slots__ = ("enqueue_t", "admit_t", "first_token_t", "last_token_t",
                 "tokens")

    def __init__(self, enqueue_t: float):
        self.enqueue_t = enqueue_t
        self.admit_t: Optional[float] = None
        self.first_token_t: Optional[float] = None
        self.last_token_t: Optional[float] = None
        self.tokens = 0


class LifecycleTracker:
    def __init__(self, registry: MetricsRegistry):
        self.reg = registry
        self._lock = threading.Lock()
        self._inflight: dict[int, _Life] = {}
        self.h_queue = registry.histogram(
            "repro_request_queue_wait_seconds",
            "enqueue -> admission wait", LATENCY_BUCKETS)
        self.h_ttft = registry.histogram(
            "repro_request_ttft_seconds",
            "enqueue -> first committed token", LATENCY_BUCKETS)
        self.h_itl = registry.histogram(
            "repro_request_itl_seconds",
            "gap between consecutive committed tokens", LATENCY_BUCKETS)
        self.h_duration = registry.histogram(
            "repro_request_duration_seconds",
            "enqueue -> finish", LATENCY_BUCKETS)
        self.h_tokens = registry.histogram(
            "repro_request_tokens",
            "committed tokens per finished request", TOKEN_BUCKETS)
        self.c_enqueued = registry.counter(
            "repro_requests_enqueued_total", "requests submitted")
        # the per-reason finished counter children are created lazily in
        # on_finish; pre-register the family so /metrics always has it
        registry.counter("repro_finished_requests_total",
                         "finished requests by reason",
                         {"reason": "eos"})

    # ---- hooks (loop thread, except on_enqueue: submitter thread) ----

    def on_enqueue(self, rid: int) -> None:
        self.c_enqueued.inc()
        with self._lock:
            self._inflight[rid] = _Life(time.perf_counter())

    def on_admit(self, rid: int) -> Optional[_Life]:
        now = time.perf_counter()
        with self._lock:
            rec = self._inflight.get(rid)
            if rec is None:     # sync path: never enqueued — admit IS
                rec = self._inflight[rid] = _Life(now)      # the start
        rec.admit_t = now
        self.h_queue.observe(now - rec.enqueue_t)
        return rec

    def on_token(self, rid: int) -> None:
        now = time.perf_counter()
        with self._lock:
            rec = self._inflight.get(rid)
        if rec is None:
            return
        rec.tokens += 1
        if rec.first_token_t is None:
            rec.first_token_t = now
            self.h_ttft.observe(now - rec.enqueue_t)
        else:
            self.h_itl.observe(now - rec.last_token_t)
        rec.last_token_t = now

    def on_finish(self, rid: int, reason: str) -> Optional[_Life]:
        now = time.perf_counter()
        self.reg.counter("repro_finished_requests_total",
                         "finished requests by reason",
                         {"reason": reason or "unknown"}).inc()
        with self._lock:
            rec = self._inflight.pop(rid, None)
        if rec is None:         # failed before enqueue was recorded
            return None
        self.h_duration.observe(now - rec.enqueue_t)
        self.h_tokens.observe(rec.tokens)
        return rec

    # ------------------------------ views -----------------------------

    def inflight(self) -> int:
        with self._lock:
            return len(self._inflight)

    def summary(self) -> dict:
        """p50/p99 snapshot for /stats and the bench harness."""
        out = {}
        for key, h in (("queue_wait", self.h_queue), ("ttft", self.h_ttft),
                       ("itl", self.h_itl), ("duration", self.h_duration),
                       ("tokens", self.h_tokens)):
            out[key] = {"count": h.count,
                        "mean": h.sum / h.count if h.count else None,
                        "p50": h.quantile(0.5) if h.count else None,
                        "p99": h.quantile(0.99) if h.count else None}
        return out

    def finish_reasons(self) -> dict:
        """Cumulative finished-request counts by reason (for /healthz)."""
        out = {}
        fam = self.reg.snapshot().get("repro_finished_requests_total")
        for s in (fam or {}).get("series", []):
            if s["value"]:
                out[s["labels"].get("reason", "unknown")] = int(s["value"])
        return out


class NullLifecycle:
    """Telemetry-disabled stand-in: every hook is a no-op."""

    def on_enqueue(self, rid: int) -> None:
        pass

    def on_admit(self, rid: int) -> None:
        return None

    def on_token(self, rid: int) -> None:
        pass

    def on_finish(self, rid: int, reason: str) -> None:
        return None

    def inflight(self) -> int:
        return 0

    def summary(self) -> dict:
        return {}

    def finish_reasons(self) -> dict:
        return {}
