"""Low-overhead metrics registry: counters, gauges, log-spaced histograms.

Design constraints (docs/observability.md):

  * **hot-path cost is one float add** — instruments are plain Python
    objects updated by the step-loop thread; no locks on the update
    path (single-writer per instrument; scrape readers tolerate the
    torn-read window the GIL leaves, which for monotone counters means
    an at-most-one-update-stale value),
  * **scrapes never block the step loop** — `render_prometheus()` and
    `snapshot()` only read; the registry lock guards family *creation*
    (rare) and is never held by a step in flight,
  * **pure stdlib** — this package must not import jax or numpy, which
    is what structurally guarantees telemetry can never introduce a
    device synchronization (asserted by tests/test_obs.py).

Histograms use fixed log-spaced bucket boundaries (`log_buckets`): a
latency distribution spanning 10 µs .. 100 s lands in ~30 buckets with
constant relative resolution, and `quantile()` interpolates inside the
bucket the same way PromQL's `histogram_quantile` does.
"""
from __future__ import annotations

import math
import threading
from bisect import bisect_left
from typing import Callable, Optional


def log_buckets(lo: float, hi: float, per_decade: int = 4) -> tuple:
    """Log-spaced histogram upper bounds: lo * 10^(i/per_decade) up to
    the first bound >= hi. Constant relative width (one bucket every
    10^(1/per_decade)x), so a single layout covers µs-scale phase spans
    and second-scale request latencies alike."""
    if lo <= 0 or hi <= lo or per_decade < 1:
        raise ValueError(f"bad bucket spec lo={lo} hi={hi}/{per_decade}")
    out = []
    i = 0
    while True:
        b = lo * 10.0 ** (i / per_decade)
        out.append(b)
        if b >= hi:
            return tuple(out)
        i += 1


# default layouts (upper bounds in seconds)
LATENCY_BUCKETS = log_buckets(1e-4, 100.0, per_decade=4)   # 100µs..100s
PHASE_BUCKETS = log_buckets(1e-6, 10.0, per_decade=4)      # 1µs..10s


class Counter:
    """Monotone counter. `fn` (if set) makes it a *derived* counter read
    from a callback at scrape time instead of accumulating here."""
    __slots__ = ("labels", "_value", "fn")

    def __init__(self, labels: Optional[dict] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.labels = labels or {}
        self._value = 0.0
        self.fn = fn

    def inc(self, n: float = 1.0) -> None:
        self._value += n

    @property
    def value(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value


class Gauge:
    """Point-in-time value: `set()` it, or give it a `fn` callback
    evaluated at scrape time (how pool/queue gauges observe live state
    without the step loop ever pushing updates)."""
    __slots__ = ("labels", "_value", "fn")

    def __init__(self, labels: Optional[dict] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.labels = labels or {}
        self._value = 0.0
        self.fn = fn

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return float(self.fn()) if self.fn is not None else self._value


class Histogram:
    """Fixed-boundary histogram. counts[i] is the number of observations
    <= bounds[i] and > bounds[i-1]; counts[-1] is the +Inf overflow."""
    __slots__ = ("labels", "bounds", "counts", "sum", "count")

    def __init__(self, bounds: tuple, labels: Optional[dict] = None):
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError("histogram bounds must be strictly increasing")
        self.labels = labels or {}
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """PromQL-style histogram_quantile: find the bucket holding the
        q-th observation and interpolate linearly between its edges
        (lower edge 0 for the first bucket; the overflow bucket reports
        its lower edge — the largest bound). Returns nan when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        if self.count == 0:
            return math.nan
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if cum + c >= target and c > 0:
                if i == len(self.bounds):       # overflow bucket
                    return self.bounds[-1]
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i]
                return lo + (hi - lo) * max(target - cum, 0.0) / c
            cum += c
        return self.bounds[-1]


class _Family:
    __slots__ = ("name", "kind", "help", "children")

    def __init__(self, name: str, kind: str, help: str):
        self.name = name
        self.kind = kind
        self.help = help
        self.children: dict[tuple, object] = {}


def _label_key(labels: Optional[dict]) -> tuple:
    return tuple(sorted((labels or {}).items()))


def _esc_label(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"') \
                 .replace("\n", "\\n")


def _fmt_labels(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{_esc_label(v)}"'
                    for k, v in sorted(items.items()))
    return "{" + body + "}"


def _fmt_num(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class MetricsRegistry:
    """Get-or-create instrument families keyed by (name, labelset).

    Re-requesting an existing (name, labels) pair returns the SAME
    instrument, so modules can look up shared counters without plumbing
    handles around. A `fn` passed to an existing callback instrument
    rebinds it (fresh allocator after engine re-setup)."""

    def __init__(self):
        self._families: dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind: str, help: str, labels, factory):
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = self._families[name] = _Family(name, kind, help)
            elif fam.kind != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}")
            child = fam.children.get(key)
            if child is None:
                child = fam.children[key] = factory()
            return child

    def counter(self, name: str, help: str = "",
                labels: Optional[dict] = None,
                fn: Optional[Callable[[], float]] = None) -> Counter:
        c = self._get(name, "counter", help, labels,
                      lambda: Counter(labels, fn))
        if fn is not None:
            c.fn = fn
        return c

    def gauge(self, name: str, help: str = "",
              labels: Optional[dict] = None,
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        g = self._get(name, "gauge", help, labels,
                      lambda: Gauge(labels, fn))
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, help: str = "",
                  buckets: tuple = LATENCY_BUCKETS,
                  labels: Optional[dict] = None) -> Histogram:
        return self._get(name, "histogram", help, labels,
                         lambda: Histogram(buckets, labels))

    # ----------------------------- export -----------------------------

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (0.0.4). Histograms render
        cumulative `_bucket{le=...}` series plus `_sum`/`_count`."""
        out = []
        with self._lock:
            fams = [(f.name, f.kind, f.help, list(f.children.values()))
                    for f in self._families.values()]
        for name, kind, help, children in sorted(fams):
            if help:
                out.append(f"# HELP {name} {help}")
            out.append(f"# TYPE {name} {kind}")
            for ch in children:
                if kind == "histogram":
                    cum = 0
                    counts = list(ch.counts)    # one consistent copy
                    for b, c in zip(ch.bounds, counts):
                        cum += c
                        out.append(
                            f"{name}_bucket"
                            f"{_fmt_labels(ch.labels, {'le': _fmt_num(b)})}"
                            f" {cum}")
                    cum += counts[-1]
                    out.append(f"{name}_bucket"
                               f"{_fmt_labels(ch.labels, {'le': '+Inf'})}"
                               f" {cum}")
                    out.append(f"{name}_sum{_fmt_labels(ch.labels)}"
                               f" {_fmt_num(ch.sum)}")
                    out.append(f"{name}_count{_fmt_labels(ch.labels)}"
                               f" {cum}")
                else:
                    out.append(f"{name}{_fmt_labels(ch.labels)}"
                               f" {_fmt_num(ch.value)}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> dict:
        """Plain-data snapshot for the JSON /stats endpoint."""
        out: dict = {}
        with self._lock:
            fams = [(f.name, f.kind, list(f.children.values()))
                    for f in self._families.values()]
        for name, kind, children in fams:
            series = []
            for ch in children:
                if kind == "histogram":
                    series.append({
                        "labels": dict(ch.labels),
                        "count": ch.count, "sum": ch.sum,
                        "p50": ch.quantile(0.5), "p99": ch.quantile(0.99),
                    })
                else:
                    series.append({"labels": dict(ch.labels),
                                   "value": ch.value})
            out[name] = {"type": kind, "series": series}
        return out
