"""Serving telemetry: metrics registry, phase spans, request lifecycle,
Perfetto trace export. Pure stdlib — importing repro.obs must never pull
in jax or numpy (tests/test_obs.py asserts this), which is the
structural guarantee that telemetry cannot add device synchronization.
"""
from .registry import (Counter, Gauge, Histogram, LATENCY_BUCKETS,
                       MetricsRegistry, PHASE_BUCKETS, log_buckets)
from .lifecycle import LifecycleTracker, NullLifecycle
from .trace import Tracer, DEFAULT_CAPACITY
from .buildinfo import build_info, git_revision, run_meta_str
from .devtime import (DEVICE_TRACK_PREFIX, DeviceTimer, NULL_DEV_SPAN,
                      ProfilerSession)
from .telemetry import (ATTR_FORWARD_PHASES, ATTR_HOST_GRAMMAR_PHASES,
                        ATTR_MASK_PHASES, DISABLED_SPAN_BUDGET_S,
                        ENABLED_SPAN_BUDGET_S, NULL_SPAN, Telemetry)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BUCKETS", "PHASE_BUCKETS", "log_buckets",
    "LifecycleTracker", "NullLifecycle",
    "Tracer", "DEFAULT_CAPACITY",
    "build_info", "git_revision", "run_meta_str",
    "DeviceTimer", "ProfilerSession", "NULL_DEV_SPAN",
    "DEVICE_TRACK_PREFIX",
    "Telemetry", "NULL_SPAN",
    "ATTR_HOST_GRAMMAR_PHASES", "ATTR_MASK_PHASES",
    "ATTR_FORWARD_PHASES",
    "DISABLED_SPAN_BUDGET_S", "ENABLED_SPAN_BUDGET_S",
]
