"""Serving telemetry: metrics registry, phase spans, request lifecycle,
Perfetto trace export. Pure stdlib — importing repro.obs must never pull
in jax or numpy (tests/test_obs.py asserts this), which is the
structural guarantee that telemetry cannot add device synchronization.
"""
from .registry import (Counter, Gauge, Histogram, LATENCY_BUCKETS,
                       MetricsRegistry, PHASE_BUCKETS, log_buckets)
from .lifecycle import LifecycleTracker, NullLifecycle
from .trace import Tracer, DEFAULT_CAPACITY
from .telemetry import (DISABLED_SPAN_BUDGET_S, ENABLED_SPAN_BUDGET_S,
                        NULL_SPAN, Telemetry)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BUCKETS", "PHASE_BUCKETS", "log_buckets",
    "LifecycleTracker", "NullLifecycle",
    "Tracer", "DEFAULT_CAPACITY",
    "Telemetry", "NULL_SPAN",
    "DISABLED_SPAN_BUDGET_S", "ENABLED_SPAN_BUDGET_S",
]
