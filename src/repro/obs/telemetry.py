"""Telemetry facade: one object the serving stack threads everywhere.

`Telemetry` bundles the metrics registry, the trace ring buffer, and the
request-lifecycle tracker behind a single `span()` API:

    with tele.span("mask_dispatch") as sp:
        ... host-side work already bracketed by perf_counter ...
    st.mask_time += sp.dur

Each span, on exit, adds its duration to the per-phase counter pair
(`repro_step_phase_seconds_total{phase=...}` +
`repro_step_phase_calls_total{phase=...}`), observes the per-phase
histogram, and — only while a trace capture is active — records a
Chrome-trace complete event. The span's measured duration (`sp.dur`) is
what callers feed into the legacy per-slot accounting, so EngineStats
and the registry are two views of the SAME perf_counter bracket and can
never drift apart.

Disabled fast path: `Telemetry(enabled=False).span(...)` returns one
shared `_NullSpan` whose __enter__/__exit__ do nothing and whose `dur`
is 0.0 — no perf_counter call, no dict lookups, no allocation. The
overhead guard in tests/test_obs.py pins this below
`DISABLED_SPAN_BUDGET_S`. Count-style instruments (tokens, mask
computations, overlap outcomes) stay live even when disabled — they are
plain float adds and EngineStats' exact count invariants depend on
them.

Pure stdlib — no jax/numpy anywhere in repro.obs.
"""
from __future__ import annotations

import time
from typing import Optional

from .buildinfo import build_info
from .devtime import NULL_DEV_SPAN, DeviceTimer, ProfilerSession
from .lifecycle import LifecycleTracker, NullLifecycle
from .registry import PHASE_BUCKETS, MetricsRegistry
from .trace import Tracer

# Step-attribution components (XGrammar-style breakdown): how each
# decode step's wall time splits between host grammar work, the two
# kernel families, and device time hidden under host work by the
# overlap engine. Host-phase spans supply the grammar term; devtime
# brackets supply the kernel terms when device timing is on (bench /
# profile mode), falling back to dispatch-span lower bounds in serving.
ATTR_HOST_GRAMMAR_PHASES = ("ci_lookup", "cd_check", "host_oracle",
                            "plan", "feed_build")
# context-split sub-components of host_grammar: the precomputed-row
# lookup vs the context-dependent residue check (docs/observability.md)
ATTR_HOST_GRAMMAR_CI_PHASES = ("ci_lookup",)
ATTR_HOST_GRAMMAR_CD_PHASES = ("cd_check",)
ATTR_MASK_PHASES = ("mask_dispatch", "select_resolve")
ATTR_FORWARD_PHASES = ("forward", "overlap_forward")

# Named overhead budgets (seconds), asserted by tests/test_obs.py.
# DISABLED_SPAN_BUDGET_S: per span() call with telemetry off — must be
# cheap enough to leave in every hot path unconditionally.
# ENABLED_SPAN_BUDGET_S: per span with telemetry on but tracing off —
# two perf_counter calls + a few float adds.
DISABLED_SPAN_BUDGET_S = 2e-6
ENABLED_SPAN_BUDGET_S = 25e-6


class _NullSpan:
    """Shared no-op span: telemetry disabled. dur is always 0.0."""
    __slots__ = ()
    dur = 0.0
    t0 = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tele", "phase", "track", "args", "t0", "dur")

    def __init__(self, tele: "Telemetry", phase: str,
                 track: Optional[str], args: Optional[dict]):
        self.tele = tele
        self.phase = phase
        self.track = track
        self.args = args
        self.t0 = 0.0
        self.dur = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.dur = dur = time.perf_counter() - self.t0
        tele = self.tele
        sec, calls, hist = tele._phase(self.phase)
        sec.inc(dur)
        calls.inc()
        hist.observe(dur)
        if tele.tracer.active:
            tele.tracer.add(self.track or self.phase, self.phase,
                            self.t0, dur, self.args)
        return False


class Telemetry:
    """enabled=True: full spans/histograms/lifecycle/trace.
    enabled=False: span() is a shared no-op and lifecycle hooks vanish;
    the registry still exists so count-style instruments keep working."""

    def __init__(self, enabled: bool = True,
                 trace_capacity: Optional[int] = None):
        self.enabled = bool(enabled)
        self.registry = MetricsRegistry()
        self.tracer = Tracer(**({} if trace_capacity is None
                                else {"capacity": trace_capacity}))
        self.lifecycle = (LifecycleTracker(self.registry)
                          if self.enabled else NullLifecycle())
        self.t_start = time.perf_counter()
        self._phases: dict = {}
        self.devtime = DeviceTimer(self.registry, self.tracer)
        self.profiler = ProfilerSession(self.devtime, self.tracer)
        if self.enabled:
            self.registry.gauge(
                "repro_uptime_seconds", "seconds since telemetry start",
                fn=lambda: time.perf_counter() - self.t_start)
            self._wire_attribution()
        else:
            # real counter either way so loop.py can add to it blindly
            self.c_overlap_hidden = self.registry.counter(
                "repro_step_attribution_seconds_total",
                "step wall-time attribution by component",
                {"component": "overlap_hidden"})

    def _wire_attribution(self) -> None:
        """Scrape-time attribution counters: derived components read the
        phase/devtime sums live so they can never drift from the spans
        they summarize; overlap_hidden is a real counter fed by the step
        loop (only it knows the dispatch-to-consumption window)."""
        c = self.registry.counter
        help = "step wall-time attribution by component"

        def phase_sum(phases):
            return lambda: sum(self.phase_seconds(p) for p in phases)

        c("repro_step_attribution_seconds_total", help,
          {"component": "host_grammar"},
          fn=phase_sum(ATTR_HOST_GRAMMAR_PHASES))
        c("repro_step_attribution_seconds_total", help,
          {"component": "host_grammar_ci"},
          fn=phase_sum(ATTR_HOST_GRAMMAR_CI_PHASES))
        c("repro_step_attribution_seconds_total", help,
          {"component": "host_grammar_cd"},
          fn=phase_sum(ATTR_HOST_GRAMMAR_CD_PHASES))
        c("repro_step_attribution_seconds_total", help,
          {"component": "mask_sample_kernel"},
          fn=lambda: self._kernel_seconds(("mask_sample",),
                                          ATTR_MASK_PHASES))
        c("repro_step_attribution_seconds_total", help,
          {"component": "forward_kernel"},
          fn=lambda: self._kernel_seconds(ATTR_FORWARD_PHASES,
                                          ATTR_FORWARD_PHASES))
        self.c_overlap_hidden = c(
            "repro_step_attribution_seconds_total", help,
            {"component": "overlap_hidden"})

    def _kernel_seconds(self, dev_fns, host_phases) -> float:
        """Kernel component: synced device intervals when devtime has
        measured this family, else the host dispatch spans (a lower
        bound in serving mode — documented in docs/observability.md)."""
        dev = sum(self.devtime.seconds(f) for f in dev_fns)
        if dev > 0.0:
            return dev
        return sum(self.phase_seconds(p) for p in host_phases)

    # ------------------------------ spans ------------------------------

    def _phase(self, phase: str):
        tup = self._phases.get(phase)
        if tup is None:
            reg = self.registry
            tup = self._phases[phase] = (
                reg.counter("repro_step_phase_seconds_total",
                            "cumulative host seconds per step phase",
                            {"phase": phase}),
                reg.counter("repro_step_phase_calls_total",
                            "span count per step phase", {"phase": phase}),
                reg.histogram("repro_step_phase_duration_seconds",
                              "per-span duration by phase",
                              PHASE_BUCKETS, {"phase": phase}),
            )
        return tup

    def span(self, phase: str, track: Optional[str] = None,
             args: Optional[dict] = None):
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, phase, track, args)

    def device_span(self, fn: str):
        """Device-interval bracket around a jitted call. No-op unless
        device timing is on AND a sync capability was injected
        (serving/devbridge.py) — serving mode never syncs."""
        if not self.enabled:
            return NULL_DEV_SPAN
        return self.devtime.span(fn)

    def add_overlap_hidden(self, seconds: float) -> None:
        """Credit device time hidden under host work by the overlap
        engine (called by the step loop on overlap-hit consumption)."""
        if seconds > 0.0:
            self.c_overlap_hidden.inc(seconds)

    def phase_seconds(self, phase: str) -> float:
        """Cumulative seconds recorded for a phase (0.0 if never hit)."""
        if not self.enabled or phase not in self._phases:
            return 0.0
        return self._phases[phase][0].value

    def phase_calls(self, phase: str) -> int:
        if not self.enabled or phase not in self._phases:
            return 0
        return int(self._phases[phase][1].value)

    # -------------------------- count helpers --------------------------
    # Always-on (cheap float adds): exact token/count stats must hold
    # with telemetry disabled, so these never go through the null path.

    def counter(self, name: str, help: str = "",
                labels: Optional[dict] = None):
        return self.registry.counter(name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[dict] = None, fn=None):
        return self.registry.gauge(name, help, labels, fn=fn)

    # --------------------------- integrations --------------------------

    def register_kv(self, alloc) -> None:
        """Callback gauges over a PagedAllocator — evaluated at scrape
        time, never pushed from the step loop."""
        reg = self.registry
        g = reg.gauge

        def metric(key):
            return lambda: float(alloc.metrics()[key])

        g("repro_kv_pages_total", "KV pool size in pages",
          fn=metric("pages_total"))
        g("repro_kv_pages_in_use", "KV pages currently referenced",
          fn=metric("pages_in_use"))
        g("repro_kv_pages_free", "KV pages on the free list",
          fn=metric("pages_free"))
        g("repro_kv_pages_cold", "evictable cached pages",
          fn=metric("pages_cold"))
        g("repro_kv_pages_peak", "high-water mark of pages in use",
          fn=metric("peak_in_use"))
        g("repro_kv_prefix_hit_rate", "prefix-cache token hit rate",
          fn=metric("prefix_hit_rate"))
        reg.counter("repro_kv_page_allocs_total", "pages ever allocated",
                    fn=metric("page_allocs"))
        reg.counter("repro_kv_evictions_total", "cold pages evicted",
                    fn=metric("evictions"))
        reg.counter("repro_kv_cow_copies_total", "copy-on-write page copies",
                    fn=metric("cow_copies"))

    # ------------------------------ views ------------------------------

    def uptime(self) -> float:
        return time.perf_counter() - self.t_start

    def attribution(self) -> dict:
        """Per-step wall-time split {host_grammar, mask_sample_kernel,
        forward_kernel, overlap_hidden} + fractions and the measurement
        source for each kernel term ("device" = synced devtime bracket,
        "host-dispatch" = span lower bound, serving mode)."""
        if not self.enabled:
            return {"enabled": False}
        host = sum(self.phase_seconds(p)
                   for p in ATTR_HOST_GRAMMAR_PHASES)
        host_ci = sum(self.phase_seconds(p)
                      for p in ATTR_HOST_GRAMMAR_CI_PHASES)
        host_cd = sum(self.phase_seconds(p)
                      for p in ATTR_HOST_GRAMMAR_CD_PHASES)
        mask = self._kernel_seconds(("mask_sample",), ATTR_MASK_PHASES)
        fwd = self._kernel_seconds(ATTR_FORWARD_PHASES,
                                   ATTR_FORWARD_PHASES)
        hidden = self.c_overlap_hidden.value
        total = host + mask + fwd
        # host_grammar_ci/_cd are SUB-components of host_grammar (they
        # overlap it, not the total): the context-split breakdown of
        # the per-step grammar work
        comp = {"host_grammar": host, "host_grammar_ci": host_ci,
                "host_grammar_cd": host_cd, "mask_sample_kernel": mask,
                "forward_kernel": fwd, "overlap_hidden": hidden}
        dev_mask = self.devtime.seconds("mask_sample") > 0.0
        dev_fwd = any(self.devtime.seconds(f) > 0.0
                      for f in ATTR_FORWARD_PHASES)
        return {
            "enabled": True,
            "seconds": comp,
            "fractions": {k: (v / total if total > 0 else 0.0)
                          for k, v in comp.items()
                          if k in ("host_grammar", "mask_sample_kernel",
                                   "forward_kernel")},
            "source": {
                "mask_sample_kernel": "device" if dev_mask
                                      else "host-dispatch",
                "forward_kernel": "device" if dev_fwd
                                  else "host-dispatch",
            },
            "device_timing": self.devtime.enabled,
        }

    def stats_json(self) -> dict:
        """Everything /stats serves: registry snapshot + lifecycle
        summary + trace state + build identity + attribution."""
        return {
            "enabled": self.enabled,
            "uptime_seconds": self.uptime(),
            "build": build_info(),
            "requests": self.lifecycle.summary(),
            "metrics": self.registry.snapshot(),
            "attribution": self.attribution(),
            "device": {"enabled": self.devtime.enabled,
                       "sync_calls": self.devtime.sync_calls,
                       "functions": self.devtime.summary()},
            "profiler": self.profiler.state(),
            "trace": {"active": self.tracer.active,
                      "buffered_events": len(self.tracer),
                      "dropped_events": self.tracer.dropped},
        }
