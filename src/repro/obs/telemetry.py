"""Telemetry facade: one object the serving stack threads everywhere.

`Telemetry` bundles the metrics registry, the trace ring buffer, and the
request-lifecycle tracker behind a single `span()` API:

    with tele.span("mask_dispatch") as sp:
        ... host-side work already bracketed by perf_counter ...
    st.mask_time += sp.dur

Each span, on exit, adds its duration to the per-phase counter pair
(`repro_step_phase_seconds_total{phase=...}` +
`repro_step_phase_calls_total{phase=...}`), observes the per-phase
histogram, and — only while a trace capture is active — records a
Chrome-trace complete event. The span's measured duration (`sp.dur`) is
what callers feed into the legacy per-slot accounting, so EngineStats
and the registry are two views of the SAME perf_counter bracket and can
never drift apart.

Disabled fast path: `Telemetry(enabled=False).span(...)` returns one
shared `_NullSpan` whose __enter__/__exit__ do nothing and whose `dur`
is 0.0 — no perf_counter call, no dict lookups, no allocation. The
overhead guard in tests/test_obs.py pins this below
`DISABLED_SPAN_BUDGET_S`. Count-style instruments (tokens, mask
computations, overlap outcomes) stay live even when disabled — they are
plain float adds and EngineStats' exact count invariants depend on
them.

Pure stdlib — no jax/numpy anywhere in repro.obs.
"""
from __future__ import annotations

import time
from typing import Optional

from .lifecycle import LifecycleTracker, NullLifecycle
from .registry import PHASE_BUCKETS, MetricsRegistry
from .trace import Tracer

# Named overhead budgets (seconds), asserted by tests/test_obs.py.
# DISABLED_SPAN_BUDGET_S: per span() call with telemetry off — must be
# cheap enough to leave in every hot path unconditionally.
# ENABLED_SPAN_BUDGET_S: per span with telemetry on but tracing off —
# two perf_counter calls + a few float adds.
DISABLED_SPAN_BUDGET_S = 2e-6
ENABLED_SPAN_BUDGET_S = 25e-6


class _NullSpan:
    """Shared no-op span: telemetry disabled. dur is always 0.0."""
    __slots__ = ()
    dur = 0.0
    t0 = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tele", "phase", "track", "args", "t0", "dur")

    def __init__(self, tele: "Telemetry", phase: str,
                 track: Optional[str], args: Optional[dict]):
        self.tele = tele
        self.phase = phase
        self.track = track
        self.args = args
        self.t0 = 0.0
        self.dur = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.dur = dur = time.perf_counter() - self.t0
        tele = self.tele
        sec, calls, hist = tele._phase(self.phase)
        sec.inc(dur)
        calls.inc()
        hist.observe(dur)
        if tele.tracer.active:
            tele.tracer.add(self.track or self.phase, self.phase,
                            self.t0, dur, self.args)
        return False


class Telemetry:
    """enabled=True: full spans/histograms/lifecycle/trace.
    enabled=False: span() is a shared no-op and lifecycle hooks vanish;
    the registry still exists so count-style instruments keep working."""

    def __init__(self, enabled: bool = True,
                 trace_capacity: Optional[int] = None):
        self.enabled = bool(enabled)
        self.registry = MetricsRegistry()
        self.tracer = Tracer(**({} if trace_capacity is None
                                else {"capacity": trace_capacity}))
        self.lifecycle = (LifecycleTracker(self.registry)
                          if self.enabled else NullLifecycle())
        self.t_start = time.perf_counter()
        self._phases: dict = {}
        if self.enabled:
            self.registry.gauge(
                "repro_uptime_seconds", "seconds since telemetry start",
                fn=lambda: time.perf_counter() - self.t_start)

    # ------------------------------ spans ------------------------------

    def _phase(self, phase: str):
        tup = self._phases.get(phase)
        if tup is None:
            reg = self.registry
            tup = self._phases[phase] = (
                reg.counter("repro_step_phase_seconds_total",
                            "cumulative host seconds per step phase",
                            {"phase": phase}),
                reg.counter("repro_step_phase_calls_total",
                            "span count per step phase", {"phase": phase}),
                reg.histogram("repro_step_phase_duration_seconds",
                              "per-span duration by phase",
                              PHASE_BUCKETS, {"phase": phase}),
            )
        return tup

    def span(self, phase: str, track: Optional[str] = None,
             args: Optional[dict] = None):
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, phase, track, args)

    def phase_seconds(self, phase: str) -> float:
        """Cumulative seconds recorded for a phase (0.0 if never hit)."""
        if not self.enabled or phase not in self._phases:
            return 0.0
        return self._phases[phase][0].value

    def phase_calls(self, phase: str) -> int:
        if not self.enabled or phase not in self._phases:
            return 0
        return int(self._phases[phase][1].value)

    # -------------------------- count helpers --------------------------
    # Always-on (cheap float adds): exact token/count stats must hold
    # with telemetry disabled, so these never go through the null path.

    def counter(self, name: str, help: str = "",
                labels: Optional[dict] = None):
        return self.registry.counter(name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Optional[dict] = None, fn=None):
        return self.registry.gauge(name, help, labels, fn=fn)

    # --------------------------- integrations --------------------------

    def register_kv(self, alloc) -> None:
        """Callback gauges over a PagedAllocator — evaluated at scrape
        time, never pushed from the step loop."""
        reg = self.registry
        g = reg.gauge

        def metric(key):
            return lambda: float(alloc.metrics()[key])

        g("repro_kv_pages_total", "KV pool size in pages",
          fn=metric("pages_total"))
        g("repro_kv_pages_in_use", "KV pages currently referenced",
          fn=metric("pages_in_use"))
        g("repro_kv_pages_free", "KV pages on the free list",
          fn=metric("pages_free"))
        g("repro_kv_pages_cold", "evictable cached pages",
          fn=metric("pages_cold"))
        g("repro_kv_pages_peak", "high-water mark of pages in use",
          fn=metric("peak_in_use"))
        g("repro_kv_prefix_hit_rate", "prefix-cache token hit rate",
          fn=metric("prefix_hit_rate"))
        reg.counter("repro_kv_page_allocs_total", "pages ever allocated",
                    fn=metric("page_allocs"))
        reg.counter("repro_kv_evictions_total", "cold pages evicted",
                    fn=metric("evictions"))
        reg.counter("repro_kv_cow_copies_total", "copy-on-write page copies",
                    fn=metric("cow_copies"))

    # ------------------------------ views ------------------------------

    def uptime(self) -> float:
        return time.perf_counter() - self.t_start

    def stats_json(self) -> dict:
        """Everything /stats serves: registry snapshot + lifecycle
        summary + trace state."""
        return {
            "enabled": self.enabled,
            "uptime_seconds": self.uptime(),
            "requests": self.lifecycle.summary(),
            "metrics": self.registry.snapshot(),
            "trace": {"active": self.tracer.active,
                      "buffered_events": len(self.tracer),
                      "dropped_events": self.tracer.dropped},
        }
