"""Device-time attribution: span brackets around jitted calls + the
live-profiler session driven by `POST /profile`.

PR 7's host spans measure *dispatch* latency — on an async backend the
`forward` span closes microseconds after the kernel is queued, so the
numbers that decide SynCode's viability (is the fused mask+sample kernel
hiding the grammar work? how big is the forward really?) are invisible.
`DeviceTimer` closes that gap with an explicit, mode-gated exception to
the no-sync contract:

  * **serving mode (default)** — `span()` returns the shared no-op
    `NULL_DEV_SPAN`; nothing syncs, the PR 7 contract holds verbatim
    (tests/test_devtime.py proves the injected sync fn is never called).
  * **bench / profile mode** — `span(fn)` brackets the jitted call and
    `done(out)` hands the dispatched arrays to the *injected* `sync_fn`
    (`jax.block_until_ready`, bound by serving/devbridge.py — this
    package still never imports jax). The bracket then covers dispatch
    **plus device execution**, i.e. a true device interval on the host
    `perf_counter` clock, so device tracks align with host spans in one
    Perfetto timeline with no clock translation.

Each measured interval feeds three surfaces:

  * registry families `repro_device_seconds_total{fn=}`,
    `repro_device_calls_total{fn=}` and the
    `repro_device_duration_seconds{fn=}` histogram,
  * a `device:<fn>` trace track (only while the tracer is capturing),
  * `DeviceTimer.summary()` — per-fn seconds/calls plus, when a static
    cost estimate was attached via `set_cost()` (distributed/hlo_cost
    parsed from the compiled HLO), achieved FLOP/s and bytes/s for
    roofline positioning (benchmarks/roofline.position).

`ProfilerSession` is the `POST /profile start|stop|dump` state machine:
start flips the owning DeviceTimer into sync-on-exit mode, starts trace
capture, and (when devbridge bound one) starts a `jax.profiler` trace
into a temp dir; dump merges the profiler's own device-thread events
into the exported Chrome timeline (`collect_chrome_events`, parsed from
`*.trace.json.gz` with stdlib gzip+json and linearly rebased onto the
host clock window of the capture).

Pure stdlib — no jax/numpy anywhere in repro.obs; every device-touching
capability is injected by the caller that already owns jax.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import tempfile
import time
from typing import Callable, Optional

from .registry import MetricsRegistry, PHASE_BUCKETS

# Track-name prefix for device intervals in the exported trace: host
# phases keep their PR 7 tracks, device intervals land beside them.
DEVICE_TRACK_PREFIX = "device:"


class _NullDevSpan:
    """Shared no-op span: serving mode. done() drops the arrays."""
    __slots__ = ()
    dur = 0.0
    t0 = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def done(self, out) -> None:
        pass


NULL_DEV_SPAN = _NullDevSpan()


class _DevSpan:
    __slots__ = ("timer", "fn", "t0", "dur", "_out")

    def __init__(self, timer: "DeviceTimer", fn: str):
        self.timer = timer
        self.fn = fn
        self.t0 = 0.0
        self.dur = 0.0
        self._out = None

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def done(self, out) -> None:
        """Hand the dispatched device arrays to the bracket; __exit__
        blocks on them, so the span covers dispatch + execution."""
        self._out = out

    def __exit__(self, *exc):
        timer = self.timer
        if self._out is not None and exc[0] is None:
            timer.sync_fn(self._out)
            self._out = None
        self.dur = dur = time.perf_counter() - self.t0
        timer._record(self.fn, self.t0, dur)
        return False


class DeviceTimer:
    """Mode-gated device-interval measurement over injected sync.

    `enabled` is False in serving (span() is free and never syncs) and
    True in bench/profile mode. `sync_fn` is injected exactly once by
    serving/devbridge.py; until it is bound, span() no-ops even when
    enabled, so obs stays import-pure and unbound timers are harmless.
    """

    def __init__(self, registry: MetricsRegistry, tracer):
        self.registry = registry
        self.tracer = tracer
        self.enabled = False
        self.sync_fn: Optional[Callable] = None
        self.sync_calls = 0             # asserted by the serving-mode
                                        # never-synced test
        self._fams: dict = {}
        self.last_dur: dict[str, float] = {}
        self.costs: dict[str, dict] = {}

    # ------------------------------ wiring -----------------------------

    def bind(self, sync_fn: Callable) -> None:
        """Inject the device-sync capability (idempotent)."""
        if self.sync_fn is None:
            base = sync_fn

            def counted(out):
                self.sync_calls += 1
                return base(out)
            self.sync_fn = counted

    def set_cost(self, fn: str, flops: float, hbm_bytes: float,
                 wire_bytes: float = 0.0) -> None:
        """Attach a static per-call FLOP/byte estimate for a jitted fn
        (distributed/hlo_cost over its compiled HLO). Exposed as
        scrape-time gauges so /metrics carries the roofline inputs."""
        self.costs[fn] = {"flops": float(flops),
                          "hbm_bytes": float(hbm_bytes),
                          "wire_bytes": float(wire_bytes)}
        g = self.registry.gauge
        g("repro_device_flops_per_call", "static FLOPs per jitted call "
          "(hlo_cost estimate)", {"fn": fn},
          fn=lambda f=fn: self.costs[f]["flops"])
        g("repro_device_hbm_bytes_per_call", "static HBM bytes per "
          "jitted call (hlo_cost estimate)", {"fn": fn},
          fn=lambda f=fn: self.costs[f]["hbm_bytes"])

    # ----------------------------- spanning ----------------------------

    def span(self, fn: str):
        if not self.enabled or self.sync_fn is None:
            return NULL_DEV_SPAN
        return _DevSpan(self, fn)

    def _family(self, fn: str):
        tup = self._fams.get(fn)
        if tup is None:
            reg = self.registry
            tup = self._fams[fn] = (
                reg.counter("repro_device_seconds_total",
                            "synced device interval seconds per jitted fn",
                            {"fn": fn}),
                reg.counter("repro_device_calls_total",
                            "device-timed calls per jitted fn",
                            {"fn": fn}),
                reg.histogram("repro_device_duration_seconds",
                              "per-call device interval by jitted fn",
                              PHASE_BUCKETS, {"fn": fn}),
            )
        return tup

    def _record(self, fn: str, t0: float, dur: float) -> None:
        sec, calls, hist = self._family(fn)
        sec.inc(dur)
        calls.inc()
        hist.observe(dur)
        self.last_dur[fn] = dur
        if self.tracer.active:
            self.tracer.add(DEVICE_TRACK_PREFIX + fn, fn, t0, dur)

    # ------------------------------ views ------------------------------

    def seconds(self, fn: str) -> float:
        tup = self._fams.get(fn)
        return tup[0].value if tup else 0.0

    def calls(self, fn: str) -> int:
        tup = self._fams.get(fn)
        return int(tup[1].value) if tup else 0

    def summary(self) -> dict:
        """Per-fn device accounting + achieved-rate roofline inputs."""
        out = {}
        for fn, (sec, calls, hist) in self._fams.items():
            d = {"calls": int(calls.value), "seconds": sec.value,
                 "p50": hist.quantile(0.5), "p99": hist.quantile(0.99)}
            cost = self.costs.get(fn)
            if cost and sec.value > 0 and calls.value > 0:
                per_call = sec.value / calls.value
                d["flops_per_call"] = cost["flops"]
                d["hbm_bytes_per_call"] = cost["hbm_bytes"]
                d["achieved_flops_per_s"] = cost["flops"] / per_call
                d["achieved_bytes_per_s"] = cost["hbm_bytes"] / per_call
            out[fn] = d
        return out


# --------------------------- profiler session ---------------------------

# Chrome-trace thread names that carry real device/kernel execution in a
# jax.profiler capture (TFRT CPU client executor threads, TPU/GPU device
# streams). Python host-callstack threads are dropped from the merge —
# the host side of the merged view comes from our own phase spans.
_DEVICE_THREAD_MARKERS = ("XLATfrtCpuClient", "/device:", "TPU", "GPU",
                          "Stream", "xla-cpu")
# Executor bookkeeping slices that would drown the kernels they schedule
_NOISE_EVENTS = ("ThreadpoolListener", "ThunkExecutor")


class ProfilerSession:
    """State machine behind `POST /profile start|stop|dump`.

    start():  remember the DeviceTimer's mode, flip it to sync-on-exit,
              start trace capture, and start the backend profiler (when
              devbridge bound one) into a fresh temp dir.
    stop():   stop the backend profiler, restore the DeviceTimer mode.
    dump():   chrome events collected from the backend profiler's
              `*.trace.json.gz`, rebased onto the host-clock window of
              the capture — merged by Tracer.export_chrome(extra=...).

    The host perf_counter timestamps taken at start/stop are the
    alignment anchors: profiler event timestamps are offsets on the
    profiler's own clock, so the earliest captured event is pinned to
    the session's host start time. Visual alignment, not ns-exact —
    the authoritative device intervals are the DeviceTimer spans, which
    are measured on the host clock directly.
    """

    def __init__(self, devtimer: DeviceTimer, tracer):
        self.devtimer = devtimer
        self.tracer = tracer
        self.profiler_start: Optional[Callable] = None  # (log_dir) -> None
        self.profiler_stop: Optional[Callable] = None   # () -> None
        self.active = False
        self.log_dir: Optional[str] = None
        self.host_t0 = 0.0
        self.host_t1 = 0.0
        self._was_enabled = False

    def bind(self, profiler_start: Callable, profiler_stop: Callable):
        if self.profiler_start is None:
            self.profiler_start = profiler_start
            self.profiler_stop = profiler_stop

    # ------------------------------ control ----------------------------

    def start(self, log_dir: Optional[str] = None) -> dict:
        if self.active:
            raise RuntimeError("profile capture already active")
        self.log_dir = log_dir or tempfile.mkdtemp(prefix="repro_profile_")
        self.host_t0 = time.perf_counter()
        self.host_t1 = 0.0
        self._was_enabled = self.devtimer.enabled
        self.devtimer.enabled = True        # sync-on-exit device spans:
        # the documented profile-mode exception to the no-sync contract
        self.tracer.clear()
        self.tracer.start()
        backend = False
        if self.profiler_start is not None:
            try:
                self.profiler_start(self.log_dir)
                backend = True
            except Exception:
                pass        # devtime spans still capture device intervals
        self.active = True
        return {"log_dir": self.log_dir, "backend_profiler": backend}

    def stop(self) -> dict:
        if not self.active:
            raise RuntimeError("no profile capture active")
        self.host_t1 = time.perf_counter()
        if self.profiler_stop is not None:
            try:
                self.profiler_stop()
            except Exception:
                pass
        self.devtimer.enabled = self._was_enabled
        self.tracer.stop()
        self.active = False
        return {"log_dir": self.log_dir,
                "duration_s": self.host_t1 - self.host_t0,
                "buffered_events": len(self.tracer)}

    # ------------------------------- dump ------------------------------

    def collect_chrome_events(self) -> list:
        """Device-thread slices from the backend profiler's Chrome trace
        (`plugins/profile/*/ *.trace.json.gz`), rebased to the host
        clock. Best-effort: an absent or unreadable capture yields []."""
        if not self.log_dir:
            return []
        pats = os.path.join(self.log_dir, "**", "*.trace.json.gz")
        events: list = []
        for fn in sorted(glob.glob(pats, recursive=True)):
            try:
                with gzip.open(fn, "rt") as f:
                    doc = json.load(f)
            except Exception:
                continue
            evs = doc.get("traceEvents", [])
            threads = {}        # (pid, tid) -> thread name
            for e in evs:
                if e.get("ph") == "M" and e.get("name") == "thread_name":
                    threads[(e.get("pid"), e.get("tid"))] = \
                        e.get("args", {}).get("name", "")
            dev_tids = {k for k, v in threads.items()
                        if any(m in v for m in _DEVICE_THREAD_MARKERS)}
            picked = [e for e in evs
                      if e.get("ph") == "X"
                      and (e.get("pid"), e.get("tid")) in dev_tids
                      and not any(e.get("name", "").startswith(n)
                                  for n in _NOISE_EVENTS)]
            if not picked:
                continue
            ts0 = min(e["ts"] for e in picked)
            base_us = self.host_t0 * 1e6
            for e in picked:
                tname = threads[(e.get("pid"), e.get("tid"))]
                events.append({
                    "track": DEVICE_TRACK_PREFIX + "xla "
                             + tname.split("/")[0],
                    "name": e.get("name", "?"),
                    "ts_us": base_us + (e["ts"] - ts0),
                    "dur_us": float(e.get("dur", 0.0)),
                })
        return events

    def state(self) -> dict:
        return {"active": self.active, "log_dir": self.log_dir,
                "backend_bound": self.profiler_start is not None,
                "device_timing": self.devtimer.enabled}
