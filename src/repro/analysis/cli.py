"""reprolint command line (`scripts/reprolint.py`, `make lint`).

Exit codes: 0 clean, 1 unsuppressed findings, 2 usage/parse error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import DEFAULT_PATHS, RULES, lint


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="AST-based invariant analyzer for this repo "
                    "(rule catalog: docs/static_analysis.md)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to scan (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--root", default=None,
                    help="project root (default: auto from this file)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output")
    ap.add_argument("--verbose", action="store_true",
                    help="also list suppressed findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, r in sorted(RULES.items()):
            first = r.doc.splitlines()[0] if r.doc else ""
            print(f"{rid}  {r.name:18s} {first}")
        return 0

    root = Path(args.root) if args.root else \
        Path(__file__).resolve().parents[3]
    select = [s.strip() for s in args.rules.split(",")] \
        if args.rules else None
    try:
        report = lint(root, paths=args.paths or None, select=select)
    except (ValueError, SyntaxError) as e:
        print(f"reprolint: error: {e}", file=sys.stderr)
        return 2
    print(report.render_json() if args.json
          else report.render_human(verbose=args.verbose))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
