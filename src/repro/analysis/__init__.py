"""reprolint: stdlib-only AST/import-graph static analysis of the
repo's own serving invariants.

The invariants this package mechanizes used to live as regex
source-asserts scattered across the test suite; each is now ONE rule
implementation shared by `make lint`, CI, and the regression tests:

  RL001 alias-race        mutated-in-place host buffers aliased into
                          async device dispatches (the PR 5 bug class)
  RL002 obs-purity        repro.obs never imports jax/numpy,
                          transitively
  RL003 sync-confinement  block_until_ready only in serving/devbridge
  RL004 span-hygiene      telemetry span bodies stay host-only
  RL005 kernel-parity     every pallas_call package ships ops/ref and
                          a parity test

Entry points: `lint()` here, `scripts/reprolint.py` / `make lint` on
the command line. docs/static_analysis.md is the rule catalog and the
how-to-add-a-rule guide.
"""
from __future__ import annotations

from .findings import Finding, Report                      # noqa: F401
from .project import Project                               # noqa: F401
from .registry import RULES, run_rules                     # noqa: F401
from . import rules                                        # noqa: F401

DEFAULT_PATHS = ("src", "benchmarks", "scripts")


def lint(root, paths=None, select=None, overlay=None) -> Report:
    """Run the (selected) rules over `paths` relative to `root`.

    `overlay` maps relative paths to substitute source text so tests
    can prove a rule fires on a hypothetical edit without touching
    disk.
    """
    project = Project.load(root, paths=paths or DEFAULT_PATHS,
                           overlay=overlay)
    return run_rules(project, select=select)
