"""Project scanner: parse every .py once, attach directives, and build
the repo-internal module-level import graph rules traverse (RL002).

The scanner is pure stdlib and filesystem-read-only. Tests inject an
`overlay` ({relative-path: source-text}) so a rule can be proven to
fire on a hypothetical edit — "delete this .copy()", "add a numpy
import under obs/" — without touching the tree.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from pathlib import Path

from .suppress import Directives, parse_directives

SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "artifacts",
             ".hypothesis", ".ruff_cache", "node_modules"}


@dataclass
class SourceFile:
    rel: str                    # posix path relative to project root
    text: str
    tree: ast.Module
    directives: Directives
    module: str | None          # dotted name when under src/


@dataclass
class Project:
    root: Path
    files: list = field(default_factory=list)
    _by_rel: dict = field(default_factory=dict)
    _by_module: dict = field(default_factory=dict)

    @classmethod
    def load(cls, root, paths=None, overlay=None) -> "Project":
        """Parse every .py under `paths` (default: src benchmarks
        scripts). `overlay` substitutes file contents by relative path;
        overlay keys that match no on-disk file are added as virtual
        files (fixture trees)."""
        root = Path(root).resolve()
        overlay = dict(overlay or {})
        proj = cls(root=root)
        rels: list[str] = []
        for p in (paths or ("src", "benchmarks", "scripts")):
            p = Path(p)
            if not p.is_absolute():
                p = root / p
            if p.is_file():
                rels.append(p.relative_to(root).as_posix())
            elif p.is_dir():
                for dirpath, dirnames, filenames in os.walk(p):
                    dirnames[:] = [d for d in dirnames
                                   if d not in SKIP_DIRS]
                    for fn in sorted(filenames):
                        if fn.endswith(".py"):
                            rel = (Path(dirpath) / fn) \
                                .relative_to(root).as_posix()
                            rels.append(rel)
        for rel in overlay:
            if rel not in rels:
                rels.append(rel)
        for rel in sorted(set(rels)):
            text = overlay.get(rel)
            if text is None:
                text = (root / rel).read_text()
            proj._add(rel, text)
        return proj

    def _add(self, rel: str, text: str) -> None:
        tree = ast.parse(text, filename=rel)
        sf = SourceFile(rel=rel, text=text, tree=tree,
                        directives=parse_directives(text),
                        module=module_name(rel))
        self.files.append(sf)
        self._by_rel[rel] = sf
        if sf.module:
            self._by_module[sf.module] = sf

    def file(self, rel: str):
        return self._by_rel.get(rel)

    def by_module(self, module: str):
        return self._by_module.get(module)

    def read_text(self, rel: str) -> str | None:
        """Overlay-aware read for paths OUTSIDE the scan set (RL005
        checks tests/ without linting it)."""
        sf = self._by_rel.get(rel)
        if sf is not None:
            return sf.text
        p = self.root / rel
        return p.read_text() if p.is_file() else None

    def glob(self, pattern: str) -> list:
        """Relative paths matching `pattern`, merged over disk and
        virtual overlay files."""
        rels = {p.relative_to(self.root).as_posix()
                for p in self.root.glob(pattern)}
        import fnmatch
        rels.update(r for r in self._by_rel
                    if fnmatch.fnmatch(r, pattern))
        return sorted(rels)

    # ----------------------- import graph (RL002) -----------------------

    def import_edges(self) -> dict:
        """module -> {(imported_module, lineno)} for MODULE-LEVEL
        imports only (function-local imports are lazy: they cannot pull
        a dependency in at import time). Edges cover both project
        modules and the raw top-level names of foreign imports, plus
        ancestor packages (importing a.b.c executes a/__init__ and
        a.b/__init__)."""
        edges: dict = {}
        for sf in self.files:
            if not sf.module:
                continue
            out = set()
            for node, names in _module_level_imports(sf.tree, sf.module):
                for name in names:
                    for target in self._resolve(name):
                        out.add((target, node.lineno))
            edges[sf.module] = out
        return edges

    def _resolve(self, dotted: str) -> list:
        """dotted import -> project modules it executes (self +
        existing ancestor packages), or its top-level name when
        foreign."""
        hits = []
        parts = dotted.split(".")
        for i in range(len(parts), 0, -1):
            cand = ".".join(parts[:i])
            if cand in self._by_module:
                hits.append(cand)
                # ancestor packages: importing a.b.c executes the
                # __init__ of a and a.b too
                for j in range(1, i):
                    anc = ".".join(parts[:j])
                    if anc in self._by_module:
                        hits.append(anc)
                break
        else:
            hits.append(parts[0])
        return hits


def module_name(rel: str) -> str | None:
    """src/repro/core/lexer.py -> repro.core.lexer ;
    src/repro/obs/__init__.py -> repro.obs ; non-src files -> None."""
    if not rel.startswith("src/") or not rel.endswith(".py"):
        return None
    parts = rel[len("src/"):-len(".py")].split("/")
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else None


def _module_level_imports(tree: ast.Module, module: str):
    """Yield (node, [dotted names]) for imports executed at import time
    — anywhere except inside a function body (class bodies and
    module-level `if`/`try` blocks DO execute)."""
    def walk(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if isinstance(child, ast.Import):
                yield child, [a.name for a in child.names]
            elif isinstance(child, ast.ImportFrom):
                if child.level:     # relative: resolve against module
                    base = module.split(".")
                    base = base[: len(base) - child.level + 1]
                    stem = ".".join(base + ([child.module]
                                            if child.module else []))
                else:
                    stem = child.module or ""
                names = [stem] if stem else []
                # `from pkg import sub` may bind a submodule: add
                # pkg.sub candidates so package-internal re-exports
                # count as edges
                for a in child.names:
                    if stem and a.name != "*":
                        names.append(f"{stem}.{a.name}")
                yield child, names
            yield from walk(child)
    yield from walk(tree)
