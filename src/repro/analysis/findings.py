"""Finding/Report datatypes and the two output renderers.

A `Finding` is one rule violation anchored at file:line. The `Report`
separates live findings (lint fails) from suppressed ones (annotated
away with a justified `# reprolint: disable=RLxxx <why>`) so both the
CLI and the tests can assert on either population.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field


@dataclass
class Finding:
    rule: str                   # "RL001"
    name: str                   # "alias-race"
    path: str                   # repo-relative, posix separators
    line: int
    message: str
    hint: str = ""
    suppressed: bool = False
    justification: str = ""     # set when suppressed

    def location(self) -> str:
        return f"{self.path}:{self.line}"

    def as_dict(self) -> dict:
        return {
            "rule": self.rule, "name": self.name, "path": self.path,
            "line": self.line, "message": self.message, "hint": self.hint,
            "suppressed": self.suppressed,
            "justification": self.justification,
        }


@dataclass
class Report:
    findings: list = field(default_factory=list)    # unsuppressed
    suppressed: list = field(default_factory=list)
    files_scanned: int = 0
    rules_run: list = field(default_factory=list)
    elapsed_s: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.findings

    def by_rule(self, rid: str) -> list:
        return [f for f in self.findings if f.rule == rid]

    def render_human(self, verbose: bool = False) -> str:
        lines = []
        for f in sorted(self.findings, key=lambda f: (f.path, f.line,
                                                      f.rule)):
            lines.append(f"{f.location()}: {f.rule} [{f.name}] "
                         f"{f.message}")
            if f.hint:
                lines.append(f"    hint: {f.hint}")
        if verbose:
            for f in sorted(self.suppressed,
                            key=lambda f: (f.path, f.line)):
                lines.append(f"{f.location()}: {f.rule} suppressed "
                             f"({f.justification})")
        lines.append(
            f"reprolint: {len(self.findings)} finding(s), "
            f"{len(self.suppressed)} suppressed, "
            f"{self.files_scanned} file(s), "
            f"rules {','.join(self.rules_run)}, "
            f"{self.elapsed_s:.2f}s")
        return "\n".join(lines)

    def render_json(self) -> str:
        return json.dumps({
            "findings": [f.as_dict() for f in self.findings],
            "suppressed": [f.as_dict() for f in self.suppressed],
            "files_scanned": self.files_scanned,
            "rules_run": self.rules_run,
            "elapsed_s": round(self.elapsed_s, 3),
            "ok": self.ok,
        }, indent=1)
