"""Rule registry and the lint driver.

A rule is a function `(Project) -> list[Finding]` registered under a
stable id. `run_rules` executes the selected rules, folds justified
`# reprolint: disable=` suppressions into the report, and appends the
RL000 suppression-hygiene findings (malformed directives, unjustified
or stale suppressions) — RL000 itself can never be suppressed.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from .findings import Finding, Report

RULES: dict = {}


@dataclass
class Rule:
    rid: str
    name: str
    doc: str
    fn: object


def rule(rid: str, name: str):
    def deco(fn):
        if rid in RULES:
            raise ValueError(f"duplicate rule id {rid}")
        RULES[rid] = Rule(rid=rid, name=name,
                          doc=(fn.__doc__ or "").strip(), fn=fn)
        return fn
    return deco


def run_rules(project, select=None) -> Report:
    t0 = time.perf_counter()
    selected = sorted(select) if select else sorted(RULES)
    unknown = [r for r in selected if r not in RULES]
    if unknown:
        raise ValueError(f"unknown rule id(s): {','.join(unknown)}")
    report = Report(files_scanned=len(project.files),
                    rules_run=selected)
    for rid in selected:
        for f in RULES[rid].fn(project):
            sf = project.file(f.path)
            d = sf.directives.disable_for(f.rule, f.line) if sf else None
            if d is not None:
                d.used.add(f.rule)
                f.suppressed = True
                f.justification = d.justification
                report.suppressed.append(f)
            else:
                report.findings.append(f)
    _suppression_hygiene(project, selected, report)
    report.elapsed_s = time.perf_counter() - t0
    return report


def _suppression_hygiene(project, selected, report) -> None:
    for sf in project.files:
        for line, msg in sf.directives.errors:
            report.findings.append(Finding(
                rule="RL000", name="suppression-hygiene", path=sf.rel,
                line=line, message=msg,
                hint="see docs/static_analysis.md §Suppression policy"))
        for d in sf.directives.disables:
            ran = [r for r in d.rules if r in selected]
            if ran and not d.used:
                report.findings.append(Finding(
                    rule="RL000", name="suppression-hygiene",
                    path=sf.rel, line=d.line,
                    message=f"stale suppression: "
                            f"{','.join(d.rules)} matched no finding "
                            f"on this or the next line",
                    hint="delete the directive, or move it onto the "
                         "offending line"))
