"""RL004 span-hygiene: telemetry `span(...)` bodies stay host-only.

PR 7's overhead contract: enabling telemetry must never ADD a device
sync — spans may only stamp perf_counter around host work that already
existed. A `block_until_ready` / `.item()` / `device_get` inside a
`with ...span(...):` body would bill device time to a host phase (and
serialize the overlap); a direct `pallas_call` inside one would hide a
kernel construction+dispatch in what reads as pure bookkeeping.

`device_span(...)` bodies are exempt — that bracket exists to measure
the device, and its sync is the injected devbridge capability, gated
off in serving mode. Nested function definitions inside a span body
are skipped (they execute elsewhere).
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..registry import rule

SYNC_IDENTS = ("block_until_ready", "device_get")


def _span_withs(tree):
    for node in ast.walk(tree):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                ctx = item.context_expr
                if isinstance(ctx, ast.Call) and \
                        isinstance(ctx.func, ast.Attribute) and \
                        ctx.func.attr == "span":
                    yield node
                    break


def _body_nodes(with_node):
    def walk(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef,
                                  ast.AsyncFunctionDef, ast.Lambda)):
                continue
            yield child
            yield from walk(child)
    for stmt in with_node.body:
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue            # nested def at body top level: executes
        yield from walk(stmt)   # elsewhere, like any deeper nested def


@rule("RL004", "span-hygiene")
def check(project):
    """telemetry span bodies stay host-only: no device sync or
    pallas_call dispatch inside `with ...span(...)`"""
    findings = []
    seen = set()
    for sf in project.files:
        for w in _span_withs(sf.tree):
            for node in _body_nodes(w):
                bad = None
                if isinstance(node, ast.Name) and \
                        node.id in SYNC_IDENTS + ("pallas_call",):
                    bad = node.id
                elif isinstance(node, ast.Attribute) and \
                        node.attr in SYNC_IDENTS + ("pallas_call",):
                    bad = node.attr
                elif isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    bad = ".item()"
                if bad and (sf.rel, node.lineno, bad) not in seen:
                    seen.add((sf.rel, node.lineno, bad))
                    findings.append(Finding(
                        rule="RL004", name="span-hygiene", path=sf.rel,
                        line=node.lineno,
                        message=f"{bad} inside a telemetry span body: "
                                f"spans bracket host work only — a "
                                f"sync or kernel dispatch here bills "
                                f"device time to a host phase and "
                                f"breaks the no-added-syncs contract "
                                f"(docs/observability.md)",
                        hint="move the device work outside the span, "
                             "or use device_span for a deliberate "
                             "device bracket"))
    return findings
