"""RL001 alias-race: host numpy buffers mutated in place while an async
device dispatch may still be reading them.

On XLA:CPU, `jnp.asarray` (and a jitted call taking numpy args
directly) may ZERO-COPY alias host memory. Dispatch is async: mutating
the buffer afterwards mutates it under the in-flight computation's
feet. PR 5 root-caused a 5.47-magnitude prefill-logits corruption to
exactly this (`serving/loop.py` paged span feed); this rule mechanizes
the guard repo-wide.

Per function scope, a dispatch of a plain name/dotted buffer without a
`.copy()` fires when any of:

  * the buffer is mutated in place LATER in the same function
    (subscript store, augmented assign, `.fill()`-class methods,
    `np.copyto`);
  * the dispatch sits inside a `for`/`while` loop that ALSO mutates
    the buffer anywhere in its body (loop-carried: iteration k+1
    mutates what iteration k dispatched);
  * the enclosing function declares the buffer
    `# reprolint: mutated-inflight=...` (another code path — e.g. the
    admission handler — mutates it between this function's dispatch
    and the device read);
  * the buffer was produced by `next(...)` inside a loop (opaque
    producer: a reused staging buffer is invisible here) and the
    producer statement carries no `# reprolint: fresh-batch` contract.

Dispatch sites are `jnp.asarray` / `jax.device_put` calls, plus every
call in a statement annotated `# reprolint: dispatch` (jitted calls
taking numpy args without an asarray wrapper). Fresh expressions
(literals, arithmetic, allocation calls) and `.copy()` arguments never
fire.
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..registry import rule

ASARRAY_FNS = {"jnp.asarray", "jax.numpy.asarray", "jax.device_put"}
MUTATOR_METHODS = {"fill", "sort", "put", "itemset", "partition",
                   "resize", "byteswap"}
STORY = ("zero-copy aliasing on XLA:CPU — the async computation can "
         "read the buffer AFTER this function mutates it (the PR 5 "
         "prefill-corruption bug class, CHANGES.md PR 5 addendum)")


def _chain(node) -> str | None:
    """Dotted name for Name/Attribute chains (`loop.feed_pos`), else
    None (anything computed is a fresh temporary)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _iter_scope(node):
    """Walk a scope without descending into nested function bodies
    (those are their own scopes)."""
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield child
        yield from _iter_scope(child)


def _scopes(tree):
    yield tree
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


class _Scope:
    def __init__(self, sf, scope):
        self.sf = sf
        self.scope = scope
        self.nodes = list(_iter_scope(scope))
        self.stmts = [n for n in self.nodes if isinstance(n, ast.stmt)]
        self.loops = [n for n in self.nodes
                      if isinstance(n, (ast.For, ast.AsyncFor,
                                        ast.While))]
        self.aliases = self._aliases()
        self.mutations = self._mutations()   # [(canonical id, lineno)]
        self.rebinds = self._rebinds()       # [(chain, lineno)]
        self.producers = self._producers()   # tainted names from next()
        self.inflight = self._inflight()

    # --------------------------- resolution ---------------------------

    def _aliases(self) -> dict:
        out = {}
        for n in self.stmts:
            if isinstance(n, ast.Assign) and len(n.targets) == 1 and \
                    isinstance(n.targets[0], ast.Name):
                src = _chain(n.value)
                if src is not None and src != n.targets[0].id:
                    out[n.targets[0].id] = src
        return out

    def canon(self, cid: str) -> str:
        seen = set()
        while cid in self.aliases and cid not in seen:
            seen.add(cid)
            cid = self.aliases[cid]
        return cid

    # ---------------------------- mutations ---------------------------

    def _mutations(self) -> list:
        out = []

        def note(expr, line):
            cid = _chain(expr)
            if cid is not None:
                out.append((self.canon(cid), line))

        for n in self.nodes:
            if isinstance(n, ast.Assign):
                for t in n.targets:
                    for el in ast.walk(t):
                        if isinstance(el, ast.Subscript):
                            note(el.value, n.lineno)
            elif isinstance(n, ast.AugAssign):
                t = n.target
                note(t.value if isinstance(t, ast.Subscript) else t,
                     n.lineno)
            elif isinstance(n, ast.Call):
                if isinstance(n.func, ast.Attribute) and \
                        n.func.attr in MUTATOR_METHODS:
                    note(n.func.value, n.lineno)
                fc = _chain(n.func)
                if fc is not None and fc.split(".")[-1] == "copyto" \
                        and n.args:
                    note(n.args[0], n.lineno)
        return out

    def _rebinds(self) -> list:
        """Plain rebinds of a name/attribute to a FRESH value (`redo =
        np.zeros(B)` at the top of a retry loop): the old buffer is
        released, so later in-place mutations touch a new object and
        the loop-carried hazard does not apply. Assigns whose value is
        itself a name chain are aliases, not rebinds — buffer identity
        survives those."""
        out = []
        for n in self.stmts:
            if isinstance(n, ast.Assign) and _chain(n.value) is None:
                for t in n.targets:
                    tc = _chain(t)
                    if tc is not None:
                        out.append((tc, n.lineno))
        return out

    def _producers(self) -> dict:
        """name -> producer Assign stmt, for `x = next(...)` inside a
        loop, propagated through comprehension targets iterating the
        tainted dict (`for k, v in batch.items()`)."""
        tainted: dict = {}
        for n in self.stmts:
            if isinstance(n, ast.Assign) and len(n.targets) == 1 and \
                    isinstance(n.targets[0], ast.Name) and \
                    isinstance(n.value, ast.Call) and \
                    isinstance(n.value.func, ast.Name) and \
                    n.value.func.id == "next" and \
                    self._enclosing_loop(n) is not None:
                tainted[n.targets[0].id] = n
        for n in self.nodes:
            if isinstance(n, (ast.ListComp, ast.SetComp, ast.DictComp,
                              ast.GeneratorExp)):
                for gen in n.generators:
                    src = None
                    it = gen.iter
                    if isinstance(it, ast.Call) and \
                            isinstance(it.func, ast.Attribute) and \
                            it.func.attr in ("items", "values"):
                        src = _chain(it.func.value)
                    elif isinstance(it, ast.Name):
                        src = it.id
                    if src in tainted:
                        for el in ast.walk(gen.target):
                            if isinstance(el, ast.Name):
                                tainted[el.id] = tainted[src]
        return tainted

    def _inflight(self) -> set:
        if isinstance(self.scope, ast.Module):
            lo, hi = 1, len(self.sf.text.splitlines()) + 1
        else:
            lo, hi = self.scope.lineno, self.scope.end_lineno
        names = set()
        for a in self.sf.directives.annotations:
            if a.kind == "mutated-inflight" and lo <= a.line <= hi:
                names.update(a.names)
        return names

    # ----------------------------- queries ----------------------------

    def _enclosing_loop(self, node):
        best = None
        for lp in self.loops:
            if lp.lineno <= node.lineno and \
                    node.lineno <= (lp.end_lineno or lp.lineno):
                if best is None or lp.lineno > best.lineno:
                    best = lp
        return best

    def stmt_of(self, node):
        """Innermost SIMPLE statement containing `node` — compound
        statements (if/for/with/try) are excluded so a `dispatch`
        annotation inside one branch cannot leak onto calls in the
        header test or sibling branches."""
        best = None
        for st in self.stmts:
            if isinstance(st, (ast.If, ast.For, ast.AsyncFor, ast.While,
                               ast.With, ast.AsyncWith, ast.Try)):
                continue
            if st.lineno <= node.lineno <= (st.end_lineno or st.lineno):
                if best is None or st.lineno >= best.lineno:
                    best = st
        return best

    def has_annotation(self, kind: str, stmt) -> bool:
        if stmt is None:
            return False
        return bool(self.sf.directives.annotations_on(
            kind, stmt.lineno - 1, stmt.end_lineno or stmt.lineno))


def _dispatch_sites(sc: _Scope):
    """Yield (call node, [arg exprs to check]) for asarray-family calls
    and for every call inside a `# reprolint: dispatch` statement."""
    seen = set()
    for n in sc.nodes:
        if not isinstance(n, ast.Call):
            continue
        fc = _chain(n.func)
        if fc in ASARRAY_FNS and n.args:
            seen.add(id(n))
            yield n, [n.args[0]]
    for n in sc.nodes:
        if not isinstance(n, ast.Call) or id(n) in seen:
            continue
        fc = _chain(n.func)
        if fc is not None and (fc in ASARRAY_FNS or
                               fc.split(".")[-1] == "copy"):
            continue
        stmt = sc.stmt_of(n)
        if sc.has_annotation("dispatch", stmt):
            args = list(n.args) + [kw.value for kw in n.keywords]
            yield n, args


def _check_scope(sc: _Scope, findings: list) -> None:
    emitted = set()

    def emit(node, cid, message, hint):
        key = (node.lineno, cid, message[:40])
        if key in emitted:
            return
        emitted.add(key)
        findings.append(Finding(
            rule="RL001", name="alias-race", path=sc.sf.rel,
            line=node.lineno, message=message, hint=hint))

    for call, args in _dispatch_sites(sc):
        for arg in args:
            if isinstance(arg, ast.Call) and \
                    isinstance(arg.func, ast.Attribute) and \
                    arg.func.attr == "copy":
                continue                      # private copy: safe
            raw = _chain(arg)
            if raw is None:
                continue                      # fresh temporary: safe
            cid = sc.canon(raw)
            hint = (f"dispatch {raw}.copy() — jax keeps the private "
                    f"copy alive and nobody mutates it")
            if raw in sc.inflight or cid in sc.inflight:
                emit(call, cid,
                     f"'{raw}' is declared mutated-inflight for this "
                     f"function (another code path mutates it in place "
                     f"while this dispatch is in flight); {STORY}",
                     hint)
                continue
            later = [ln for mid, ln in sc.mutations
                     if mid == cid and ln > call.lineno]
            if later:
                emit(call, cid,
                     f"'{raw}' is dispatched here and mutated in place "
                     f"at line {min(later)}; {STORY}", hint)
                continue
            loop = sc._enclosing_loop(call)
            if loop is not None:
                carried = [ln for mid, ln in sc.mutations
                           if mid == cid and
                           loop.lineno <= ln <= (loop.end_lineno or ln)]
                fresh_each_iter = any(
                    rc in (raw, cid) and
                    loop.lineno <= ln <= (loop.end_lineno or ln)
                    for rc, ln in sc.rebinds)
                if carried and not fresh_each_iter:
                    emit(call, cid,
                         f"'{raw}' is dispatched inside a loop that "
                         f"mutates it in place (line {min(carried)}): "
                         f"iteration k+1 mutates what iteration k's "
                         f"async dispatch is still reading; {STORY}",
                         hint)
                    continue
            if isinstance(arg, ast.Name) and raw in sc.producers:
                prod = sc.producers[raw]
                if not (sc.has_annotation("fresh-batch", prod) or
                        sc.has_annotation("fresh-batch",
                                          sc.stmt_of(call))):
                    emit(call, cid,
                         f"'{raw}' comes from an opaque producer "
                         f"(`next(...)` at line {prod.lineno}, inside "
                         f"a loop) — a producer that reuses a staging "
                         f"buffer would mutate it under the in-flight "
                         f"dispatch; {STORY}",
                         f"dispatch {raw}.copy(), or annotate the "
                         f"producer statement with `# reprolint: "
                         f"fresh-batch <test enforcing the "
                         f"freshly-allocated-batch contract>`")


@rule("RL001", "alias-race")
def check(project):
    """host buffers mutated in place under an in-flight async dispatch
    (the PR 5 zero-copy aliasing bug class)"""
    findings: list = []
    for sf in project.files:
        for scope in _scopes(sf.tree):
            _check_scope(_Scope(sf, scope), findings)
    return findings
