"""RL005 kernel-parity: every Pallas kernel package ships its contract.

A `src/repro/kernels/<pkg>/` that dispatches `pallas_call` must carry:

  * `ops.py`  — the dispatch wrapper serving code imports (and the
    interpret-mode / sharding routing point);
  * `ref.py`  — the jnp reference implementation the kernel is held
    bit-exact against;
  * a parity test: some `tests/test_*.py` references
    `kernels.<pkg>` / `kernels/<pkg>` (the repo's convention since the
    masked_logits kernel — parity fuzz is what caught the S=1 gemv
    rounding split and the fused-select edge cases).

A kernel without a ref and a test is an unfalsifiable kernel; this
rule makes that state unrepresentable at HEAD.
"""
from __future__ import annotations

import ast
import re

from ..findings import Finding
from ..registry import rule

KERNELS_PREFIX = "src/repro/kernels/"


def _uses_pallas_call(tree) -> int:
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and node.id == "pallas_call":
            return node.lineno
        if isinstance(node, ast.Attribute) and \
                node.attr == "pallas_call":
            return node.lineno
    return 0


@rule("RL005", "kernel-parity")
def check(project):
    """every pallas_call kernel package ships ops.py + ref.py and is
    referenced by a parity test"""
    findings = []
    pkgs: dict = {}          # pkg -> {rel: (sf, pallas_line)}
    for sf in project.files:
        if not sf.rel.startswith(KERNELS_PREFIX):
            continue
        parts = sf.rel[len(KERNELS_PREFIX):].split("/")
        if len(parts) != 2:
            continue         # kernels/_compat.py etc.: not a package
        pkgs.setdefault(parts[0], {})[parts[1]] = (
            sf, _uses_pallas_call(sf.tree))
    test_texts = None
    for pkg, files in sorted(pkgs.items()):
        dispatching = [(rel, sf, ln) for rel, (sf, ln) in files.items()
                       if ln]
        if not dispatching:
            continue
        anchor_rel, _, anchor_line = dispatching[0]
        anchor = f"{KERNELS_PREFIX}{pkg}/{anchor_rel}"
        for required in ("ops.py", "ref.py"):
            if required not in files and \
                    project.read_text(
                        f"{KERNELS_PREFIX}{pkg}/{required}") is None:
                findings.append(Finding(
                    rule="RL005", name="kernel-parity", path=anchor,
                    line=anchor_line,
                    message=f"kernel package '{pkg}' dispatches "
                            f"pallas_call but ships no {required} — "
                            f"every kernel needs a dispatch wrapper "
                            f"(ops.py) and a jnp reference (ref.py) "
                            f"to be held bit-exact against",
                    hint="see kernels/masked_logits for the package "
                         "shape"))
        if test_texts is None:
            test_texts = [(rel, project.read_text(rel) or "")
                          for rel in project.glob("tests/test_*.py")]
        pat = re.compile(rf"kernels[./]{re.escape(pkg)}\b")
        if not any(pat.search(text) for _, text in test_texts):
            findings.append(Finding(
                rule="RL005", name="kernel-parity", path=anchor,
                line=anchor_line,
                message=f"kernel package '{pkg}' is referenced by no "
                        f"tests/test_*.py — an untested Pallas kernel "
                        f"has no parity guarantee",
                hint="add a bit-exactness fuzz vs ref.py (the "
                     "masked_logits/paged_attention test pattern)"))
    return findings
