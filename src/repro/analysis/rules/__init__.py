"""Rule modules register themselves on import; importing this package
is what populates the registry."""
from . import (alias_race, kernel_parity, obs_purity,       # noqa: F401
               span_hygiene, sync_confinement)
