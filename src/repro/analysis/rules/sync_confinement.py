"""RL003 sync-confinement: `block_until_ready` lives ONLY in
`serving/devbridge.py`.

devbridge is the single sanctioned module binding the device sync into
the observability layer as an injected capability (invoked only in
bench/profile mode; tests/test_devtime.py proves serving never calls
it). Any other identifier-level use of `block_until_ready` anywhere in
the scanned tree is a finding — a sync smuggled into serving would
serialize the XGrammar-style host/device overlap, and one hidden in a
library path is a latency cliff waiting for load.

Within `src/repro/serving/` the rule additionally bans the quieter
sync spellings `.item()` and `device_get` (the pre-reprolint
source-scan in tests/test_obs.py, mechanized).

AST/identifier matching, not regex: docstrings and comments may say
"block_until_ready" freely.
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..registry import rule

ALLOWED_FILE = "src/repro/serving/devbridge.py"
SERVING_PREFIX = "src/repro/serving/"


def _ident_uses(tree, ident: str):
    for node in ast.walk(tree):
        if isinstance(node, ast.Name) and node.id == ident:
            yield node.lineno
        elif isinstance(node, ast.Attribute) and node.attr == ident:
            yield node.lineno


@rule("RL003", "sync-confinement")
def check(project):
    """block_until_ready only in serving/devbridge.py; no .item() /
    device_get syncs inside the serving package"""
    findings = []
    for sf in project.files:
        if sf.rel == ALLOWED_FILE:
            continue
        for line in _ident_uses(sf.tree, "block_until_ready"):
            findings.append(Finding(
                rule="RL003", name="sync-confinement", path=sf.rel,
                line=line,
                message="block_until_ready outside "
                        "serving/devbridge.py: the device sync is an "
                        "injected capability confined to the bridge so "
                        "no serving or library path can silently "
                        "serialize the host/device overlap",
                hint="route the sync through the obs devtime bridge, "
                     "or justify a deliberate timing bracket with a "
                     "suppression"))
        if sf.rel.startswith(SERVING_PREFIX):
            for line in _ident_uses(sf.tree, "device_get"):
                findings.append(Finding(
                    rule="RL003", name="sync-confinement", path=sf.rel,
                    line=line,
                    message="device_get in the serving package: a "
                            "host transfer is a device sync",
                    hint="only [B]-sized resolved ids may cross to "
                         "the host, via the step loop's resolve phase"))
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "item" and not node.args:
                    findings.append(Finding(
                        rule="RL003", name="sync-confinement",
                        path=sf.rel, line=node.lineno,
                        message=".item() in the serving package "
                                "blocks on the device value — a "
                                "hidden per-token sync",
                        hint="batch the transfer (np.asarray at the "
                             "resolve phase) instead of scalarizing"))
    return findings
