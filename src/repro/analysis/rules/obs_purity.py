"""RL002 obs-purity: `src/repro/obs/` must not import jax or numpy,
transitively.

The observability layer's no-added-syncs guarantee is structural: a
package that cannot even import the array libraries cannot block on a
device value. Two checks:

  * DIRECT — no obs file imports jax/numpy anywhere, including inside
    functions (a lazy import is one refactor away from the hot path);
  * TRANSITIVE — no module reachable from obs over MODULE-LEVEL
    repro-internal imports has a module-level jax/numpy import (a
    fresh interpreter importing `repro.obs` must leave sys.modules
    clean). The one sanctioned jax touchpoint is
    `serving/devbridge.py`, which injects sync/profiler callables
    INTO obs — the dependency arrow points the safe way.
"""
from __future__ import annotations

import ast

from ..findings import Finding
from ..registry import rule

BANNED = ("jax", "numpy")
OBS_PREFIX = "src/repro/obs/"
OBS_MODULE = "repro.obs"


def _banned_imports(tree):
    """(lineno, top-level name) for every jax/numpy import anywhere in
    the file (function bodies included)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] in BANNED:
                    yield node.lineno, a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom):
            if node.level == 0 and node.module and \
                    node.module.split(".")[0] in BANNED:
                yield node.lineno, node.module.split(".")[0]


@rule("RL002", "obs-purity")
def check(project):
    """repro.obs must not import jax/numpy, transitively — telemetry
    can then never add a device sync"""
    findings = []
    obs_files = [sf for sf in project.files
                 if sf.rel.startswith(OBS_PREFIX)]

    # ---- direct imports, any scope --------------------------------
    for sf in obs_files:
        for line, name in _banned_imports(sf.tree):
            findings.append(Finding(
                rule="RL002", name="obs-purity", path=sf.rel, line=line,
                message=f"repro.obs imports {name}: the telemetry layer "
                        f"must stay import-pure so it can never add a "
                        f"device sync (docs/observability.md overhead "
                        f"contract)",
                hint="inject device capabilities through "
                     "serving/devbridge.py instead of importing the "
                     "array library"))

    # ---- transitive closure over module-level imports -------------
    edges = project.import_edges()
    # module-level banned imports per project module
    mod_banned = {}
    for sf in project.files:
        if sf.module:
            hit = [(t, ln) for t, ln in edges.get(sf.module, ())
                   if t in BANNED]
            if hit:
                mod_banned[sf.module] = hit
    for sf in obs_files:
        if not sf.module or not sf.module.startswith(OBS_MODULE):
            continue
        # BFS recording the chain for the finding's story
        chain = {sf.module: None}
        frontier = [sf.module]
        while frontier:
            nxt = []
            for m in frontier:
                for t, ln in sorted(edges.get(m, ())):
                    if t in BANNED:
                        if m == sf.module:
                            continue    # direct: reported above
                        path_back = []
                        cur = m
                        while cur is not None:
                            path_back.append(cur)
                            cur = chain[cur]
                        via = " -> ".join(reversed(path_back))
                        first_ln = _first_edge_line(edges, sf.module,
                                                    path_back[-2]
                                                    if len(path_back) > 1
                                                    else m)
                        findings.append(Finding(
                            rule="RL002", name="obs-purity",
                            path=sf.rel, line=first_ln,
                            message=f"{sf.module} transitively imports "
                                    f"{t} via {via} -> {t}: importing "
                                    f"repro.obs must not pull the "
                                    f"array libraries into "
                                    f"sys.modules",
                            hint="break the edge or make the heavy "
                                 "import function-local in the "
                                 "intermediate module"))
                    elif t.startswith("repro.") and t not in chain:
                        chain[t] = m
                        nxt.append(t)
            frontier = nxt
    return findings


def _first_edge_line(edges, src_module, towards):
    for t, ln in edges.get(src_module, ()):
        if t == towards:
            return ln
    return 1
