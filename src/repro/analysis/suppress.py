"""reprolint comment directives: suppressions and rule annotations.

One grammar, parsed from `tokenize` comment tokens (so strings and
docstrings can never fake a directive):

    # reprolint: disable=RL001[,RL003] <justification>
    # reprolint: fresh-batch <justification>
    # reprolint: dispatch [note]
    # reprolint: mutated-inflight=name1,name2 [note]

* `disable` suppresses findings of the listed rules anchored on the
  same line or the immediately following line (put the comment on the
  offending line, or alone on the line above a multi-line statement).
  The justification is MANDATORY and must carry at least two words —
  an unjustified or stale (never-matching) suppression is itself a
  finding (RL000), so the tree cannot quietly accrete waivers.
* `fresh-batch` declares the producer contract RL001 understands: the
  annotated `x = next(producer)` statement's producer returns freshly
  allocated arrays every call (never a reused staging buffer), so its
  batches may ship through `jnp.asarray` uncopied. Justification
  mandatory — name the test that enforces the contract.
* `dispatch` marks a statement as an async device dispatch whose
  direct numpy arguments RL001 must check (jitted calls taking numpy
  args without a jnp.asarray wrapper are invisible otherwise).
* `mutated-inflight` declares, for the enclosing function, buffer
  names (dotted chains allowed: `loop.greedy`) that some OTHER code
  path mutates in place while this function's dispatches are in
  flight — RL001 then requires a `.copy()` on every dispatch of them,
  with no intra-function mutation evidence needed.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field

DIRECTIVE_RE = re.compile(r"#\s*reprolint:\s*(.*)$")
RULE_ID_RE = re.compile(r"^RL\d{3}$")
MIN_JUSTIFICATION_WORDS = 2


@dataclass
class Disable:
    line: int
    rules: tuple
    justification: str
    used: set = field(default_factory=set)   # rule ids that matched


@dataclass
class Annotation:
    line: int
    kind: str           # "fresh-batch" | "dispatch" | "mutated-inflight"
    names: tuple = ()   # mutated-inflight buffer chains
    note: str = ""


@dataclass
class Directives:
    disables: list = field(default_factory=list)
    annotations: list = field(default_factory=list)
    errors: list = field(default_factory=list)   # (line, message)

    def disable_for(self, rule: str, line: int):
        """Suppression covering a finding of `rule` at `line`: same
        line, or a directive on the line directly above."""
        for d in self.disables:
            if rule in d.rules and line in (d.line, d.line + 1):
                return d
        return None

    def annotations_on(self, kind: str, lo: int, hi: int) -> list:
        """Annotations of `kind` attached to any line in [lo, hi] —
        statement attachment for fresh-batch/dispatch."""
        return [a for a in self.annotations
                if a.kind == kind and lo <= a.line <= hi + 1]


def _parse_one(line: int, body: str, out: Directives) -> None:
    head, _, rest = body.strip().partition(" ")
    rest = rest.strip()
    if head.startswith("disable="):
        rules = tuple(r.strip() for r in head[len("disable="):].split(",")
                      if r.strip())
        bad = [r for r in rules if not RULE_ID_RE.match(r) or r == "RL000"]
        if not rules or bad:
            out.errors.append((line, f"disable lists no valid rule ids "
                                     f"(got {rules or '(none)'})"))
            return
        if len(rest.split()) < MIN_JUSTIFICATION_WORDS:
            out.errors.append(
                (line, f"unjustified suppression of {','.join(rules)} — "
                       f"say WHY the invariant holds here "
                       f"(>= {MIN_JUSTIFICATION_WORDS} words)"))
            return
        out.disables.append(Disable(line, rules, rest))
    elif head == "fresh-batch":
        if len(rest.split()) < MIN_JUSTIFICATION_WORDS:
            out.errors.append(
                (line, "fresh-batch waives RL001 for an opaque producer "
                       "— justify it (name the test enforcing the "
                       "freshly-allocated-batch contract)"))
            return
        out.annotations.append(Annotation(line, "fresh-batch", note=rest))
    elif head == "dispatch":
        out.annotations.append(Annotation(line, "dispatch", note=rest))
    elif head.startswith("mutated-inflight="):
        names = tuple(n.strip()
                      for n in head[len("mutated-inflight="):].split(",")
                      if n.strip())
        if not names:
            out.errors.append((line, "mutated-inflight lists no buffer "
                                     "names"))
            return
        out.annotations.append(Annotation(line, "mutated-inflight",
                                          names=names, note=rest))
    else:
        out.errors.append((line, f"unknown reprolint directive "
                                 f"{head!r} (disable= / fresh-batch / "
                                 f"dispatch / mutated-inflight=)"))


def parse_directives(source: str) -> Directives:
    out = Directives()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = DIRECTIVE_RE.search(tok.string)
            if m:
                _parse_one(tok.start[0], m.group(1), out)
    except tokenize.TokenError:
        pass    # the ast parse reports the syntax error with context
    return out
