import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: AOT lower + compile every (architecture x input
shape) on the production mesh, record memory/cost/collective stats.

The two lines above MUST precede any jax import: the dry-run builds a
16x16 (and 2x16x16) mesh out of 512 host placeholder devices. Run as its
own process (`python -m repro.launch.dryrun ...`); tests and benches see
the single real CPU device.

Usage:
  python -m repro.launch.dryrun --arch mamba2-370m --shape train_4k
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all            # every combo, subprocesses
  python -m repro.launch.dryrun --all --multi-pod
Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json
"""
import argparse
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.distributed.api import use_sharding
from repro.distributed.hlo_stats import collective_stats
from repro.distributed.sharding import (activation_rules, batch_shardings,
                                        cache_shardings, opt_state_shardings,
                                        params_shardings)
from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.shapes import (SHAPES, applicable, input_specs,
                                 variant_for_shape)
from repro.models.model import build_model

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def count_params(abstract_params, cfg):
    """(total_params, active_params) — active discounts expert weights by
    top-k/E (MoE forward touches only routed experts)."""
    total = 0
    active = 0.0
    flat = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    for path, leaf in flat:
        ps = jax.tree_util.keystr(path)
        n = int(np.prod(leaf.shape))
        total += n
        if "['moe']" in ps and any(
                f"['{w}']" in ps for w in ("w_gate", "w_up", "w_down")):
            active += n * cfg.experts_per_token / max(cfg.num_experts, 1)
        else:
            active += n
    return total, int(active)


def _jit_target(model, mode, specs, mesh, microbatch: int = 1):
    """-> (jitted fn, ordered abstract args)."""
    from repro.distributed.sharding import needs_fsdp
    cfg = model.cfg
    fsdp = needs_fsdp(specs["params"], mesh)
    p_sh = params_shardings(specs["params"], mesh, fsdp=fsdp)
    if mode == "train":
        from repro.training.optimizer import AdamWConfig, apply_updates

        def train_step(params, opt_state, batch):
            if microbatch > 1:
                # gradient accumulation: scan over microbatches; the
                # remat residual stack shrinks by the microbatch factor
                # (the activation-memory lever — EXPERIMENTS.md §Perf)
                def micro(carry, mb):
                    acc, lsum = carry
                    (loss, _), grads = jax.value_and_grad(
                        model.loss, has_aux=True)(params, mb)
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(jnp.float32) / microbatch,
                        acc, grads)
                    return (acc, lsum + loss / microbatch), None

                mbs = jax.tree.map(
                    lambda x: x.reshape(microbatch,
                                        x.shape[0] // microbatch,
                                        *x.shape[1:]),
                    batch)
                # grad accumulator: ZeRO-sharded like the Adam moments
                # (unconstrained, GSPMD replicated it across data -> OOM)
                mu_sh = o_sh["mu"]
                acc0 = jax.tree.map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        jnp.zeros(p.shape, jnp.float32), s),
                    params, mu_sh)
                (grads, loss), _ = jax.lax.scan(
                    micro, (acc0, jnp.zeros((), jnp.float32)), mbs)
            else:
                (loss, _), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch)
            params2, opt_state2, om = apply_updates(
                AdamWConfig(), params, grads, opt_state,
                update_shardings=o_sh["mu"], param_shardings=p_sh)
            return params2, opt_state2, loss

        o_sh = opt_state_shardings(specs["opt_state"], mesh)
        b_sh = batch_shardings(specs["batch"], mesh)
        # donation here shapes the MEMORY ANALYSIS only: args are
        # ShapeDtypeStructs (AOT lower/compile, never executed), so no
        # host buffer exists to alias — unlike the serving dispatch
        # sites, which must .copy() (serving/loop.py)
        fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                     donate_argnums=(0, 1))
        args = (specs["params"], specs["opt_state"], specs["batch"])
        return fn, args
    if mode == "prefill":
        b_sh = batch_shardings(specs["batch"], mesh)

        def prefill(params, batch):
            return model.prefill(params, batch)

        fn = jax.jit(prefill, in_shardings=(p_sh, b_sh))
        return fn, (specs["params"], specs["batch"])
    if mode == "decode":
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.sharding import _div
        from repro.kernels.masked_logits.ref import masked_logits_ref
        c_sh = cache_shardings(specs["caches"], mesh, cfg)
        B = specs["token"].shape[0]
        t_sh = batch_shardings({"t": specs["token"]}, mesh)["t"]
        # mask store sharded over the packed-word (vocab) dim on `model`,
        # aligned with vocab-sharded logits (DESIGN.md §3 — beyond-paper:
        # the union + apply is then fully local)
        W = specs["mask_store"].shape[1]
        mp_w = "model" if _div(W, mesh, "model") else None
        s_sh = NamedSharding(mesh, P(None, mp_w))

        def serve_step(params, caches, token, pos, mask_store, mask_rows,
                       mask_cd, eos_allowed):
            logits, caches = model.decode_step(params, caches, token, pos)
            masked = masked_logits_ref(logits, mask_store, mask_rows,
                                       eos_allowed, cd=mask_cd)
            nxt = jnp.argmax(masked, axis=-1).astype(jnp.int32)
            return nxt, masked, caches

        fn = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, t_sh, t_sh, s_sh, t_sh,
                                   s_sh, t_sh),
                     donate_argnums=(1,))
        return fn, (specs["params"], specs["caches"], specs["token"],
                    specs["pos"], specs["mask_store"], specs["mask_rows"],
                    specs["mask_cd"], specs["eos_allowed"])
    raise ValueError(mode)


# gradient-accumulation factor per arch for train_4k (keeps the remat
# residual stack within HBM; chosen via the §Perf iteration log)
DEFAULT_MICROBATCH = {
    "internlm2-1.8b": 2,
    "qwen1.5-0.5b": 2,
    "smollm-360m": 2,
    "mamba2-370m": 2,
    "deepseek-coder-33b": 16,
    "recurrentgemma-9b": 4,
    "kimi-k2-1t-a32b": 16,
    "llama-3.2-vision-90b": 16,
    "qwen3-moe-30b-a3b": 16,
    "whisper-base": 2,
}


def run_one(arch: str, shape: str, multi_pod: bool = False,
            save: bool = True, verbose: bool = True,
            microbatch: int | None = None,
            seq_parallel: bool = False) -> dict:
    ok, why = applicable(get_config(arch), shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "skipped": why}
        if save:
            _save(rec)
        return rec

    cfg = variant_for_shape(get_config(arch), shape)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    mode, specs = input_specs(model, shape)
    info = SHAPES[shape]
    rules = activation_rules(mesh, cfg, info["global_batch"],
                             seq_parallel=seq_parallel)
    if microbatch is None:
        microbatch = DEFAULT_MICROBATCH.get(arch, 1) if mode == "train" else 1

    t0 = time.time()
    with use_sharding(mesh, rules):
        fn, args = _jit_target(model, mode, specs, mesh, microbatch)
        lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    # XLA's cost_analysis counts while-loop bodies once (layer scans!), so
    # roofline terms come from our trip-count-aware HLO analyzer.
    from repro.distributed.hlo_cost import roofline_counts
    hlo_text = compiled.as_text()
    rc = roofline_counts(hlo_text)
    flops_dev = float(rc["flops"])
    bytes_dev = float(rc["hbm_bytes"])
    coll = rc["collectives"]
    coll["total_wire_bytes"] = rc["wire_bytes"]
    wire_dev = float(rc["wire_bytes"])
    xla_cost = {"flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0))}

    total_p, active_p = count_params(specs["params"], cfg)
    if mode == "train":
        tokens = info["global_batch"] * info["seq_len"]
        model_flops = 6.0 * active_p * tokens
    elif mode == "prefill":
        tokens = info["global_batch"] * info["seq_len"]
        model_flops = 2.0 * active_p * tokens
    else:
        tokens = info["global_batch"]
        model_flops = 2.0 * active_p * tokens

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)

    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        if hasattr(mem, f):
            mem_fields[f] = int(getattr(mem, f))
    # the CPU backend widens bf16 while-loop state to f32 (wrapped_convert
    # fusions); the TPU backend keeps bf16 — correct the estimate and
    # report both (methodology: EXPERIMENTS.md §Dry-run)
    from repro.distributed.hlo_cost import bf16_widening_correction
    widen = bf16_widening_correction(hlo_text)
    mem_fields["cpu_bf16_widening_bytes_removed"] = int(widen)
    peak_bytes = mem_fields.get("temp_size_in_bytes", 0) + \
        mem_fields.get("argument_size_in_bytes", 0) - widen

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "mode": mode,
        "chips": chips, "microbatch": microbatch,
        "seq_parallel": seq_parallel,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "hbm_bytes_per_device": bytes_dev,
        "wire_bytes_per_device": wire_dev,
        "collectives": coll,
        "xla_cost_analysis": xla_cost,
        "memory": mem_fields,
        "fits_hbm": bool(peak_bytes <= HBM_BYTES),
        "hbm_utilization": peak_bytes / HBM_BYTES,
        "params_total": total_p,
        "params_active": active_p,
        "model_flops_global": model_flops,
        "hlo_flops_global": flops_dev * chips,
        "useful_flops_ratio":
            model_flops / max(flops_dev * chips, 1.0),
        "roofline": {**{k: float(v) for k, v in terms.items()},
                     "bottleneck": bottleneck},
    }
    if verbose:
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "mode", "compile_s",
                           "fits_hbm", "hbm_utilization",
                           "useful_flops_ratio")}, indent=None))
        print("  roofline:", {k: f"{v:.3e}" for k, v in terms.items()},
              "->", bottleneck)
    if save:
        _save(rec)
    return rec


def _save(rec):
    os.makedirs(ART_DIR, exist_ok=True)
    fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(ART_DIR, fn), "w") as f:
        json.dump(rec, f, indent=1)


def run_all(multi_pod: bool, archs=None, shapes=None, timeout: int = 3600):
    """Each combo in its own subprocess (isolates compile memory)."""
    archs = archs or ARCH_IDS
    shapes = shapes or list(SHAPES)
    failures = []
    for arch in archs:
        for shape in shapes:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if multi_pod:
                cmd.append("--multi-pod")
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=timeout,
                               env={**os.environ,
                                    "PYTHONPATH": os.environ.get(
                                        "PYTHONPATH", "src")})
            status = "ok" if r.returncode == 0 else "FAIL"
            print(f"[{status}] {arch} x {shape} "
                  f"({time.time() - t0:.0f}s)")
            if r.returncode != 0:
                failures.append((arch, shape, r.stderr[-2000:]))
    for arch, shape, err in failures:
        print(f"\n=== FAILURE {arch} x {shape} ===\n{err}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--seq-parallel", action="store_true")
    args = ap.parse_args()
    if args.all:
        archs = [args.arch] if args.arch else None
        shapes = [args.shape] if args.shape else None
        failures = run_all(args.multi_pod, archs, shapes)
        sys.exit(1 if failures else 0)
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    run_one(args.arch, args.shape, args.multi_pod,
            microbatch=args.microbatch, seq_parallel=args.seq_parallel)


if __name__ == "__main__":
    main()
