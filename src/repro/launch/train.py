"""Distributed training launcher.

On the production mesh this runs the same jitted train_step the dry-run
lowers; on this CPU container it trains the small demo/reduced configs
for real (examples/train_grammar_lm.py drives it end-to-end).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch syncode-demo \
      --grammar json --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.grammars import load_grammar
from repro.core.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.training.data import GrammarDataPipeline, RandomTokenPipeline
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="syncode-demo")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test reduced variant")
    ap.add_argument("--grammar", default="json",
                    help="grammar for the synthetic data pipeline, or "
                         "'random' for random tokens")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(l.size for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"vocab={cfg.vocab_size}")

    if args.grammar == "random":
        data = iter(RandomTokenPipeline(cfg, args.seq, args.batch,
                                        seed=args.seed))
    else:
        tok = ByteTokenizer(cfg.vocab_size)
        g, _ = load_grammar(args.grammar)
        data = iter(GrammarDataPipeline(g, tok, args.seq, args.batch,
                                        seed=args.seed))

    opt = AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                      total_steps=args.steps)
    params, result = train(model, params, data, args.steps, opt_cfg=opt,
                           checkpoint_path=args.checkpoint)
    print(f"final loss {result.losses[-1]:.4f} "
          f"({result.steps_per_sec:.2f} steps/s)")
    return params, result


if __name__ == "__main__":
    main()
