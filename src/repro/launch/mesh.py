"""Production mesh definition (assignment: 16x16 single pod = 256 chips,
2x16x16 multi-pod = 512 chips). A function, not a module-level constant,
so importing never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (tests / CPU demos)."""
    n = jax.device_count()
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def make_serving_mesh(model_parallel: int = 1):
    """Serving-engine mesh: a pure "model" axis of `model_parallel`
    devices (data axis 1 — the engine's continuous-batching pool IS the
    batch dim and stays host-driven). Works on real accelerators and on
    forced host devices alike (CPU CI runs under
    XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    m = int(model_parallel)
    if m < 1:
        raise ValueError(f"model_parallel must be >= 1, got {m}")
    if m > jax.device_count():
        raise ValueError(
            f"serving mesh wants {m} devices but only "
            f"{jax.device_count()} exist (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={m} for CPU runs)")
    return jax.make_mesh((1, m), ("data", "model"))


# TPU v5e hardware constants (roofline targets; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per direction)
HBM_BYTES = 16e9                # v5e HBM capacity per chip
