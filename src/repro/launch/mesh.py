"""Production mesh definition (assignment: 16x16 single pod = 256 chips,
2x16x16 multi-pod = 512 chips). A function, not a module-level constant,
so importing never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Mesh over whatever devices exist (tests / CPU demos)."""
    n = jax.device_count()
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


# TPU v5e hardware constants (roofline targets; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per direction)
HBM_BYTES = 16e9                # v5e HBM capacity per chip
