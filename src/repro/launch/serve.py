"""Serving launcher: grammar-constrained generation with the Engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --grammar json -n 4 \
      --max-new 80 --temperature 0.8 --slots 4 \
      [--grammar-mode grammar_mask|grammar_strict] \
      [--sequential] [--opportunistic] [--checkpoint ckpt] \
      [--speculative] [--literal-jump] [--draft-k K] [--max-jump J]

`--slots B` sets the width of the continuous-batching decode pool (one
[B, V] decode + one fused mask call per step); `--sequential` uses the
round-robin one-request-per-device-call baseline instead.

`--speculative` enables grammar-aware speculative decoding (jump-forward
forced continuations + draft-verify spans; see docs/speculation.md);
`--literal-jump` additionally jumps grammar-forced byte literals,
re-tokenized canonically (longer jumps, byte-identical grammar
guarantees, token stream may differ from the plain engine's).

`--serve` starts the persistent streaming HTTP endpoint instead of a
batch run (docs/serving.md): one background step loop with live
admission, per-token NDJSON streaming, cancellation on disconnect and
per-request deadlines:

  python -m repro.launch.serve --serve --port 8400 --grammar json
  curl -N -d '{"prompt": "say:", "grammar": "json"}' \
      http://127.0.0.1:8400/generate

`--no-overlap` disables the host/device overlap in the dense decode
loop (on by default; serving/loop.py).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.core.decoding import DecodeConfig
from repro.core.grammars import BUILTIN, load_grammar
from repro.core.mask_store import build_mask_store
from repro.core.parser import IncrementalParser
from repro.core.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def build_engine(arch="syncode-demo", grammars=BUILTIN, vocab=None,
                 max_len=512, opportunistic=False, checkpoint=None,
                 seed=0, slots=4, paged=False, page_size=16,
                 num_pages=None, prefill_chunk=32, mesh=None,
                 trunk_shard=False, overlap=True,
                 grammar_mode="grammar_mask", telemetry=True,
                 devtime=False):
    """mesh: None | int (model-parallel degree; 1 = single device) | a
    prebuilt jax Mesh with a "model" axis. See docs/sharding.md."""
    cfg = get_config(arch)
    if vocab:
        from dataclasses import replace
        cfg = replace(cfg, vocab_size=vocab)
    tok = ByteTokenizer(cfg.vocab_size)
    bundles = {}
    for name in grammars:
        g, tab = load_grammar(name)
        bundles[name] = (g, tab, build_mask_store(g, tok))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    if checkpoint:
        from repro.training.checkpoint import load_checkpoint
        params, step, _ = load_checkpoint(checkpoint, params)
        print(f"loaded checkpoint at step {step}")
    if isinstance(mesh, int):
        # mesh=1 builds a real single-device mesh (exercises the whole
        # sharded code path; benchmarks use it to price the machinery)
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(mesh)
    return Engine(model, params, tok, bundles, max_len=max_len,
                  opportunistic=opportunistic, slots=slots, paged=paged,
                  page_size=page_size, num_pages=num_pages,
                  prefill_chunk=prefill_chunk, mesh=mesh,
                  trunk_shard=trunk_shard, overlap=overlap,
                  grammar_mode=grammar_mode, telemetry=telemetry,
                  devtime=devtime), bundles, tok


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="syncode-demo")
    ap.add_argument("--grammar", default="json", choices=list(BUILTIN))
    ap.add_argument("--grammar-mode", default="grammar_mask",
                    choices=("grammar_mask", "grammar_strict"),
                    help="mask approximation family (docs/grammars.md): "
                         "grammar_mask over-approximates (never bans a "
                         "valid token); grammar_strict under-approximates "
                         "(only tokens ending exactly on terminal "
                         "boundaries or inside one terminal)")
    ap.add_argument("-n", "--num-requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=80)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--greedy", action="store_true")
    ap.add_argument("--opportunistic", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--prompt", default="Q: produce output. A:")
    ap.add_argument("-B", "--slots", type=int, default=4,
                    help="continuous-batching decode pool width")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: page-table attention, "
                         "refcounted prefix sharing, chunked prefill "
                         "(docs/kv_paging.md)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged mode)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="KV pool size in pages (default: the dense "
                         "engine's memory budget, slots*max_len/page)")
    ap.add_argument("--mesh", type=int, default=None,
                    help="tensor-parallel mesh size: shard embed/lm_head,"
                         " logits, the packed mask store and the mask/"
                         "sample hot path across N devices (vocab "
                         "parallelism, token-for-token identical to "
                         "single-device; docs/sharding.md). CPU runs "
                         "need XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--trunk-shard", action="store_true",
                    help="with --mesh: additionally shard the model "
                         "trunk megatron-style (memory relief at TPU "
                         "scale; gives up bit-exact equivalence)")
    ap.add_argument("--sequential", action="store_true",
                    help="round-robin baseline (one request per call)")
    ap.add_argument("--speculative", action="store_true",
                    help="grammar-aware speculative decoding "
                         "(jump-forward + draft-verify)")
    ap.add_argument("--literal-jump", action="store_true",
                    help="jump grammar-forced byte literals, canonically "
                         "re-tokenized (longer jumps)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens per slot per speculative step")
    ap.add_argument("--max-jump", type=int, default=16,
                    help="max forced tokens committed per jump")
    ap.add_argument("--proposer", default="sam", choices=("sam", "ngram"),
                    help="draft proposer (suffix automaton | n-gram)")
    ap.add_argument("--serve", action="store_true",
                    help="start the persistent streaming HTTP endpoint "
                         "(POST /generate NDJSON stream, GET /healthz; "
                         "docs/serving.md) instead of a batch run")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8400)
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable host/device overlap in the dense "
                         "decode loop (serving/loop.py)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable the observability layer (phase spans, "
                         "latency histograms, trace capture; "
                         "docs/observability.md) — count stats stay "
                         "exact, timing stats read 0")
    ap.add_argument("--devtime", action="store_true",
                    help="bench/profile mode: device-span brackets sync "
                         "on exit so stats carry true device intervals "
                         "(adds per-step syncs — not for serving; "
                         "docs/observability.md)")
    args = ap.parse_args(argv)

    engine, bundles, tok = build_engine(
        args.arch, grammars=(args.grammar,),
        opportunistic=args.opportunistic, checkpoint=args.checkpoint,
        slots=args.slots, paged=args.paged, page_size=args.page_size,
        num_pages=args.num_pages, mesh=args.mesh,
        trunk_shard=args.trunk_shard, overlap=not args.no_overlap,
        grammar_mode=args.grammar_mode, telemetry=not args.no_telemetry,
        devtime=args.devtime)

    if args.serve:
        import asyncio

        from repro.serving.async_engine import AsyncEngine
        from repro.serving.server import run_server
        spec = None
        if args.speculative:
            from repro.spec import SpecConfig
            spec = SpecConfig(literal_jump=args.literal_jump,
                              draft_k=args.draft_k, max_jump=args.max_jump,
                              proposer=args.proposer)
        aeng = AsyncEngine(engine, spec=spec, verbose=True)
        try:
            asyncio.run(run_server(aeng, host=args.host, port=args.port))
        except KeyboardInterrupt:
            pass
        return

    dc = DecodeConfig(method="greedy" if args.greedy else "sample",
                      temperature=args.temperature)
    reqs = [Request(rid=i, prompt=args.prompt.encode(),
                    grammar=args.grammar, max_new_tokens=args.max_new,
                    decode=dc, seed=i) for i in range(args.num_requests)]
    if args.speculative:
        from repro.spec import SpecConfig
        spec = SpecConfig(literal_jump=args.literal_jump,
                          draft_k=args.draft_k, max_jump=args.max_jump,
                          proposer=args.proposer)
        states, stats = engine.generate_speculative(reqs, spec=spec,
                                                    verbose=True)
    else:
        run = (engine.generate_sequential if args.sequential
               else engine.generate)
        states, stats = run(reqs, verbose=True)

    g, tab, _ = bundles[args.grammar]
    p = IncrementalParser(g, tab)
    complete = [s for s in states if s.finish_reason == "eos"]
    valid = sum(p.recognize(s.generated) for s in complete)
    print(f"\n{stats.tokens} tokens @ {stats.tokens_per_sec:.1f} tok/s "
          f"({stats.decode_steps} decode steps x {stats.batch_slots} slots)"
          f" | mask {stats.mask_time:.2f}s/{stats.mask_computations} | "
          f"opportunistic hits {stats.opportunistic_hits}")
    if stats.mesh_devices > 1:
        print(f"tensor-parallel: {stats.mesh_devices}-device mesh "
              f"(vocab-sharded mask path"
              f"{', trunk sharded' if args.trunk_shard else ''})")
    if args.speculative:
        print(f"speculation: jump {stats.jump_tokens} tokens "
              f"({stats.jump_fraction:.0%} of output), drafts "
              f"{stats.draft_accepted}/{stats.draft_proposed} accepted "
              f"({stats.acceptance_rate:.0%}), plan {stats.plan_time:.2f}s")
    if args.paged:
        print(f"kv paging: {stats.kv_pages_in_use} pages in use, peak "
              f"util {stats.kv_peak_utilization:.0%}, prefix hit rate "
              f"{stats.prefix_hit_rate:.0%}, {stats.kv_evictions} "
              f"evictions, {stats.kv_cow_copies} COW copies")
    print(f"complete: {len(complete)}/{len(states)}, "
          f"valid among complete: {valid}/{len(complete)}")


if __name__ == "__main__":
    main()
