"""Assigned input shapes + per-(arch, shape) input ShapeDtypeStruct specs.

``input_specs(cfg, shape)`` returns abstract inputs (no allocation) for
the step function the shape exercises:
  * train_4k     -> train_step(params, opt_state, batch)
  * prefill_32k  -> prefill(params, batch)
  * decode_*     -> decode_step(params, caches, token, pos)

Applicability carve-outs (DESIGN.md §4):
  * long_500k needs bounded state: ssm/hybrid run natively; dense/moe/vlm
    run the sliding-window variant (window 8192); whisper is skipped.
  * whisper decode shapes drive the *decoder* serve_step; the conv/mel
    frontend is stubbed via precomputed frame embeddings.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, mode="decode"),
}

SLIDING_WINDOW_FOR_LONG = 8192


def applicable(cfg, shape: str) -> tuple[bool, str]:
    if shape == "long_500k":
        if cfg.arch_type == "audio":
            return False, ("whisper-base is full-attention enc-dec; no "
                           "faithful sub-quadratic variant (DESIGN.md §4)")
    return True, ""


def variant_for_shape(cfg, shape: str):
    """Config actually lowered for this shape."""
    if shape == "long_500k" and cfg.arch_type in ("dense", "moe", "vlm"):
        return replace(cfg, sliding_window=SLIDING_WINDOW_FOR_LONG)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg, seq_len: int, batch: int, with_labels: bool):
    b = {"tokens": _sds((batch, seq_len), jnp.int32)}
    if with_labels:
        b["labels"] = _sds((batch, seq_len), jnp.int32)
        b["loss_mask"] = _sds((batch, seq_len), jnp.float32)
    if cfg.arch_type == "vlm":
        b["image_embeds"] = _sds((batch, cfg.num_image_tokens, cfg.d_model),
                                 jnp.bfloat16)
    if cfg.arch_type == "audio":
        b["frames"] = _sds((batch, cfg.audio_frames, cfg.d_model),
                           jnp.bfloat16)
    return b


def input_specs(model, shape: str):
    """-> (mode, specs dict). specs keys depend on mode:
    train:   params, opt_state, batch
    prefill: params, batch
    decode:  params, caches, token, pos
    """
    cfg = model.cfg
    info = SHAPES[shape]
    S, B, mode = info["seq_len"], info["global_batch"], info["mode"]
    params = model.abstract_params()
    if mode == "train":
        from repro.training.optimizer import init_opt_state
        opt_state = jax.eval_shape(init_opt_state, params)
        return mode, {
            "params": params,
            "opt_state": opt_state,
            "batch": batch_specs(cfg, S, B, with_labels=True),
        }
    if mode == "prefill":
        return mode, {
            "params": params,
            "batch": batch_specs(cfg, S, B, with_labels=False),
        }
    if mode == "decode":
        caches = jax.eval_shape(
            lambda: model.init_decode_caches(B, S))
        # the serve step includes the paper's grammar mask: packed DFA
        # mask-store rows (uint32 bit-words over the vocab) + per-request
        # row ids from the host-side incremental parser
        words = (cfg.vocab_size + 31) // 32
        words = ((words + 15) // 16) * 16   # model-axis divisible
        return mode, {
            "params": params,
            "caches": caches,
            "token": _sds((B,), jnp.int32),
            "pos": _sds((B,), jnp.int32),
            "mask_store": _sds((MASK_STORE_ROWS, words), jnp.uint32),
            "mask_rows": _sds((B, MAX_ACCEPT), jnp.int32),
            "mask_cd": _sds((B, words), jnp.uint32),
            "eos_allowed": _sds((B,), jnp.bool_),
        }
    raise ValueError(mode)


# sized for the Python grammar scale the paper reports (|Γ|=94 terminals,
# a few thousand DFA states x (|Γ|+1) rows)
MASK_STORE_ROWS = 16384
MAX_ACCEPT = 48
