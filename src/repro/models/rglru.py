"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Gated linear recurrence h_t = a_t·h_{t-1} + sqrt(1-a_t²)·(i_t ⊙ x_t) with
a_t = exp(-c·softplus(Λ)·r_t). Training/prefill uses
`lax.associative_scan` (log-depth, TPU-friendly); decode is O(1).
The surrounding block is Griffin's: GeLU branch ⊙ (conv1d → RG-LRU),
then an output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys

_C = 8.0


def init_rglru(key, cfg, dtype):
    D = cfg.d_model
    R = cfg.lru_dim
    Kc = cfg.conv_kernel
    ks = split_keys(key, 6)
    return {
        "w_gelu": dense_init(ks[0], (D, R), dtype=dtype),
        "w_rec": dense_init(ks[1], (D, R), dtype=dtype),
        "conv_w": dense_init(ks[2], (Kc, R), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((R,), dtype),
        "w_a": dense_init(ks[3], (R, R), dtype=dtype),
        "b_a": jnp.zeros((R,), jnp.float32),
        "w_i": dense_init(ks[4], (R, R), dtype=dtype),
        "b_i": jnp.zeros((R,), jnp.float32),
        "lam": jnp.full((R,), 0.7, jnp.float32),     # Λ
        "w_out": dense_init(ks[5], (R, D), dtype=dtype),
    }


def _causal_conv(x, w, b):
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _gates(params, x):
    """x [.., R] -> (log_a, b_t) in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) +
                       params["b_a"])
    i = jax.nn.sigmoid(xf @ params["w_i"].astype(jnp.float32) +
                       params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (i * xf)
    return a, b


def rglru_train(params, x, cfg):
    y, _ = _rglru_forward(params, x, cfg, return_state=False)
    return y


def rglru_prefill(params, x, cfg):
    return _rglru_forward(params, x, cfg, return_state=True)


def _rglru_forward(params, x, cfg, return_state: bool):
    """x [B,S,D]."""
    u = jax.nn.gelu(x @ params["w_gelu"])
    v_raw = x @ params["w_rec"]
    v = _causal_conv(v_raw, params["conv_w"], params["conv_b"])
    a, b = _gates(params, v)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = hh                                           # [B,S,R] f32
    y = (u.astype(jnp.float32) * h).astype(x.dtype) @ params["w_out"]
    if not return_state:
        return y, None
    K = cfg.conv_kernel - 1
    S = x.shape[1]
    conv_cache = (v_raw[:, S - K:, :] if S >= K else
                  jnp.pad(v_raw, ((0, 0), (K - S, 0), (0, 0))))
    cache = {"h": h[:, -1, :], "conv": conv_cache.astype(x.dtype)}
    return y, cache


def init_rglru_cache(cfg, batch, dtype):
    R = cfg.lru_dim
    return {
        "h": jnp.zeros((batch, R), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, R), dtype),
    }


def rglru_decode(params, x, cache, cfg):
    """x [B,1,D] -> ([B,1,D], cache)."""
    u = jax.nn.gelu(x[:, 0] @ params["w_gelu"])
    v_raw = x[:, 0] @ params["w_rec"]
    hist = jnp.concatenate(
        [cache["conv"], v_raw[:, None, :].astype(cache["conv"].dtype)],
        axis=1)
    w = params["conv_w"]
    v = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                   w.astype(jnp.float32)) + params["conv_b"].astype(
        jnp.float32)
    a, b = _gates(params, v)
    h = a * cache["h"] + b
    y = ((u.astype(jnp.float32) * h).astype(x.dtype) @
         params["w_out"])[:, None, :]
    return y, {"h": h, "conv": hist[:, 1:]}
