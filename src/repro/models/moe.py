"""Top-k routed Mixture-of-Experts FFN (Kimi-K2 / Qwen3-MoE style).

Dispatch is capacity-based scatter/gather (sort-free): pair (token, slot)
positions within each expert come from a stable argsort over expert ids,
then tokens are scattered into an [E, C, D] buffer, expert FFNs run as
batched einsums over the expert dim, and outputs are gathered back and
gate-combined. With experts sharded over the `model` mesh axis and tokens
over `data`, GSPMD materializes the dispatch as all-to-all-style
collectives — exactly the paper-adjacent traffic the roofline tracks.

Aux outputs: load-balance loss (Switch-style f·P) and router z-loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys
from ..distributed.api import shard_hint


def init_moe(key, cfg, dtype):
    E, D, F = cfg.num_experts, cfg.d_model, cfg.expert_d_ff
    ks = split_keys(key, 4)
    return {
        "router": dense_init(ks[0], (D, E), dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), dtype=dtype),
        "w_up": dense_init(ks[2], (E, D, F), dtype=dtype),
        "w_down": dense_init(ks[3], (E, F, D), dtype=dtype),
    }


def capacity(cfg, num_tokens: int) -> int:
    k, E = cfg.experts_per_token, cfg.num_experts
    c = math.ceil(k * num_tokens / E * cfg.moe_capacity_factor)
    return max(8, ((c + 7) // 8) * 8)        # MXU-aligned


def moe_ffn(params, x, cfg):
    """x [B,S,D] -> (y [B,S,D], aux dict).

    GShard-style *grouped* dispatch: each batch row is a routing group,
    so top-k selection, slot assignment (argsort) and the scatter into
    the [B, E, C, D] buffer are all local to the data shard holding that
    row — no global sort/gather. The expert einsum against E-sharded
    weights is where GSPMD inserts the expert-parallel all-to-all.
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, S)

    logits = x.astype(jnp.float32) @ params["router"]        # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # [B,S,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- slot positions within (row, expert): vmapped stable argsort ----
    e_flat = idx.reshape(B, S * k)                           # [B, S*k]
    order = jnp.argsort(e_flat, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=-1)
    counts = jnp.zeros((B, E), jnp.int32).at[
        jnp.arange(B)[:, None], e_flat].add(1)               # [B,E]
    starts = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32), jnp.cumsum(counts, -1)[:, :-1]], -1)
    pos_sorted = jnp.arange(S * k, dtype=jnp.int32)[None, :] - \
        jnp.take_along_axis(starts, sorted_e, axis=-1)
    pos = jnp.zeros((B, S * k), jnp.int32).at[
        jnp.arange(B)[:, None], order].set(pos_sorted)
    keep = pos < C

    # ---- dispatch: scatter-add into [B, E, C, D] (row-local) ----
    tok_of_pair = jnp.arange(S * k, dtype=jnp.int32) // k    # [S*k]
    src = x[:, tok_of_pair]                                  # [B, S*k, D]
    contrib = jnp.where(keep[..., None], src, 0)
    e_safe = jnp.where(keep, e_flat, 0)
    p_safe = jnp.where(keep, pos, 0)
    buf = jnp.zeros((B, E, C, D), x.dtype).at[
        jnp.arange(B)[:, None], e_safe, p_safe].add(
        contrib.astype(x.dtype), mode="drop")
    buf = shard_hint(buf, "moe_becd")

    # ---- expert FFNs (batched over B, E) ----
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["w_gate"])) * \
        jnp.einsum("becd,edf->becf", buf, params["w_up"])
    y_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    y_buf = shard_hint(y_buf, "moe_becd")

    # ---- combine: gather back, weight by gates, sum the k slots ----
    out_pairs = y_buf[jnp.arange(B)[:, None], e_safe, p_safe]
    out_pairs = jnp.where(keep[..., None], out_pairs, 0)
    out_pairs = out_pairs * gates.reshape(B, S * k)[..., None].astype(
        x.dtype)
    y = out_pairs.reshape(B, S, k, D).sum(axis=2)

    # ---- aux losses (Switch f·P, router z-loss) ----
    me = probs.mean(axis=(0, 1))                             # [E]
    ce = counts.sum(0).astype(jnp.float32) / (B * S * k)
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return y, {"lb_loss": lb_loss, "z_loss": z_loss}
