"""Mamba2 / SSD (state-space duality) block [arXiv:2405.21060].

TPU adaptation: the SSD "chunked" algorithm is already MXU-shaped — the
sequence is split into chunks; intra-chunk terms are batched matmuls and
the inter-chunk term is a first-order recurrence over per-chunk states
(lax.scan over nchunks, each step a few einsums). Decode is the O(1)
recurrent update h' = exp(dt·A)·h + dt·(B ⊗ x).

Layout: d_inner = expand*d_model, heads Hs = d_inner/ssm_head_dim (P),
state N = cfg.ssm_state, single B/C group (ngroups=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys


def init_ssm(key, cfg, dtype):
    D = cfg.d_model
    Din = cfg.ssm_inner
    Hs = cfg.ssm_heads
    N = cfg.ssm_state
    Kc = cfg.conv_kernel
    ks = split_keys(key, 6)
    conv_dim = Din + 2 * N           # conv over x, B, C (mamba2 layout)
    return {
        # in_proj -> [z, xBC, dt]
        "w_in": dense_init(ks[0], (D, 2 * Din + 2 * N + Hs), dtype=dtype),
        "conv_w": dense_init(ks[1], (Kc, conv_dim), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((Hs,), jnp.float32),          # A = -exp(A_log)
        "D": jnp.ones((Hs,), jnp.float32),
        "dt_bias": jnp.zeros((Hs,), jnp.float32),
        "w_out": dense_init(ks[2], (Din, D), dtype=dtype),
        "norm_w": jnp.ones((Din,), dtype),
    }


def _split_proj(cfg, proj):
    Din, N, Hs = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :Din]
    xBC = proj[..., Din:Din + Din + 2 * N]
    dt = proj[..., Din + Din + 2 * N:]
    return z, xBC, dt


def _causal_conv_train(xBC, w, b):
    """Depthwise causal conv over seq. xBC [B,S,C], w [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _gated_norm(y, z, w, eps=1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)


def ssm_train(params, x, cfg):
    """x [B,S,D] -> [B,S,D] via chunked SSD."""
    y, _ = _ssd_forward(params, x, cfg, return_state=False)
    return y


def ssm_prefill(params, x, cfg):
    """Returns (y, cache) — the final recurrent state feeds decode."""
    return _ssd_forward(params, x, cfg, return_state=True)


def _ssd_forward(params, x, cfg, return_state: bool):
    B, S, D = x.shape
    Din, N, Hs, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nch = S // Q

    proj = x @ params["w_in"]
    z, xBC_raw, dt_raw = _split_proj(cfg, proj)
    xBC = _causal_conv_train(xBC_raw, params["conv_w"], params["conv_b"])
    xs = xBC[..., :Din].reshape(B, S, Hs, P).astype(jnp.float32)
    Bmat = xBC[..., Din:Din + N].astype(jnp.float32)        # [B,S,N]
    Cmat = xBC[..., Din + N:].astype(jnp.float32)           # [B,S,N]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"])                 # [B,S,Hs]
    A = -jnp.exp(params["A_log"])                           # [Hs]
    a = dt * A                                              # [B,S,Hs] (log-decay)

    # chunk reshape
    xs_c = xs.reshape(B, nch, Q, Hs, P)
    B_c = Bmat.reshape(B, nch, Q, N)
    C_c = Cmat.reshape(B, nch, Q, N)
    dt_c = dt.reshape(B, nch, Q, Hs)
    a_c = a.reshape(B, nch, Q, Hs)
    acs = jnp.cumsum(a_c, axis=2)                           # [B,nch,Q,Hs]

    # --- intra-chunk (quadratic within chunk, batched matmuls) ---
    # L[b,c,h,i,j] = exp(acs_i - acs_j) for i >= j
    diff = acs[:, :, :, None, :] - acs[:, :, None, :, :]    # [B,nch,Q,Q,Hs]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores CB[b,c,i,j] = C_i . B_j
    CB = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)
    M = CB[..., None] * Lmat                                # [B,nch,Q,Q,Hs]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M,
                         xs_c * dt_c[..., None])

    # --- chunk states: S_c = sum_j exp(acs_Q - acs_j) B_j (dt_j x_j)^T ---
    decay_to_end = jnp.exp(acs[:, :, -1:, :] - acs)         # [B,nch,Q,Hs]
    state_c = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                         B_c, decay_to_end * dt_c, xs_c)    # [B,nch,Hs,N,P]

    # --- inter-chunk recurrence over chunk states ---
    chunk_decay = jnp.exp(acs[:, :, -1, :])                 # [B,nch,Hs]

    def scan_fn(h, inp):
        st, dec = inp                                       # [B,Hs,N,P],[B,Hs]
        h_out = h                                           # state BEFORE chunk
        h = h * dec[..., None, None] + st
        return h, h_out

    st_sw = state_c.swapaxes(0, 1)                          # [nch,B,Hs,N,P]
    dec_sw = chunk_decay.swapaxes(0, 1)
    h0 = jnp.zeros((B, Hs, N, P), jnp.float32)
    h_last, h_prevs = jax.lax.scan(scan_fn, h0, (st_sw, dec_sw))
    h_prev = h_prevs.swapaxes(0, 1)                         # [B,nch,Hs,N,P]

    # --- inter-chunk output: y_j += C_j exp(acs_j) h_prev ---
    decay_from_start = jnp.exp(acs)                         # [B,nch,Q,Hs]
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         C_c, h_prev, decay_from_start)

    y = (y_intra + y_inter).reshape(B, S, Hs, P)
    y = y + params["D"][None, None, :, None] * xs
    y = y.reshape(B, S, Din)
    y = _gated_norm(y, z, params["norm_w"])
    out = (y.astype(x.dtype) @ params["w_out"])
    if not return_state:
        return out, None
    K = cfg.conv_kernel - 1
    conv_cache = (xBC_raw[:, S - K:, :] if S >= K else
                  jnp.pad(xBC_raw, ((0, 0), (K - S, 0), (0, 0))))
    cache = {"h": h_last, "conv": conv_cache.astype(x.dtype)}
    return out, cache


def init_ssm_cache(cfg, batch, dtype):
    Hs, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_dim = cfg.ssm_inner + 2 * N
    return {
        "h": jnp.zeros((batch, Hs, N, P), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
    }


def ssm_decode(params, x, cache, cfg):
    """x [B,1,D] single step."""
    B = x.shape[0]
    Din, N, Hs, P = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    proj = x[:, 0] @ params["w_in"]                         # [B, ...]
    z, xBC, dt_raw = _split_proj(cfg, proj)
    # conv with cache
    hist = jnp.concatenate([cache["conv"],
                            xBC[:, None, :].astype(cache["conv"].dtype)],
                           axis=1)                          # [B,K,conv_dim]
    w = params["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          w.astype(jnp.float32)) + params["conv_b"].astype(
        jnp.float32)
    xBC = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:]

    xs = xBC[:, :Din].reshape(B, Hs, P)
    Bv = xBC[:, Din:Din + N]
    Cv = xBC[:, Din + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    dec = jnp.exp(dt * A)                                   # [B,Hs]
    h = cache["h"] * dec[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", Bv, dt, xs)
    y = jnp.einsum("bn,bhnp->bhp", Cv, h)
    y = y + params["D"][None, :, None] * xs
    y = y.reshape(B, Din)
    y = _gated_norm(y, z, params["norm_w"])
    out = (y.astype(x.dtype) @ params["w_out"])[:, None, :]
    return out, {"h": h, "conv": new_conv}
