"""Shared model components: norms, RoPE, chunked (flash-style) attention in
pure jnp, embeddings, losses, init helpers.

TPU adaptation note (DESIGN.md §3): prefill attention never materializes
the [S, S] score matrix — it is an online-softmax scan over KV chunks
(lax.scan), which is what bounds compiled HBM at 32k/500k context. The
Pallas `flash_attention` kernel is the hot-path twin with explicit VMEM
BlockSpecs; the jnp path here is the oracle + the dry-run lowering path.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ----------------------------- init helpers -------------------------------

def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.bfloat16):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ----------------------------- norms / rope -------------------------------

def rms_norm(x, weight, eps):
    """RMSNorm with an f32 *reduction* but no f32 image of x: the sum of
    squares is a contraction with f32 accumulation, so XLA never sees an
    elementwise convert(x) it could hoist out of the backward layer loop
    (that hoist materialized an f32 copy of the whole [L,B,S,D] residual
    stack — 12.9 GB/device on internlm2 train_4k; EXPERIMENTS.md §Perf)."""
    sq = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(sq / x.shape[-1] + eps)
    return (x * inv[..., None].astype(x.dtype)) * weight


def rope_frequencies(head_dim, theta):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta):
    """x [..., S, H, Dh], positions [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(dh, theta), dtype=jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [...,S,dh/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ------------------------ chunked causal attention -------------------------

def _gqa_scores(q, k):
    """q [B,Sq,H,Dh], k [B,Sk,K,Dh] with H = K*G -> scores [B,H,Sq,Sk]
    (f32 accumulation; operands stay in their dtype so no full-size f32
    image of K is ever materialized)."""
    B, Sq, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    return s.reshape(B, H, Sq, k.shape[1])


def _gqa_out(p, v):
    """p [B,H,Sq,Sk], v [B,Sk,K,Dh] -> [B,Sq,H,Dh] (f32 accumulation)."""
    B, H, Sq, Sk = p.shape
    K = v.shape[2]
    G = H // K
    pg = p.reshape(B, K, G, Sq, Sk)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pg, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, v.shape[-1])


def chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                      window: int = 0, chunk: int = 1024,
                      kv_valid_len=None, q_chunk: int = 0):
    """Online-softmax attention over KV chunks, additionally blocked over
    the query dim (lax.map over q blocks) so peak score-buffer memory is
    [B,H,q_chunk,chunk] regardless of sequence length.

    q [B,Sq,H,Dh]; k,v [B,Sk,K,Dh] (GQA). `q_offset`: absolute position of
    q[0] (for decode, q_offset = pos). `window`>0 = sliding window.
    `kv_valid_len` (scalar or [B]) masks out cache positions >= valid.
    """
    B, Sq, H, Dh = q.shape
    q_chunk = q_chunk or chunk
    # Pin K/V to their attention layout ONCE, before the q-block scan:
    # otherwise GSPMD re-gathers every KV chunk inside every q-block
    # iteration (measured 125k tiny all-gathers = 2.1 TB/device on kimi
    # prefill_32k; EXPERIMENTS.md Perf H2c).
    from repro.distributed.api import shard_hint
    k = shard_hint(k, "attn_kv")
    v = shard_hint(v, "attn_kv")
    if Sq > q_chunk and Sq % q_chunk == 0:
        nq = Sq // q_chunk
        qb = q.reshape(B, nq, q_chunk, H, Dh).swapaxes(0, 1)

        def one(args):
            i, qblk = args
            return _kv_chunked_attention(
                qblk, k, v, causal=causal,
                q_offset=q_offset + i * q_chunk, window=window,
                chunk=chunk, kv_valid_len=kv_valid_len)

        out = jax.lax.map(one, (jnp.arange(nq), qb))
        return out.swapaxes(0, 1).reshape(B, Sq, H, Dh)
    return _kv_chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                                 window=window, chunk=chunk,
                                 kv_valid_len=kv_valid_len)


def _kv_chunked_attention(q, k, v, *, causal: bool, q_offset=0,
                          window: int = 0, chunk: int = 1024,
                          kv_valid_len=None):
    B, Sq, H, Dh = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    qf = (q * scale).astype(q.dtype)

    if Sk <= chunk:
        s = _gqa_scores(qf, k)
        s = _mask_scores(s, Sq, Sk, 0, q_offset, causal, window, kv_valid_len)
        p = jax.nn.softmax(s, axis=-1)
        return _gqa_out(p.astype(q.dtype), v).astype(q.dtype)

    nchunks = (Sk + chunk - 1) // chunk
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        base_valid = kv_valid_len if kv_valid_len is not None else Sk
    else:
        base_valid = kv_valid_len
    kc = k.reshape(B, nchunks, chunk, *k.shape[2:]).swapaxes(0, 1)
    vc = v.reshape(B, nchunks, chunk, *v.shape[2:]).swapaxes(0, 1)

    def body(carry, xs):
        acc, m, denom, idx = carry
        kb, vb = xs
        s = _gqa_scores(qf, kb)                          # [B,H,Sq,chunk] f32
        s = _mask_scores(s, Sq, chunk, idx * chunk, q_offset, causal,
                         window, base_valid)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * alpha + p.sum(axis=-1)
        # p [B,H,Sq,chunk] x v [B,chunk,K,Dh] -> [B,H,Sq,Dh] (GQA grouped)
        K = vb.shape[2]
        G = H // K
        pg = p.astype(vb.dtype).reshape(B, K, G, Sq, chunk)
        og = jnp.einsum("bkgqs,bskd->bkgqd", pg, vb,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + og.reshape(B, H, Sq, Dh)
        return (acc, m_new, denom, idx + 1), None

    acc0 = jnp.zeros((B, H, Sq, Dh), jnp.float32)
    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, H, Sq), jnp.float32)
    (acc, m, denom, _), _ = jax.lax.scan(body, (acc0, m0, d0, 0), (kc, vc))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q.dtype)          # [B,Sq,H,Dh]


def _expand_kv(kv, H):
    """[B,S,K,Dh] -> [B,S,H,Dh] by repeating groups (for einsum in scan)."""
    B, S, K, Dh = kv.shape
    G = H // K
    return jnp.repeat(kv, G, axis=2)


def _mask_scores(s, Sq, Sk_chunk, kv_start, q_offset, causal, window,
                 kv_valid_len):
    """s [B,H,Sq,Sk_chunk]; positions: q_pos = q_offset + iq,
    kv_pos = kv_start + ik."""
    iq = jnp.arange(Sq)[:, None] + q_offset
    ik = jnp.arange(Sk_chunk)[None, :] + kv_start
    mask = jnp.ones((Sq, Sk_chunk), bool)
    if causal:
        mask &= ik <= iq
    if window:
        mask &= ik > iq - window
    m = mask[None, None]
    if kv_valid_len is not None:
        vl = jnp.asarray(kv_valid_len)
        if vl.ndim == 0:
            m = m & (ik < vl)[None, None]
        else:
            m = m & (ik[None] < vl[:, None, None])[:, None]
    return jnp.where(m, s, NEG_INF)


# ----------------------------- attention layer -----------------------------

def init_attention(key, cfg, dtype):
    D, H, K, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * Dh), dtype=dtype),
        "wk": dense_init(ks[1], (D, K * Dh), dtype=dtype),
        "wv": dense_init(ks[2], (D, K * Dh), dtype=dtype),
        "wo": dense_init(ks[3], (H * Dh, D), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * Dh,), dtype)
        p["bk"] = jnp.zeros((K * Dh,), dtype)
        p["bv"] = jnp.zeros((K * Dh,), dtype)
    return p


def qkv_proj(p, x, cfg):
    B, S, D = x.shape
    H, K, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return (q.reshape(B, S, H, Dh), k.reshape(B, S, K, Dh),
            v.reshape(B, S, K, Dh))


def attn_out(p, o):
    B, S, H, Dh = o.shape
    from repro.distributed.api import shard_hint
    # serving gather point: heads were computed model-sharded; wo's
    # contraction runs over them, so pull the activation back to
    # replicated first — the dot is then a full local contraction,
    # bit-identical to the single-device engine's (docs/sharding.md).
    # Outside a serving ctx ("attn_out_in" unbound) this is identity.
    o = shard_hint(o.reshape(B, S, H * Dh), "attn_out_in")
    return o @ p["wo"]


# ----------------------------- FFN -----------------------------------------

def init_ffn(key, d_model, d_ff, dtype):
    ks = split_keys(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(ks[2], (d_ff, d_model), dtype=dtype),
    }


def ffn(p, x):
    from repro.distributed.api import shard_hint
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    # serving gather point before the w_down contraction over d_ff
    # (see attn_out); identity unless "ffn_hidden" is bound.
    return shard_hint(h, "ffn_hidden") @ p["w_down"]


# ----------------------------- embedding / loss ----------------------------

def init_embed(key, cfg, dtype):
    ks = split_keys(key, 2)
    p = {"embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                             scale=1.0, dtype=dtype),
         "final_norm": jnp.ones((cfg.d_model,), dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                  dtype=dtype)
    return p


def embed_tokens(p, tokens):
    return p["embed"][tokens]


def lm_logits(p, x, cfg):
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ p["embed"].T
    return x @ p["lm_head"]


def cross_entropy_loss(logits, labels, mask=None):
    """logits [B,S,V] (any float dtype), labels [B,S] int.

    The gold logit is picked with an iota-compare reduction rather than
    take_along_axis so a vocab-sharded logits tensor reduces with a small
    all-reduce instead of an all-gather (GSPMD-friendly)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = (jnp.arange(V, dtype=labels.dtype) == labels[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
