"""Architecture config — a superset dataclass covering the six assigned
architecture families (dense / moe / ssm / hybrid / vlm / audio)."""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    sliding_window: int = 0           # 0 = full causal attention
    attn_chunk: int = 1024            # online-softmax KV chunk (jnp path)
    remat: bool = True                # checkpoint layer blocks in training
    dtype: str = "bfloat16"

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    first_dense_layers: int = 0       # leading layers use a dense FFN
    moe_d_ff: int = 0                 # per-expert hidden (0 -> d_ff)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # --- hybrid (RecurrentGemma / Griffin) ---
    block_pattern: tuple = ()         # e.g. ("rec", "rec", "attn")
    local_window: int = 2048
    lru_width: int = 0                # 0 -> d_model

    # --- VLM (cross-attention image layers) ---
    cross_attn_every: int = 0         # every Nth layer is a cross-attn layer
    num_image_tokens: int = 0

    # --- audio enc-dec (Whisper) ---
    encoder_layers: int = 0
    audio_frames: int = 1500          # post-conv-frontend frames (stubbed)

    # citation for the assigned config
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.num_heads))

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def lru_dim(self) -> int:
        return self.lru_width or self.d_model

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests (assignment spec:
        <=2 layers, d_model<=512, <=4 experts)."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            vocab_size=min(self.vocab_size, 512),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=64 if self.num_heads else 0,
            attn_chunk=128,
            remat=False,
        )
        if self.num_experts:
            small.update(num_experts=4, experts_per_token=2,
                         moe_d_ff=min(self.expert_d_ff, 128),
                         first_dense_layers=min(self.first_dense_layers, 1))
        if self.ssm_state:
            small.update(ssm_state=32, ssm_head_dim=32, ssm_chunk=32)
        if self.block_pattern:
            small.update(block_pattern=("rec", "attn"), local_window=64,
                         lru_width=min(self.lru_dim, 256))
        if self.cross_attn_every:
            small.update(cross_attn_every=2, num_image_tokens=16)
        if self.encoder_layers:
            small.update(encoder_layers=2, audio_frames=32)
        if self.sliding_window:
            small.update(sliding_window=64)
        small.update(overrides)
        small["name"] = self.name + "-smoke"
        return replace(self, **small)
