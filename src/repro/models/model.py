"""Model assembly: pattern-block layer groups scanned with lax.scan.

Layers with identical structure are stacked ([count, ...] params) and run
under `lax.scan`, so HLO size is independent of depth (compile-time and
memory hygiene for the 100-layer dry-run configs). Heterogeneous stacks
(hybrid 2:1 recurrent:attention, VLM every-5th cross-attention, MoE with
leading dense layers) become a short list of homogeneous *groups*, each
scanning a fixed intra-block pattern.

Public API (all pure functions over a params pytree):
  model.init(rng)                               -> params
  model.train_logits(params, batch)             -> (logits [B,S,V], aux)
  model.loss(params, batch)                     -> (scalar, metrics)
  model.prefill(params, batch)                  -> (logits [B,S,V], caches)
  model.decode_step(params, caches, token, pos) -> (logits [B,V], caches)
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import (cross_entropy_loss, dense_init, dtype_of, embed_tokens,
                     init_embed, lm_logits, rms_norm, split_keys)
from .config import ModelConfig
from .layers import KIND_DECODE, KIND_INIT, KIND_PREFILL, KIND_TRAIN
from ..distributed.api import shard_hint

LB_COEF = 0.01
Z_COEF = 0.001


@jax.custom_jvp
def _opt_barrier(x):
    return jax.lax.optimization_barrier(x)


@_opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    # identity JVP: older jax has no differentiation rule for
    # optimization_barrier; the barrier only matters for primal scheduling
    return _opt_barrier(primals[0]), tangents[0]


def layer_groups(cfg: ModelConfig):
    """-> list of (pattern tuple, count). Decoder-side stack."""
    L = cfg.num_layers
    at = cfg.arch_type
    if at == "dense":
        return [(("attn",), L)]
    if at == "moe":
        gs = []
        fd = cfg.first_dense_layers
        if fd:
            gs.append((("attn",), fd))
        gs.append((("moe",), L - fd))
        return gs
    if at == "ssm":
        return [(("ssm",), L)]
    if at == "hybrid":
        pat = tuple(cfg.block_pattern)
        n, rem = divmod(L, len(pat))
        gs = [(pat, n)] if n else []
        if rem:
            gs.append((pat[:rem], 1))
        return gs
    if at == "vlm":
        e = cfg.cross_attn_every
        pat = ("attn",) * (e - 1) + ("cross",)
        n, rem = divmod(L, e)
        gs = [(pat, n)] if n else []
        if rem:
            gs.append((("attn",) * rem, 1))
        return gs
    if at == "audio":
        return [(("dec",), L)]
    raise ValueError(at)


def _init_group(key, pattern, count, cfg, dtype):
    """-> tuple over pattern positions of stacked param trees [count,...]."""
    out = []
    for j, kind in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, j), count)
        out.append(jax.vmap(
            lambda k: KIND_INIT[kind](k, cfg, dtype))(keys))
    return tuple(out)


def _sum_aux(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


@dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------ init ---------------------------------
    def init(self, rng):
        cfg = self.cfg
        dtype = dtype_of(cfg)
        ks = split_keys(rng, 4)
        params = {"embed_block": init_embed(ks[0], cfg, dtype)}
        params["groups"] = [
            _init_group(jax.random.fold_in(ks[1], gi), pat, count, cfg, dtype)
            for gi, (pat, count) in enumerate(layer_groups(cfg))
        ]
        if cfg.arch_type == "audio":
            enc_keys = jax.random.fold_in(ks[2], 0)
            params["encoder"] = _init_group(enc_keys, ("enc",),
                                            cfg.encoder_layers, cfg, dtype)
        return params

    def abstract_params(self, rng=None):
        """ShapeDtypeStruct params (no allocation) for AOT lowering."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, rng)

    # --------------------------- encoder (audio) --------------------------
    def _encode_frames(self, params, frames):
        cfg = self.cfg
        x = frames
        def body(x, pslice):
            y, _ = KIND_TRAIN["enc"](pslice, x, cfg, {})
            return y, None
        x, _ = jax.lax.scan(body, x, params["encoder"][0])
        return x

    def _base_ctx(self):
        ctx = {}
        if self.cfg.arch_type == "hybrid":
            # hybrid attention layers are local (RecurrentGemma 1:2)
            ctx["window"] = self.cfg.local_window
        return ctx

    def _ctx_from_batch(self, params, batch):
        ctx = self._base_ctx()
        if self.cfg.arch_type == "vlm":
            ctx["image_embeds"] = batch["image_embeds"]
        if self.cfg.arch_type == "audio":
            ctx["enc_out"] = self._encode_frames(params, batch["frames"])
        return ctx

    # ------------------------------ train --------------------------------
    def _trunk(self, params, batch):
        """Embed + layer stacks -> (hidden [B,S,D], aux losses)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        ctx = self._ctx_from_batch(params, batch)
        x = embed_tokens(params["embed_block"], tokens)
        x = shard_hint(x, "act_bsd")
        aux = {"lb": jnp.zeros((), jnp.float32),
               "z": jnp.zeros((), jnp.float32)}
        for (pat, count), gp in zip(layer_groups(cfg), params["groups"]):
            def body(x, pslices, pat=pat):
                # barrier: without it XLA hoists the first bf16->f32
                # convert of x out of the backward while-loop, material-
                # izing an f32 copy of the whole [L,B,S,D] residual stack
                # (observed 12.9 GB/device on internlm2 train_4k).
                x = _opt_barrier(x)
                a = {"lb": jnp.zeros((), jnp.float32),
                     "z": jnp.zeros((), jnp.float32)}
                for j, kind in enumerate(pat):
                    x, aj = KIND_TRAIN[kind](pslices[j], x, cfg, ctx)
                    a = _sum_aux(a, aj)
                return x, a
            if cfg.remat:
                body = jax.checkpoint(body)
            x, auxs = jax.lax.scan(body, x, gp)
            aux = _sum_aux(aux, jax.tree.map(jnp.sum, auxs))
        return x, aux

    def train_logits(self, params, batch):
        x, aux = self._trunk(params, batch)
        logits = lm_logits(params["embed_block"], x, self.cfg)
        return shard_hint(logits, "logits_bsv"), aux

    def loss(self, params, batch, seq_chunk: int = 1024):
        """Sequence-chunked softmax cross-entropy: per-chunk logits are
        (re)computed under jax.checkpoint, so the [B,S,V] logits tensor is
        never materialized (memory analysis showed it dominating trainer
        HBM for the 150k-vocab archs; see EXPERIMENTS.md §Perf)."""
        cfg = self.cfg
        x, aux = self._trunk(params, batch)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        B, S, D = x.shape
        C = min(seq_chunk, S)
        eb = params["embed_block"]
        if S % C != 0:
            logits = shard_hint(lm_logits(eb, x, cfg), "logits_bsv")
            ce = cross_entropy_loss(logits, labels, mask)
        else:
            n = S // C

            @jax.checkpoint
            def chunk_nll(xc, lc, mc):
                logits = lm_logits(eb, xc, cfg)
                logits = shard_hint(logits, "logits_bsv").astype(jnp.float32)
                logz = jax.nn.logsumexp(logits, axis=-1)
                onehot = (jnp.arange(cfg.vocab_size, dtype=lc.dtype)
                          == lc[..., None])
                gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
                nll = (logz - gold) * mc
                return nll.sum(), mc.sum()

            def body(carry, args):
                tot, cnt = carry
                s, c = chunk_nll(*args)
                return (tot + s, cnt + c), None

            xs = (x.reshape(B, n, C, D).swapaxes(0, 1),
                  labels.reshape(B, n, C).swapaxes(0, 1),
                  (mask if mask is not None else
                   jnp.ones((B, S), jnp.float32)).reshape(
                      B, n, C).swapaxes(0, 1))
            (tot, cnt), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), xs)
            ce = tot / jnp.maximum(cnt, 1.0)
        total = ce + LB_COEF * aux["lb"] + Z_COEF * aux["z"]
        return total, {"ce": ce, "lb": aux["lb"], "z": aux["z"]}

    # ----------------------------- prefill -------------------------------
    def prefill(self, params, batch, cache_len=None, true_len=None):
        """true_len (traced scalar, optional): number of real prompt
        tokens when `tokens` is padded to a jit bucket length — padded
        positions get kv_pos = -1 so they can never be attended."""
        cfg = self.cfg
        tokens = batch["tokens"]
        ctx = self._ctx_from_batch(params, batch)
        if cache_len is not None:
            ctx["cache_len"] = cache_len
        if true_len is not None:
            ctx["true_len"] = true_len
        x = embed_tokens(params["embed_block"], tokens)
        x = shard_hint(x, "act_bsd")
        caches = []
        for (pat, count), gp in zip(layer_groups(cfg), params["groups"]):
            def body(x, pslices, pat=pat):
                cs = []
                for j, kind in enumerate(pat):
                    x, c = KIND_PREFILL[kind](pslices[j], x, cfg, ctx)
                    cs.append(c)
                return x, tuple(cs)
            x, group_cache = jax.lax.scan(body, x, gp)
            caches.append(group_cache)
        logits = lm_logits(params["embed_block"], x, cfg)
        return shard_hint(logits, "logits_bsv"), caches

    # ------------------------------ decode -------------------------------
    def _decode_trunk(self, params, caches, tokens, ctx):
        """Shared decode body: embed [B,S] tokens, run every layer group's
        KIND_DECODE under lax.scan against the caches -> ([B,S,V], caches)."""
        cfg = self.cfg
        x = embed_tokens(params["embed_block"], tokens)
        # pin the residual stream's sharding before the layer scan (same
        # hint train/prefill apply): under the serving rules this
        # gathers the vocab-sharded embedding lookup back to replicated
        # exactly once, instead of leaving GSPMD to re-decide inside the
        # scanned layer body (docs/sharding.md)
        x = shard_hint(x, "act_bsd")
        new_caches = []
        for (pat, count), gp, gc in zip(layer_groups(cfg), params["groups"],
                                        caches):
            def body(x, sl, pat=pat):
                pslices, cslices = sl
                ncs = []
                for j, kind in enumerate(pat):
                    x, nc = KIND_DECODE[kind](pslices[j], x, cslices[j],
                                              cfg, ctx)
                    ncs.append(nc)
                return x, tuple(ncs)
            x, ngc = jax.lax.scan(body, x, (gp, gc))
            new_caches.append(ngc)
        return lm_logits(params["embed_block"], x, cfg), new_caches

    def decode_step(self, params, caches, token, pos, batch_ctx=None):
        """token [B] int32, pos [B] or scalar int32 -> (logits [B,V], caches)."""
        ctx = self._base_ctx()
        ctx.update(batch_ctx or {})
        ctx["pos"] = pos
        logits, new_caches = self._decode_trunk(params, caches,
                                                token[:, None], ctx)
        return shard_hint(logits[:, 0], "logits_bv"), new_caches

    @property
    def prefill_padding_safe(self) -> bool:
        """True iff prefill tolerates a zero-padded prompt tail under
        `true_len` masking (the serving engine's jit-bucketing). Cache
        entries of attention kinds are per-position and masked via
        kv_pos; recurrent kinds (rec/ssm) fold the padded tail into
        their carried state, so they must be prefilled at exact
        length."""
        return all(kind not in ("rec", "ssm")
                   for pat, _ in layer_groups(self.cfg) for kind in pat)

    @property
    def supports_span_decode(self) -> bool:
        """True iff every decode layer kind is position-addressed (KV
        cache keyed by absolute position), so a multi-token span can be
        fed in one call and rejected speculative writes roll back via the
        kv_pos <= q_pos masking rule. Recurrent kinds (rec/ssm) carry
        unaddressed state and cannot rewind; cross/dec need side inputs."""
        return all(kind in ("attn", "moe")
                   for pat, _ in layer_groups(self.cfg) for kind in pat)

    def decode_span(self, params, caches, tokens, pos, feed_mask=None,
                    batch_ctx=None):
        """Speculative span decode: tokens [B,S] at absolute positions
        pos[b] + i -> (logits [B,S,V], caches). One fused device call
        scores a whole draft window (jump-forward feed + draft-verify),
        replacing S sequential decode_step round-trips. feed_mask [B,S]
        bool gates per-position cache writes for ragged spans (see
        layers._self_attention_decode). Requires supports_span_decode."""
        ctx = self._base_ctx()
        ctx.update(batch_ctx or {})
        ctx["pos"] = pos
        if feed_mask is not None:
            ctx["feed_mask"] = feed_mask
        logits, new_caches = self._decode_trunk(params, caches, tokens, ctx)
        return shard_hint(logits, "logits_bsv"), new_caches


    # ------------------------- cache construction ------------------------
    def init_decode_caches(self, batch_size: int, cache_len: int):
        """Zero caches shaped for decode (used by the decode dry-run shapes
        and by the serving engine's slot allocator)."""
        from .layers import init_kv_cache
        from .rglru import init_rglru_cache
        from .ssm import init_ssm_cache
        cfg = self.cfg
        dtype = dtype_of(cfg)
        K, Dh = cfg.num_kv_heads, cfg.resolved_head_dim

        def one(kind):
            w = cfg.local_window if cfg.arch_type == "hybrid" else \
                cfg.sliding_window
            L = min(cache_len, w) if w else cache_len
            if kind in ("attn", "moe"):
                return init_kv_cache(cfg, batch_size, L, dtype)
            if kind == "rec":
                return init_rglru_cache(cfg, batch_size, dtype)
            if kind == "ssm":
                return init_ssm_cache(cfg, batch_size, dtype)
            if kind == "cross":
                Ni = cfg.num_image_tokens
                return {"k": jnp.zeros((batch_size, Ni, K, Dh), dtype),
                        "v": jnp.zeros((batch_size, Ni, K, Dh), dtype)}
            if kind == "dec":
                Sa = cfg.audio_frames
                return {
                    "self": init_kv_cache(cfg, batch_size, cache_len, dtype),
                    "cross": {
                        "k": jnp.zeros((batch_size, Sa, K, Dh), dtype),
                        "v": jnp.zeros((batch_size, Sa, K, Dh), dtype)},
                }
            raise ValueError(kind)

        caches = []
        for pat, count in layer_groups(cfg):
            group = tuple(
                jax.tree.map(lambda a: jnp.zeros((count,) + a.shape, a.dtype),
                             one(kind))
                for kind in pat)
            caches.append(group)
        return caches

    def init_paged_caches(self, num_pages: int, page_size: int):
        """Global paged KV pool for the serving engine's paged mode
        (docs/kv_paging.md): every attention layer holds
        {"k","v": [count, num_pages, page_size, K, Dh]} shared across
        all decode slots; per-slot page tables ride in via
        ctx["page_table"] on each decode/span call. Requires
        position-addressed, window-free attention throughout."""
        cfg = self.cfg
        if not self.supports_span_decode:
            raise ValueError(
                "paged KV caches need position-addressed decode caches "
                "(attn/moe layer kinds); this arch has recurrent or "
                "side-input state")
        if cfg.sliding_window:
            raise ValueError(
                "paged KV caches do not support sliding-window attention")
        dtype = dtype_of(cfg)
        K, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
        caches = []
        for pat, count in layer_groups(cfg):
            group = tuple(
                {"k": jnp.zeros((count, num_pages, page_size, K, Dh),
                                dtype),
                 "v": jnp.zeros((count, num_pages, page_size, K, Dh),
                                dtype)}
                for _ in pat)
            caches.append(group)
        return caches


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
