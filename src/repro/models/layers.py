"""Layer kinds assembled into pattern blocks by decoder.py.

Each kind implements:
  init_<kind>(key, cfg, dtype) -> params
  <kind>_train(params, x, ctx)            -> x
  <kind>_prefill(params, x, ctx)          -> (x, cache)
  <kind>_decode(params, x, cache, ctx)    -> (x, cache)

ctx is a dict: {"positions": [B,S] or None, "pos": scalar decode position,
"image_embeds": [B,Ni,D] (vlm), "enc_out": [B,Se,D] (audio),
"cache_len": static cache length, "window": per-layer window override}.

KV caches store rotated K plus a per-slot absolute-position array
(`kv_pos`, −1 = empty) so ring-buffer (sliding-window) and linear caches
share one masking rule: valid ⇔ 0 ≤ kv_pos ≤ q_pos (∧ q_pos − kv_pos <
window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (NEG_INF, apply_rope, attn_out, chunked_attention,
                     dense_init, ffn, init_attention, init_ffn, qkv_proj,
                     rms_norm, split_keys)
from ..kernels.paged_attention.ops import paged_attention
from .moe import init_moe, moe_ffn
from .rglru import (init_rglru, init_rglru_cache, rglru_decode,
                    rglru_prefill, rglru_train)
from .ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_prefill, ssm_train
from ..distributed.api import shard_hint


# ======================= attention with explicit cache =====================

def _window_of(cfg, ctx):
    return ctx.get("window", cfg.sliding_window)


def _cache_len(cfg, ctx, seq_len):
    w = _window_of(cfg, ctx)
    L = ctx.get("cache_len", seq_len)
    return min(L, w) if w else L


def init_kv_cache(cfg, batch, length, dtype, kv_heads=None):
    K = kv_heads or cfg.num_kv_heads
    Dh = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, length, K, Dh), dtype),
        "v": jnp.zeros((batch, length, K, Dh), dtype),
        "kv_pos": jnp.full((batch, length), -1, jnp.int32),
    }


def _self_attention_train(p, x, cfg, ctx, causal=True):
    B, S, D = x.shape
    q, k, v = qkv_proj(p, x, cfg)
    positions = ctx.get("positions")
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=causal,
                          window=_window_of(cfg, ctx), chunk=cfg.attn_chunk)
    return attn_out(p, o)


def _self_attention_prefill(p, x, cfg, ctx):
    """Returns (out, cache) — cache covers the last `cache_len` positions
    (ring layout slot = pos % cache_len)."""
    B, S, D = x.shape
    q, k, v = qkv_proj(p, x, cfg)
    positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=True,
                          window=_window_of(cfg, ctx), chunk=cfg.attn_chunk)
    L = _cache_len(cfg, ctx, S)
    cache = init_kv_cache(cfg, B, L, x.dtype)
    take = jnp.arange(L) + max(0, S - L)          # last L absolute positions
    slot = take % L
    # true_len < S marks bucket-padded prompt tail positions (the engine
    # pads prompts to power-of-two lengths so prefill jits once per
    # bucket, not once per length) as empty: their K/V are garbage the
    # ring overwrites later, and kv_pos = -1 keeps them unattendable.
    limit = jnp.minimum(S, jnp.asarray(ctx["true_len"], jnp.int32)) \
        if "true_len" in ctx else S
    kv_pos = jnp.broadcast_to(jnp.where(take < limit, take, -1)[None, :],
                              (B, L))
    cache = {
        "k": cache["k"].at[:, slot].set(k[:, take].astype(cache["k"].dtype)),
        "v": cache["v"].at[:, slot].set(v[:, take].astype(cache["v"].dtype)),
        "kv_pos": jnp.zeros((B, L), jnp.int32).at[:, slot].set(kv_pos),
    }
    return attn_out(p, o), cache


def _paged_attention_decode(p, x, cache, cfg, ctx):
    """Paged twin of `_self_attention_decode`: the cache is a global page
    pool {"k","v": [P, ps, K, Dh]} shared by every slot, and
    ctx["page_table"] [B, nP] (int32, -1 = unmapped) names each slot's
    pages. Span position i of slot b writes its K/V at
    (page_table[b, (pos+i)//ps], (pos+i)%ps) — a flat scatter; unmapped
    or feed_mask-gated positions drop — and attention reads back through
    the page table (`kernels.paged_attention`, bit-exact with the dense
    branch). Rejected speculative writes roll back exactly as in the
    dense path: positions beyond the commit frontier are masked
    (idx <= q_pos) and overwritten on re-feed."""
    B, S, D = x.shape
    kp, vp = cache["k"], cache["v"]                    # [P, ps, K, Dh]
    P, ps, K, Dh = kp.shape
    pos = jnp.broadcast_to(jnp.asarray(ctx["pos"], jnp.int32), (B,))
    qpos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # [B,S]
    q, k, v = qkv_proj(p, x, cfg)
    q = apply_rope(q, qpos, cfg.rope_theta)
    k = apply_rope(k, qpos, cfg.rope_theta)
    # pin the fresh K/V to the pool's kv-head sharding BEFORE the
    # scatter, so a sharded pool is updated shard-locally instead of
    # being gathered (identity outside a serving sharding ctx)
    k = shard_hint(k, "attn_kv")
    v = shard_hint(v, "attn_kv")
    pt = ctx["page_table"]
    page = jnp.take_along_axis(pt, qpos // ps, axis=1)             # [B,S]
    ok = page >= 0
    feed = ctx.get("feed_mask")
    if feed is not None:
        ok &= feed
    dest = jnp.where(ok, page * ps + qpos % ps, P * ps)  # OOB -> dropped
    flat = dest.reshape(-1)
    kp = kp.reshape(P * ps, K, Dh).at[flat].set(
        k.reshape(B * S, K, Dh).astype(kp.dtype),
        mode="drop").reshape(P, ps, K, Dh)
    vp = vp.reshape(P * ps, K, Dh).at[flat].set(
        v.reshape(B * S, K, Dh).astype(vp.dtype),
        mode="drop").reshape(P, ps, K, Dh)
    o = paged_attention(q, kp, vp, pt, pos,
                        backend=ctx.get("paged_backend", "auto"))
    return attn_out(p, o), {"k": kp, "v": vp}


def _self_attention_decode(p, x, cache, cfg, ctx):
    """x [B,S,D] (S = 1 plain decode; S > 1 a speculative span);
    ctx['pos'] is a scalar or [B] int32 vector of absolute START
    positions (per-request positions in the serving engine) — span
    query i sits at absolute position pos + i.

    ctx['feed_mask'] [B,S] bool (optional) gates cache WRITES per span
    position: padding positions of a ragged span attend (their outputs
    are discarded by the caller) but never write, so rejected-draft /
    padding state can't leak into the cache. Writes from real positions
    at speculative offsets are naturally rolled back by the absolute-
    position masking rule (kv_pos <= q_pos) plus overwrite-on-reuse.

    When ctx carries a page table the slot's KV lives in the shared
    paged pool instead of a dense per-slot cache (docs/kv_paging.md)."""
    if "page_table" in ctx:
        return _paged_attention_decode(p, x, cache, cfg, ctx)
    B, S, D = x.shape
    pos = jnp.broadcast_to(jnp.asarray(ctx["pos"], jnp.int32), (B,))
    qpos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # [B,S]
    q, k, v = qkv_proj(p, x, cfg)
    q = apply_rope(q, qpos, cfg.rope_theta)
    k = apply_rope(k, qpos, cfg.rope_theta)
    # match the cache's kv-head sharding before the where-blend write
    # (identity outside a serving sharding ctx)
    k = shard_hint(k, "attn_kv")
    v = shard_hint(v, "attn_kv")
    L = cache["k"].shape[1]
    slot = qpos % L                                           # [B, S]
    # where-blend instead of scatter: GSPMD partitions a batched scatter
    # on a sharded cache via an f32-upcast rewrite (observed 10.7 GB of
    # f32 cache copies on the VLM decode); the select is shard-agnostic.
    # Span positions occupy distinct slots (S <= L), so the one-hot
    # blend over S is exact: each cache line receives at most one write.
    hit = (jnp.arange(L)[None, None, :] == slot[:, :, None])  # [B, S, L]
    feed = ctx.get("feed_mask")
    if feed is not None:
        hit &= feed[:, :, None]
    any_hit = hit.any(axis=1)                                 # [B, L]
    hsel = hit.astype(cache["k"].dtype)
    kc_new = jnp.einsum("bsl,bskd->blkd", hsel,
                        k.astype(cache["k"].dtype))
    vc_new = jnp.einsum("bsl,bskd->blkd", hsel,
                        v.astype(cache["v"].dtype))
    kc = jnp.where(any_hit[:, :, None, None], kc_new, cache["k"])
    vc = jnp.where(any_hit[:, :, None, None], vc_new, cache["v"])
    pos_new = jnp.einsum("bsl,bs->bl", hit.astype(jnp.int32), qpos)
    kv_pos = jnp.where(any_hit, pos_new, cache["kv_pos"])

    # mask from absolute positions (per span query)
    w = _window_of(cfg, ctx)
    valid = (kv_pos[:, None, :] >= 0) & \
        (kv_pos[:, None, :] <= qpos[:, :, None])              # [B, S, L]
    if w:
        valid &= kv_pos[:, None, :] > (qpos[:, :, None] - w)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    K = kc.shape[2]
    G = cfg.num_heads // K
    qg = (q * scale).reshape(B, S, K, G, -1)
    # bf16 operands + f32 accumulation: never materialize an f32 image of
    # the KV cache (it dominated decode HBM on the 100-layer VLM)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, S, cfg.num_heads, -1).astype(x.dtype)
    return attn_out(p, o), {"k": kc, "v": vc, "kv_pos": kv_pos}


# ============================ layer kinds ==================================

# ---- "attn": self-attention + dense FFN (pre-norm residual) ----

def init_attn_layer(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "ffn": init_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


ZERO_AUX = {"lb": 0.0, "z": 0.0}


def _zero_aux():
    return {"lb": jnp.zeros((), jnp.float32), "z": jnp.zeros((), jnp.float32)}


def attn_train(p, x, cfg, ctx):
    x = x + _self_attention_train(p["attn"], rms_norm(x, p["ln1"],
                                                      cfg.norm_eps), cfg, ctx)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), _zero_aux()


def attn_prefill(p, x, cfg, ctx):
    o, cache = _self_attention_prefill(p["attn"],
                                       rms_norm(x, p["ln1"], cfg.norm_eps),
                                       cfg, ctx)
    x = x + o
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), cache


def attn_decode(p, x, cache, cfg, ctx):
    o, cache = _self_attention_decode(p["attn"],
                                      rms_norm(x, p["ln1"], cfg.norm_eps),
                                      cache, cfg, ctx)
    x = x + o
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), cache


# ---- "moe": self-attention + MoE FFN ----

def init_moe_layer(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "moe": init_moe(ks[1], cfg, dtype),
    }


def moe_train(p, x, cfg, ctx):
    x = x + _self_attention_train(p["attn"], rms_norm(x, p["ln1"],
                                                      cfg.norm_eps), cfg, ctx)
    y, aux = moe_ffn(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return shard_hint(x + y, "act_bsd"), {"lb": aux["lb_loss"],
                                          "z": aux["z_loss"]}


def moe_prefill(p, x, cfg, ctx):
    o, cache = _self_attention_prefill(p["attn"],
                                       rms_norm(x, p["ln1"], cfg.norm_eps),
                                       cfg, ctx)
    x = x + o
    y, _ = moe_ffn(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return shard_hint(x + y, "act_bsd"), cache


def moe_decode(p, x, cache, cfg, ctx):
    o, cache = _self_attention_decode(p["attn"],
                                      rms_norm(x, p["ln1"], cfg.norm_eps),
                                      cache, cfg, ctx)
    x = x + o
    y, _ = moe_ffn(p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg)
    return shard_hint(x + y, "act_bsd"), cache


# ---- "cross": cross-attention to image/encoder tokens + FFN (VLM) ----

def init_cross_layer(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "ffn": init_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype),
        "gate": jnp.zeros((1,), dtype),      # tanh-gated residual
    }


def _cross_kv(p, mem, cfg):
    B, Sm, D = mem.shape
    K, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    k = mem @ p["wk"]
    v = mem @ p["wv"]
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    return k.reshape(B, Sm, K, Dh), v.reshape(B, Sm, K, Dh)


def _cross_attention(p, x, k, v, cfg):
    B, S, D = x.shape
    H, Dh = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, H, Dh)
    o = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return attn_out(p, o)


def cross_train(p, x, cfg, ctx):
    mem = ctx["image_embeds"] if "image_embeds" in ctx else ctx["enc_out"]
    k, v = _cross_kv(p["attn"], mem, cfg)
    g = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype)
    x = x + g * _cross_attention(p["attn"],
                                 rms_norm(x, p["ln1"], cfg.norm_eps),
                                 k, v, cfg)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), _zero_aux()


def cross_prefill(p, x, cfg, ctx):
    mem = ctx["image_embeds"] if "image_embeds" in ctx else ctx["enc_out"]
    k, v = _cross_kv(p["attn"], mem, cfg)
    g = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype)
    x = x + g * _cross_attention(p["attn"],
                                 rms_norm(x, p["ln1"], cfg.norm_eps),
                                 k, v, cfg)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), {"k": k, "v": v}


def cross_decode(p, x, cache, cfg, ctx):
    g = jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype)
    x = x + g * _cross_attention(p["attn"],
                                 rms_norm(x, p["ln1"], cfg.norm_eps),
                                 cache["k"], cache["v"], cfg)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, cache


# ---- "rec": RG-LRU recurrent block + FFN (RecurrentGemma) ----

def init_rec_layer(key, cfg, dtype):
    ks = split_keys(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "rec": init_rglru(ks[0], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "ffn": init_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def rec_train(p, x, cfg, ctx):
    x = x + rglru_train(p["rec"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), _zero_aux()


def rec_prefill(p, x, cfg, ctx):
    o, cache = rglru_prefill(p["rec"], rms_norm(x, p["ln1"], cfg.norm_eps),
                             cfg)
    x = x + o
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), cache


def rec_decode(p, x, cache, cfg, ctx):
    o, cache = rglru_decode(p["rec"], rms_norm(x, p["ln1"], cfg.norm_eps),
                            cache, cfg)
    x = x + o
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, cache


# ---- "ssm": Mamba2 block (no separate FFN; norm + SSD + residual) ----

def init_ssm_layer(key, cfg, dtype):
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ssm": init_ssm(key, cfg, dtype),
    }


def ssm_layer_train(p, x, cfg, ctx):
    return shard_hint(
        x + ssm_train(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg),
        "act_bsd"), _zero_aux()


def ssm_layer_prefill(p, x, cfg, ctx):
    o, cache = ssm_prefill(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps),
                           cfg)
    return shard_hint(x + o, "act_bsd"), cache


def ssm_layer_decode(p, x, cache, cfg, ctx):
    o, cache = ssm_decode(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps),
                          cache, cfg)
    return x + o, cache


# ---- "enc": non-causal encoder layer (Whisper encoder) ----

def init_enc_layer(key, cfg, dtype):
    return init_attn_layer(key, cfg, dtype)


def enc_train(p, x, cfg, ctx):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q, k, v = qkv_proj(p["attn"], h, cfg)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    x = x + attn_out(p["attn"], o)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), _zero_aux()


# ---- "dec": decoder layer with self + cross (Whisper decoder) ----

def init_dec_layer(key, cfg, dtype):
    ks = split_keys(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(ks[0], cfg, dtype),
        "lnx": jnp.ones((cfg.d_model,), dtype),
        "xattn": init_attention(ks[1], cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "ffn": init_ffn(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def dec_train(p, x, cfg, ctx):
    x = x + _self_attention_train(p["attn"],
                                  rms_norm(x, p["ln1"], cfg.norm_eps),
                                  cfg, ctx)
    k, v = _cross_kv(p["xattn"], ctx["enc_out"], cfg)
    x = x + _cross_attention(p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps),
                             k, v, cfg)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), _zero_aux()


def dec_prefill(p, x, cfg, ctx):
    o, self_cache = _self_attention_prefill(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg, ctx)
    x = x + o
    k, v = _cross_kv(p["xattn"], ctx["enc_out"], cfg)
    x = x + _cross_attention(p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps),
                             k, v, cfg)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return shard_hint(x, "act_bsd"), {"self": self_cache,
                                      "cross": {"k": k, "v": v}}


def dec_decode(p, x, cache, cfg, ctx):
    o, self_cache = _self_attention_decode(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cache["self"],
        cfg, ctx)
    x = x + o
    x = x + _cross_attention(p["xattn"], rms_norm(x, p["lnx"], cfg.norm_eps),
                             cache["cross"]["k"], cache["cross"]["v"], cfg)
    x = x + ffn(p["ffn"], rms_norm(x, p["ln2"], cfg.norm_eps))
    return x, {"self": self_cache, "cross": cache["cross"]}


# ============================ registry =====================================

KIND_INIT = {
    "attn": init_attn_layer,
    "moe": init_moe_layer,
    "cross": init_cross_layer,
    "rec": init_rec_layer,
    "ssm": init_ssm_layer,
    "enc": init_enc_layer,
    "dec": init_dec_layer,
}
KIND_TRAIN = {
    "attn": attn_train,
    "moe": moe_train,
    "cross": cross_train,
    "rec": rec_train,
    "ssm": ssm_layer_train,
    "enc": enc_train,
    "dec": dec_train,
}
KIND_PREFILL = {
    "attn": attn_prefill,
    "moe": moe_prefill,
    "cross": cross_prefill,
    "rec": rec_prefill,
    "ssm": ssm_layer_prefill,
    "dec": dec_prefill,
}
KIND_DECODE = {
    "attn": attn_decode,
    "moe": moe_decode,
    "cross": cross_decode,
    "rec": rec_decode,
    "ssm": ssm_layer_decode,
    "dec": dec_decode,
}
