"""Per-architecture sharding rules (pjit PartitionSpecs).

Mesh axes: ("data", "model") single-pod 16x16, ("pod", "data", "model")
multi-pod 2x16x16. The pod axis is pure data parallelism (batch sharded
over ("pod","data")).

Parameter rules (megatron-style tensor parallelism on "model"):
  * column-parallel (wq/wk/wv/w_gate/w_up/w_in/...): last dim on model
  * row-parallel (wo/w_down/w_out): contracted dim on model
  * MoE expert weights [E,D,F]: expert dim on model (expert parallelism)
  * embed [V,D] / lm_head [D,V]: vocab dim on model
  * 1-D params replicate; any non-divisible dim falls back to replicated
    (e.g. smollm's 15 heads on a 16-way model axis).

KV caches: batch on data; kv-head dim on model when divisible, otherwise
the cache *sequence* dim goes on model (flash-decode-style partial
attention — GSPMD inserts the softmax all-reduces).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(n, mesh, axis) -> bool:
    if isinstance(axis, tuple):
        size = int(np.prod([mesh.shape[a] for a in axis]))
    else:
        size = mesh.shape[axis]
    return n % size == 0


def _dp(mesh, n):
    """data axes if divisible, else fewer axes, else None."""
    axes = data_axes(mesh)
    if _div(n, mesh, tuple(axes)):
        return tuple(axes) if len(axes) > 1 else axes[0]
    if len(axes) > 1 and _div(n, mesh, axes[-1]):
        return axes[-1]
    return None


_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_gelu", "w_rec",
        "w_a", "w_i", "router")
_ROW = ("wo", "w_down", "w_out")


def param_spec(path_str: str, shape, mesh, fsdp: bool = False) -> P:
    """Sharding rule for one parameter leaf. Leaves under ['groups'] /
    ['encoder'] carry one leading layer-stack dim (never sharded).
    With fsdp=True, the largest remaining divisible dim is additionally
    sharded over the data axes (pjit-FSDP: GSPMD all-gathers at use
    sites) — required for the >=33B archs whose weights exceed HBM under
    tensor parallelism alone."""
    stacked = ("['groups']" in path_str) or ("['encoder']" in path_str)
    pre = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    name = path_str.rsplit("['", 1)[-1].rstrip("']")

    def mp(n):
        return "model" if _div(n, mesh, "model") else None

    spec = None
    is_moe = "['moe']" in path_str
    if len(core) <= 1:
        spec = [None] * len(core)
    elif is_moe and name in ("w_gate", "w_up", "w_down") and len(core) == 3:
        # Expert parallelism: E on model. (H2b tried contraction-dim-on-
        # model + E-on-data instead: collective term regressed 70->112 s
        # on kimi prefill — the reduce-scatter of the [B,E,C,F] hidden is
        # worse than the baseline flows. See EXPERIMENTS.md Perf H2.)
        spec = [mp(core[0]), None, None]
    elif name == "embed":
        spec = [mp(core[0]), None]
    elif name == "lm_head":
        spec = [None, mp(core[1])]
    elif name in _COL:
        spec = [None] * (len(core) - 1) + [mp(core[-1])]
    elif name in _ROW:
        spec = [None] * len(core)
        spec[-2] = mp(core[-2])
    elif name == "conv_w":
        spec = [None, mp(core[-1])]
    else:
        spec = [None] * len(core)

    if fsdp and len(core) >= 2:
        dpa = data_axes(mesh)
        dax = tuple(dpa) if len(dpa) > 1 else dpa[0]
        # Prefer sharding a NON-contracted dim: gathering the weight is a
        # small collective, while a sharded contraction dim makes GSPMD
        # all-reduce the (much larger) activation partial sums
        # (EXPERIMENTS.md Perf H2: 1.9 TB/device of all-reduce on kimi
        # prefill when expert D was the fsdp dim).
        contracted = None  # (H2a: excluding contraction dims measured
        # no change — GSPMD re-shards to its preferred strategy anyway)
        best = None
        for i, s in enumerate(spec):
            if s is None and i != contracted and \
                    _div(core[i], mesh, tuple(dpa)):
                if best is None or core[i] > core[best]:
                    best = i
        if best is None:
            for i, s in enumerate(spec):
                if s is None and _div(core[i], mesh, tuple(dpa)):
                    if best is None or core[i] > core[best]:
                        best = i
        if best is not None:
            spec[best] = dax
    return P(*pre, *spec)


def needs_fsdp(abstract_params, mesh, budget_bytes: float = 3.5e9) -> bool:
    """True when bf16 weights exceed `budget_bytes`/device under tensor
    parallelism alone."""
    total = sum(leaf.size * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
                for leaf in jax.tree.leaves(abstract_params))
    return total / mesh.shape["model"] > budget_bytes


def params_shardings(abstract_params, mesh, fsdp: bool = False):
    def rule(path, leaf):
        return NamedSharding(
            mesh, param_spec(jax.tree_util.keystr(path), leaf.shape, mesh,
                             fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def opt_state_shardings(abstract_opt, mesh, zero: bool = True):
    """Optimizer-moment shardings. With zero=True (ZeRO-1 style), each
    moment additionally shards its largest not-yet-sharded dim over the
    data axes — Adam moments dominate training memory at scale."""
    def rule(path, leaf):
        ps = jax.tree_util.keystr(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = list(param_spec(ps, leaf.shape, mesh))
        while len(spec) < leaf.ndim:
            spec.append(None)
        if zero:
            dpa = data_axes(mesh)
            free = [i for i, s in enumerate(spec) if s is None]
            # pick the largest divisible free dim
            best = None
            for i in free:
                if _div(leaf.shape[i], mesh, tuple(dpa)):
                    if best is None or leaf.shape[i] > leaf.shape[best]:
                        best = i
            if best is not None:
                spec[best] = tuple(dpa) if len(dpa) > 1 else dpa[0]
        return NamedSharding(mesh, P(*spec))

    def top(path, leaf):
        ps = jax.tree_util.keystr(path)
        if ps.startswith("['step']"):
            return NamedSharding(mesh, P())
        return rule(path, leaf)
    return jax.tree_util.tree_map_with_path(top, abstract_opt)


def batch_shardings(abstract_batch, mesh):
    def rule(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 0
        dp = _dp(mesh, b) if leaf.ndim else None
        return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
    return jax.tree_util.tree_map_with_path(rule, abstract_batch)


def cache_shardings(abstract_caches, mesh, cfg):
    """Caches are [count, B, ...] stacked trees."""
    def rule(path, leaf):
        ps = jax.tree_util.keystr(path)
        name = ps.rsplit("['", 1)[-1].rstrip("']")
        shape = leaf.shape
        if len(shape) < 2:
            return NamedSharding(mesh, P())
        B = shape[1]
        dp = _dp(mesh, B)
        if name in ("k", "v") and len(shape) == 5:
            _, _, L, K, Dh = shape
            if _div(K, mesh, "model"):
                return NamedSharding(mesh, P(None, dp, None, "model", None))
            if _div(L, mesh, "model"):
                # sequence-sharded cache (flash-decode style)
                return NamedSharding(mesh, P(None, dp, "model", None, None))
            if _div(Dh, mesh, "model"):
                return NamedSharding(mesh, P(None, dp, None, None, "model"))
            return NamedSharding(mesh, P(None, dp, None, None, None))
        if name == "kv_pos" and len(shape) == 3:
            return NamedSharding(mesh, P(None, dp, None))
        if name == "h" and len(shape) == 5:    # ssm state [c,B,Hs,N,P]
            Hs = shape[2]
            mp = "model" if _div(Hs, mesh, "model") else None
            return NamedSharding(mesh, P(None, dp, mp, None, None))
        if name == "h" and len(shape) == 3:    # rglru state [c,B,R]
            R = shape[2]
            mp = "model" if _div(R, mesh, "model") else None
            return NamedSharding(mesh, P(None, dp, mp))
        if name == "conv" and len(shape) == 4:
            C = shape[3]
            mp = "model" if _div(C, mesh, "model") else None
            return NamedSharding(mesh, P(None, dp, None, mp))
        return NamedSharding(mesh, P(*([None] * len(shape))))
    return jax.tree.map(
        lambda l: None, abstract_caches) if abstract_caches is None else \
        jax.tree_util.tree_map_with_path(rule, abstract_caches)


# ======================= serving tensor parallelism ========================
# The sharded serving engine promises token-for-token identical output
# to the single-device engine (docs/sharding.md), which constrains WHAT
# may be sharded. Measured on the CPU host-platform backend (bf16 demo
# decode, forced host devices):
#
#   * vocab-dim sharding is bit-exact: embed [V, D] on V (the gather's
#     masked-sum combine only ever adds the true value to zeros),
#     lm_head [D, V] on V (every shard computes its logit columns with
#     the full, un-split contraction over D), the packed mask store
#     [R, W] on W, and all elementwise mask math on the sharded vocab
#     axis;
#   * any trunk sharding is NOT: row-parallel wo/w_down partition the
#     contraction (partial dots + all-reduce reorder the fp summation;
#     logits drift ~3e-2 after two layers), and even head-aligned
#     wq/wk/wv or w_gate/w_up sharding with forced gather points before
#     the next contraction shifts attention/FFN outputs by one bf16 ulp
#     (the partitioned einsum's accumulation differs from the
#     single-device kernel's).
#
# So the serving default is VOCAB PARALLELISM: trunk + KV caches
# replicated, the grammar hot path — logits, packed mask rows, mask
# application — vocab-sharded, with ONE gather in the selector before
# the categorical draw. `trunk_shard=True` additionally applies the
# megatron-style `param_spec`/`cache_shardings` rules for TPU-scale
# serving, where per-device memory forces it and the bit-exactness
# gate does not apply.

def serving_param_spec(path_str: str, shape, mesh, cfg,
                       trunk_shard: bool = False) -> P:
    """Sharding rule for one serving param (vocab-parallel; see above)."""
    stacked = ("['groups']" in path_str) or ("['encoder']" in path_str)
    pre = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    name = path_str.rsplit("['", 1)[-1].rstrip("']")

    def mp(n):
        return "model" if _div(n, mesh, "model") else None

    if name == "embed" and len(core) == 2:
        return P(*pre, mp(core[0]), None)
    if name == "lm_head" and len(core) == 2:
        return P(*pre, None, mp(core[1]))
    if trunk_shard:
        return param_spec(path_str, shape, mesh)
    return P(*pre, *([None] * len(core)))


def serving_param_shardings(abstract_params, mesh, cfg,
                            trunk_shard: bool = False):
    def rule(path, leaf):
        return NamedSharding(
            mesh, serving_param_spec(jax.tree_util.keystr(path), leaf.shape,
                                     mesh, cfg, trunk_shard=trunk_shard))
    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def serving_cache_shardings(abstract_caches, mesh, cfg,
                            trunk_shard: bool = False):
    """KV caches/pools for the sharded engine. Bit-exact default:
    replicated (sharding the kv-head or sequence/page dims partitions
    the attention einsums, which is measurably not ulp-stable on the
    CPU backend). trunk_shard=True defers to `cache_shardings` — the
    dense [c,B,L,K,Dh] rule also covers the paged pools' [c,P,ps,K,Dh]
    leaves (kv-head dim on "model" when divisible)."""
    if abstract_caches is None:
        return None
    if trunk_shard:
        return cache_shardings(abstract_caches, mesh, cfg)
    return jax.tree.map(
        lambda l: NamedSharding(mesh, P(*([None] * l.ndim))),
        abstract_caches)


def serving_store_sharding(mesh, num_words: int):
    """Packed mask store [R, W]: uint32 word dim on "model" (the vocab
    axis at 1/32 scale) when divisible, else replicated."""
    wp = "model" if _div(num_words, mesh, "model") else None
    return NamedSharding(mesh, P(None, wp))


def serving_rules(mesh, cfg, trunk_shard: bool = False) -> dict:
    """shard_hint rules for the sharded serving engine (consumed inside
    `use_sharding`; see distributed/api.py). Replication rules are hard
    gather points: they force a sharded activation back to replicated
    before math that must stay bit-exact."""
    mp_v = "model" if _div(cfg.vocab_size, mesh, "model") else None
    kv_mp = "model" if trunk_shard and cfg.num_kv_heads and \
        _div(cfg.num_kv_heads, mesh, "model") else None
    return {
        "act_bsd": P(None, None, None),         # residual stream replicated
        "attn_kv": P(None, None, kv_mp, None),
        "logits_bsv": P(None, None, mp_v),
        "logits_bv": P(None, mp_v),
        # gather points guarding contractions over trunk-sharded dims
        # (no-ops in the vocab-parallel default, where the trunk is
        # replicated anyway)
        "attn_out_in": P(None, None, None),     # heads, before @ wo
        "ffn_hidden": P(None, None, None),      # d_ff, before @ w_down
        # the selector's single combine: replicate [B(*S), V] masked
        # logits once, right before the sort/cumsum/categorical draw
        # (a cumsum over a sharded vocab axis is NOT bit-exact)
        "sample_logits": P(None, None),
    }


def activation_rules(mesh, cfg, batch_size: int, seq_parallel: bool = False):
    """Logical-name rules consumed by shard_hint (distributed/api.py).

    seq_parallel=True shards the sequence dim of activations over
    `model` — the fallback parallelism when attention heads don't divide
    the model axis (e.g. smollm's 15 heads; hillclimb §Perf H1)."""
    dpa = _dp(mesh, batch_size)
    mp_v = "model" if _div(cfg.vocab_size, mesh, "model") else None
    mp_e = "model" if cfg.num_experts and _div(cfg.num_experts, mesh,
                                               "model") else None
    kv_mp = "model" if cfg.num_kv_heads and _div(cfg.num_kv_heads, mesh,
                                                 "model") else None
    rules = {
        "act_bsd": P(dpa, "model" if seq_parallel else None, None),
        # [B, S, K, Dh] K/V before blocked attention: heads on model when
        # divisible, otherwise explicitly replicated ONCE (H2c)
        "attn_kv": P(dpa, None, kv_mp, None),
        "logits_bsv": P(dpa, None, mp_v),
        "logits_bv": P(dpa, mp_v),
        # MoE dispatch/combine buffers [B,E,C,D]: sharded on batch + D —
        # NOT on E — so the index scatter (dispatch) and gather (combine)
        # are shard-local; the expert einsums against E-sharded weights
        # are where GSPMD inserts the expert-parallel collectives.
        "moe_becd": P(dpa, None, None,
                      "model" if _div(cfg.d_model, mesh, "model") else None),
    }
    return rules
