"""Per-architecture sharding rules (pjit PartitionSpecs).

Mesh axes: ("data", "model") single-pod 16x16, ("pod", "data", "model")
multi-pod 2x16x16. The pod axis is pure data parallelism (batch sharded
over ("pod","data")).

Parameter rules (megatron-style tensor parallelism on "model"):
  * column-parallel (wq/wk/wv/w_gate/w_up/w_in/...): last dim on model
  * row-parallel (wo/w_down/w_out): contracted dim on model
  * MoE expert weights [E,D,F]: expert dim on model (expert parallelism)
  * embed [V,D] / lm_head [D,V]: vocab dim on model
  * 1-D params replicate; any non-divisible dim falls back to replicated
    (e.g. smollm's 15 heads on a 16-way model axis).

KV caches: batch on data; kv-head dim on model when divisible, otherwise
the cache *sequence* dim goes on model (flash-decode-style partial
attention — GSPMD inserts the softmax all-reduces).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _div(n, mesh, axis) -> bool:
    if isinstance(axis, tuple):
        size = int(np.prod([mesh.shape[a] for a in axis]))
    else:
        size = mesh.shape[axis]
    return n % size == 0


def _dp(mesh, n):
    """data axes if divisible, else fewer axes, else None."""
    axes = data_axes(mesh)
    if _div(n, mesh, tuple(axes)):
        return tuple(axes) if len(axes) > 1 else axes[0]
    if len(axes) > 1 and _div(n, mesh, axes[-1]):
        return axes[-1]
    return None


_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_gelu", "w_rec",
        "w_a", "w_i", "router")
_ROW = ("wo", "w_down", "w_out")


def param_spec(path_str: str, shape, mesh, fsdp: bool = False) -> P:
    """Sharding rule for one parameter leaf. Leaves under ['groups'] /
    ['encoder'] carry one leading layer-stack dim (never sharded).
    With fsdp=True, the largest remaining divisible dim is additionally
    sharded over the data axes (pjit-FSDP: GSPMD all-gathers at use
    sites) — required for the >=33B archs whose weights exceed HBM under
    tensor parallelism alone."""
    stacked = ("['groups']" in path_str) or ("['encoder']" in path_str)
    pre = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    name = path_str.rsplit("['", 1)[-1].rstrip("']")

    def mp(n):
        return "model" if _div(n, mesh, "model") else None

    spec = None
    is_moe = "['moe']" in path_str
    if len(core) <= 1:
        spec = [None] * len(core)
    elif is_moe and name in ("w_gate", "w_up", "w_down") and len(core) == 3:
        # Expert parallelism: E on model. (H2b tried contraction-dim-on-
        # model + E-on-data instead: collective term regressed 70->112 s
        # on kimi prefill — the reduce-scatter of the [B,E,C,F] hidden is
        # worse than the baseline flows. See EXPERIMENTS.md Perf H2.)
        spec = [mp(core[0]), None, None]
    elif name == "embed":
        spec = [mp(core[0]), None]
    elif name == "lm_head":
        spec = [None, mp(core[1])]
    elif name in _COL:
        spec = [None] * (len(core) - 1) + [mp(core[-1])]
    elif name in _ROW:
        spec = [None] * len(core)
        spec[-2] = mp(core[-2])
    elif name == "conv_w":
        spec = [None, mp(core[-1])]
    else:
        spec = [None] * len(core)

    if fsdp and len(core) >= 2:
        dpa = data_axes(mesh)
        dax = tuple(dpa) if len(dpa) > 1 else dpa[0]
        # Prefer sharding a NON-contracted dim: gathering the weight is a
        # small collective, while a sharded contraction dim makes GSPMD
        # all-reduce the (much larger) activation partial sums
        # (EXPERIMENTS.md Perf H2: 1.9 TB/device of all-reduce on kimi
        # prefill when expert D was the fsdp dim).
        contracted = None  # (H2a: excluding contraction dims measured
        # no change — GSPMD re-shards to its preferred strategy anyway)
        best = None
        for i, s in enumerate(spec):
            if s is None and i != contracted and \
                    _div(core[i], mesh, tuple(dpa)):
                if best is None or core[i] > core[best]:
                    best = i
        if best is None:
            for i, s in enumerate(spec):
                if s is None and _div(core[i], mesh, tuple(dpa)):
                    if best is None or core[i] > core[best]:
                        best = i
        if best is not None:
            spec[best] = dax
    return P(*pre, *spec)


def needs_fsdp(abstract_params, mesh, budget_bytes: float = 3.5e9) -> bool:
    """True when bf16 weights exceed `budget_bytes`/device under tensor
    parallelism alone."""
    total = sum(leaf.size * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
                for leaf in jax.tree.leaves(abstract_params))
    return total / mesh.shape["model"] > budget_bytes


def params_shardings(abstract_params, mesh, fsdp: bool = False):
    def rule(path, leaf):
        return NamedSharding(
            mesh, param_spec(jax.tree_util.keystr(path), leaf.shape, mesh,
                             fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def opt_state_shardings(abstract_opt, mesh, zero: bool = True):
    """Optimizer-moment shardings. With zero=True (ZeRO-1 style), each
    moment additionally shards its largest not-yet-sharded dim over the
    data axes — Adam moments dominate training memory at scale."""
    def rule(path, leaf):
        ps = jax.tree_util.keystr(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = list(param_spec(ps, leaf.shape, mesh))
        while len(spec) < leaf.ndim:
            spec.append(None)
        if zero:
            dpa = data_axes(mesh)
            free = [i for i, s in enumerate(spec) if s is None]
            # pick the largest divisible free dim
            best = None
            for i in free:
                if _div(leaf.shape[i], mesh, tuple(dpa)):
                    if best is None or leaf.shape[i] > leaf.shape[best]:
                        best = i
            if best is not None:
                spec[best] = tuple(dpa) if len(dpa) > 1 else dpa[0]
        return NamedSharding(mesh, P(*spec))

    def top(path, leaf):
        ps = jax.tree_util.keystr(path)
        if ps.startswith("['step']"):
            return NamedSharding(mesh, P())
        return rule(path, leaf)
    return jax.tree_util.tree_map_with_path(top, abstract_opt)


def batch_shardings(abstract_batch, mesh):
    def rule(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 0
        dp = _dp(mesh, b) if leaf.ndim else None
        return NamedSharding(mesh, P(dp, *([None] * (leaf.ndim - 1))))
    return jax.tree_util.tree_map_with_path(rule, abstract_batch)


def cache_shardings(abstract_caches, mesh, cfg):
    """Caches are [count, B, ...] stacked trees."""
    def rule(path, leaf):
        ps = jax.tree_util.keystr(path)
        name = ps.rsplit("['", 1)[-1].rstrip("']")
        shape = leaf.shape
        if len(shape) < 2:
            return NamedSharding(mesh, P())
        B = shape[1]
        dp = _dp(mesh, B)
        if name in ("k", "v") and len(shape) == 5:
            _, _, L, K, Dh = shape
            if _div(K, mesh, "model"):
                return NamedSharding(mesh, P(None, dp, None, "model", None))
            if _div(L, mesh, "model"):
                # sequence-sharded cache (flash-decode style)
                return NamedSharding(mesh, P(None, dp, "model", None, None))
            if _div(Dh, mesh, "model"):
                return NamedSharding(mesh, P(None, dp, None, None, "model"))
            return NamedSharding(mesh, P(None, dp, None, None, None))
        if name == "kv_pos" and len(shape) == 3:
            return NamedSharding(mesh, P(None, dp, None))
        if name == "h" and len(shape) == 5:    # ssm state [c,B,Hs,N,P]
            Hs = shape[2]
            mp = "model" if _div(Hs, mesh, "model") else None
            return NamedSharding(mesh, P(None, dp, mp, None, None))
        if name == "h" and len(shape) == 3:    # rglru state [c,B,R]
            R = shape[2]
            mp = "model" if _div(R, mesh, "model") else None
            return NamedSharding(mesh, P(None, dp, mp))
        if name == "conv" and len(shape) == 4:
            C = shape[3]
            mp = "model" if _div(C, mesh, "model") else None
            return NamedSharding(mesh, P(None, dp, None, mp))
        return NamedSharding(mesh, P(*([None] * len(shape))))
    return jax.tree.map(
        lambda l: None, abstract_caches) if abstract_caches is None else \
        jax.tree_util.tree_map_with_path(rule, abstract_caches)


def activation_rules(mesh, cfg, batch_size: int, seq_parallel: bool = False):
    """Logical-name rules consumed by shard_hint (distributed/api.py).

    seq_parallel=True shards the sequence dim of activations over
    `model` — the fallback parallelism when attention heads don't divide
    the model axis (e.g. smollm's 15 heads; hillclimb §Perf H1)."""
    dpa = _dp(mesh, batch_size)
    mp_v = "model" if _div(cfg.vocab_size, mesh, "model") else None
    mp_e = "model" if cfg.num_experts and _div(cfg.num_experts, mesh,
                                               "model") else None
    kv_mp = "model" if cfg.num_kv_heads and _div(cfg.num_kv_heads, mesh,
                                                 "model") else None
    rules = {
        "act_bsd": P(dpa, "model" if seq_parallel else None, None),
        # [B, S, K, Dh] K/V before blocked attention: heads on model when
        # divisible, otherwise explicitly replicated ONCE (H2c)
        "attn_kv": P(dpa, None, kv_mp, None),
        "logits_bsv": P(dpa, None, mp_v),
        "logits_bv": P(dpa, mp_v),
        # MoE dispatch/combine buffers [B,E,C,D]: sharded on batch + D —
        # NOT on E — so the index scatter (dispatch) and gather (combine)
        # are shard-local; the expert einsums against E-sharded weights
        # are where GSPMD inserts the expert-parallel collectives.
        "moe_becd": P(dpa, None, None,
                      "model" if _div(cfg.d_model, mesh, "model") else None),
    }
    return rules
