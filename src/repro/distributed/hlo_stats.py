"""Collective-traffic accounting from compiled/optimized HLO text.

cost_analysis() has FLOPs and HBM bytes but no collective volume, so we
parse the partitioned HLO (shapes there are PER-DEVICE) and estimate wire
bytes per device with ring-algorithm factors:

  all-reduce        2(N-1)/N x bytes(result)
  all-gather        (N-1)/N  x bytes(result)
  reduce-scatter    (N-1)    x bytes(result)   (operand = N x result)
  all-to-all        (N-1)/N  x bytes(result)
  collective-permute 1       x bytes(result)

N = replica-group size parsed from the op's replica_groups attribute.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_OP_RE = re.compile(
    r"=\s+(?:\()?\s*((?:[a-z0-9]+\[[0-9,]*\][^\s]*|\([^)]*\)))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", )
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """-> {op_kind: {"count": int, "result_bytes": int, "wire_bytes": int},
          "total_wire_bytes": int}"""
    out = defaultdict(lambda: {"count": 0, "result_bytes": 0,
                               "wire_bytes": 0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        type_str, kind = m.group(1), m.group(2)
        rb = _shape_bytes(type_str)
        gm = _GROUPS_RE.search(line)
        if gm:
            ids = [x for x in gm.group(1).split(",") if x.strip()]
            n = max(2, len(ids))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = max(2, int(gi.group(2))) if gi else 2
        if kind == "all-reduce":
            wire = 2 * (n - 1) / n * rb
        elif kind == "all-gather":
            wire = (n - 1) / n * rb
        elif kind == "reduce-scatter":
            wire = (n - 1) * rb
        elif kind == "all-to-all":
            wire = (n - 1) / n * rb
        else:  # collective-permute
            wire = rb
        d = out[kind]
        d["count"] += 1
        d["result_bytes"] += rb
        d["wire_bytes"] += int(wire)
    result = {k: dict(v) for k, v in out.items()}
    result["total_wire_bytes"] = sum(v["wire_bytes"] for v in out.values())
    return result
