"""Roofline-term extraction from optimized (post-SPMD) HLO text.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE, so under
layer-scanned models it undercounts FLOPs/bytes/collectives by the trip
count (verified empirically; see EXPERIMENTS.md §Dry-run methodology).
This module parses the partitioned HLO and computes, per device:

  * flops       — MXU work: 2 x |result| x |contracting dims| per `dot`,
                  scaled by enclosing while-loop trip counts
  * hbm_bytes   — traffic model: per top-level instruction, result +
                  operand bytes (fusion internals assumed register/VMEM
                  resident), scaled by trip counts
  * collectives — wire bytes with ring factors (see hlo_stats), scaled by
                  trip counts

Trip counts come from the integer constant in each while-condition
computation (XLA emits `compare(iter, constant(N)), direction=LT`).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|body=)%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_INT_RE = re.compile(r"\bconstant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = ("parameter", "constant", "tuple(", "get-tuple-element",
                   "bitcast", "iota", "after-all", "partition-id",
                   "replica-id")


def _shapes(line: str):
    out = []
    for m in _SHAPE_RE.finditer(line):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        out.append((dt, n, [int(d) for d in dims.split(",") if d.strip()]))
    return out


def _split_computations(text: str) -> dict:
    comps = {}
    cur = None
    buf = []
    for line in text.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_START_RE.match(line.strip())
            if m:
                cur = m.group(1)
                buf = []
                continue
        if line.strip() == "}" and cur is not None:
            comps[cur] = buf
            cur = None
            continue
        if cur is not None:
            buf.append(line.strip())
    return comps


def _op_name(line: str):
    """Op name = first identifier after the (possibly tuple) result type."""
    eq = line.find("=")
    if eq < 0:
        return ""
    rest = line[eq + 1:].lstrip()
    if rest.startswith("("):
        depth = 0
        for i, c in enumerate(rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    rest = rest[i + 1:].lstrip()
                    break
    else:
        sp = rest.find(" ")
        rest = rest[sp + 1:].lstrip() if sp > 0 else ""
    m = re.match(r"([a-z][a-z0-9\-]*)\(", rest)
    return m.group(1) if m else ""


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=")
# lhs operand of a dot; newer XLA prints the operand type inline:
#   dot(%lhs, ...)   or   dot(f32[256,256]{1,0} %lhs, ...)
_DOT_OPERANDS_RE = re.compile(
    r"\bdot\((?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?\s+)?%([\w.\-]+),")


def _symtab(lines):
    """instruction name -> (dtype, elems, dims) of its (first) result."""
    tab = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        head = ln.split("=", 1)[1].split("(", 1)[0]
        sh = _shapes(head)
        if sh:
            tab[m.group(1)] = sh[0]
    return tab


def _dot_flops(line: str, symtab: dict) -> float:
    shapes = _shapes(line.split("=", 1)[1].split("(", 1)[0])
    if not shapes:
        return 0.0
    res_elems = shapes[0][1]
    om = _DOT_OPERANDS_RE.search(line)
    if not om or om.group(1) not in symtab:
        return 2.0 * res_elems  # unknown contraction: lower bound
    lhs_dims = symtab[om.group(1)][2]
    cm = _CONTRACT_RE.search(line)
    contract = 1
    if cm:
        for d in cm.group(1).split(","):
            if d.strip():
                contract *= lhs_dims[int(d)]
    return 2.0 * res_elems * contract


def _group_size(line: str) -> int:
    gm = _GROUPS_RE.search(line)
    if gm:
        ids = [x for x in gm.group(1).split(",") if x.strip()]
        return max(2, len(ids))
    gi = _GROUPS_IOTA_RE.search(line)
    return max(2, int(gi.group(2))) if gi else 2


def _collective_wire(kind: str, rb: float, n: int) -> float:
    if kind == "all-reduce":
        return 2 * (n - 1) / n * rb
    if kind == "all-gather":
        return (n - 1) / n * rb
    if kind == "reduce-scatter":
        return (n - 1) * rb
    if kind == "all-to-all":
        return (n - 1) / n * rb
    return rb  # collective-permute


class HloCost:
    def __init__(self, text: str):
        self.comps = _split_computations(text)
        self._trips: dict[str, int] = {}
        self._memo: dict[str, tuple] = {}
        # entry = computation containing a while/... choose the one not
        # referenced by others; XLA marks it ENTRY but we stripped that —
        # detect by "main" prefix fallback to the largest.
        refs = set()
        for name, lines in self.comps.items():
            for ln in lines:
                for cm in _CALL_RE.finditer(ln):
                    refs.add(cm.group(1))
                cc = _COND_RE.search(ln)
                if cc:
                    refs.add(cc.group(1))
        entries = [n for n in self.comps if n not in refs]
        self.entry = None
        for n in entries:
            if n.startswith("main") or ".main" in n:
                self.entry = n
        if self.entry is None and entries:
            self.entry = max(entries, key=lambda n: len(self.comps[n]))

    def _trip_count(self, cond_name: str) -> int:
        if cond_name in self._trips:
            return self._trips[cond_name]
        trips = 1
        for ln in self.comps.get(cond_name, []):
            for cm in _CONST_INT_RE.finditer(ln):
                trips = max(trips, int(cm.group(1)))
        self._trips[cond_name] = trips
        return trips

    def analyze(self, name: str | None = None) -> dict:
        name = name or self.entry
        if name in self._memo:
            return self._memo[name]
        flops = 0.0
        bytes_ = 0.0
        coll = defaultdict(lambda: {"count": 0.0, "wire_bytes": 0.0})
        lines = self.comps.get(name, [])
        symtab = _symtab(lines)
        for ln in lines:
            op = _op_name(ln)
            if op == "dot":
                flops += _dot_flops(ln, symtab)
            # bytes: skip no-traffic ops; while-loop traffic is accounted
            # by its body (counting the carry tuple here would double it).
            # Traffic model = result bytes per instruction (operand shapes
            # are not inline in optimized HLO; producers were counted when
            # defined). dynamic-update-slice aliases its big operand in
            # place — the written window was already counted at its
            # producer — so it contributes 0, not a full stacked-buffer
            # rewrite per layer-scan iteration.
            if op and op not in ("while", "dynamic-update-slice",
                                 "scatter") and \
                    not any(op.startswith(s.rstrip("(")) for s in
                            _SKIP_BYTES_OPS):
                bytes_ += sum(s[1] * _DTYPE_BYTES[s[0]]
                              for s in _shapes(ln))
            for ck in _COLLECTIVES:
                if op == ck or op == ck + "-start":
                    rb = sum(s[1] * _DTYPE_BYTES[s[0]]
                             for s in _shapes(ln.split("(", 1)[0]))
                    n = _group_size(ln)
                    coll[ck]["count"] += 1
                    coll[ck]["wire_bytes"] += _collective_wire(ck, rb, n)
            # recurse into calls
            if "while(" in ln:
                cm = _CALL_RE.search(ln)      # body=
                cond = _COND_RE.search(ln)
                trips = self._trip_count(cond.group(1)) if cond else 1
                if cm:
                    sub = self.analyze(cm.group(1))
                    flops += trips * sub["flops"]
                    bytes_ += trips * sub["hbm_bytes"]
                    for k, v in sub["collectives"].items():
                        coll[k]["count"] += trips * v["count"]
                        coll[k]["wire_bytes"] += trips * v["wire_bytes"]
            elif "fusion(" in ln or "to_apply=" in ln or " call(" in ln:
                cm = _CALL_RE.search(ln)
                if cm and cm.group(1) in self.comps:
                    sub = self.analyze(cm.group(1))
                    flops += sub["flops"]
                    # fusion internals don't touch HBM; bytes counted at
                    # the call site above. But nested collectives/dots do.
                    for k, v in sub["collectives"].items():
                        coll[k]["count"] += v["count"]
                        coll[k]["wire_bytes"] += v["wire_bytes"]
        res = {"flops": flops, "hbm_bytes": bytes_,
               "collectives": {k: dict(v) for k, v in coll.items()}}
        res["wire_bytes"] = sum(v["wire_bytes"]
                                for v in res["collectives"].values())
        self._memo[name] = res
        return res


def roofline_counts(hlo_text: str) -> dict:
    return HloCost(hlo_text).analyze()


def estimate_jit_cost(fn, *args, **kwargs) -> dict:
    """Static per-call roofline terms for a jitted fn at these
    arguments: {flops, hbm_bytes, wire_bytes, collectives}, parsed from
    the compiled (post-SPMD) HLO. Compiles at the same shapes the caller
    will run — reuses the persistent compilation cache, so after the
    first real call this costs only the lowering walk. Raises whatever
    lower()/compile() raises; callers that probe opportunistically (the
    engine's devtime cost registration) catch and skip."""
    compiled = fn.lower(*args, **kwargs).compile()
    return roofline_counts(compiled.as_text())


_WIDEN_RE = re.compile(
    r"%wrapped_convert[\w.]*\s*=\s*f32\[([0-9,]+)\][^=]*fusion\(")


def bf16_widening_correction(hlo_text: str, min_bytes: int = 32 << 20) -> int:
    """Bytes over-reported by the CPU backend's bf16->f32 widening of
    while-loop tensors (wrapped_convert fusions producing big f32 copies
    of bf16 loop state). The TPU backend keeps these in bf16, so the
    corrected temp estimate subtracts half of each widened f32 buffer.
    Returns the total number of bytes to subtract."""
    saved = 0
    for m in _WIDEN_RE.finditer(hlo_text):
        n = 1
        for d in m.group(1).split(","):
            if d.strip():
                n *= int(d)
        b = n * 4
        if b >= min_bytes:
            saved += b // 2
    return saved
