"""Logical sharding hints.

Model code calls `shard_hint(x, "logical_name")`; outside a sharding
context this is the identity (smoke tests, CPU serving). Inside
`use_sharding(mesh, rules)` (set up by the launcher) it becomes
`lax.with_sharding_constraint` with the rule's PartitionSpec — keeping
mesh-axis names out of model code.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules():
    return getattr(_state, "ctx", None)


@contextlib.contextmanager
def use_sharding(mesh, rules: dict):
    """rules: logical name -> PartitionSpec."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules)
    try:
        yield
    finally:
        _state.ctx = prev


def shard_hint(x, name: str):
    ctx = _rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.get(name)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_active() -> bool:
    """True inside a `use_sharding` context (trace-time query). Kernel
    dispatchers use it to route around Pallas bodies, which GSPMD cannot
    partition, onto the jnp references it can."""
    return _rules() is not None


def current_mesh():
    """The active `use_sharding` mesh, or None."""
    ctx = _rules()
    return None if ctx is None else ctx[0]
