"""Speculation scheduler: per-slot state machine + ragged span planning.

Every engine step, each active slot is planned into one of four phases:

  JUMPING   — the grammar forced >= 1 token this step; they are committed
              host-side (zero model calls) and queued for cache replay.
  DRAFTING  — the proposer drafted tokens that survived the grammar
              oracle; they ride the span for verification.
  VERIFYING — the slot contributed drafts to the current span device call
              (set while the fused [B, S, V] decode+mask+select runs).
  DECODING  — nothing speculative this step: the slot advances one token
              exactly like the plain batched engine.

The scheduler never talks to the device: it owns the per-request draft
proposers, runs the jump analyzer, oracle-filters drafts, and hands the
serving engine a `SlotPlan` per slot. The engine packs plans into a
bucketed [B, S] span (padding gated off via the model's feed_mask) so
speculating and plain-decoding slots share one device call per step —
neither stalls the other.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum

from repro.core.tokenizer import EOS_ID
from .jump import jump_forward
from .proposer import make_proposer

# span-width buckets the engine jits against: ragged per-slot feeds are
# padded up to the smallest bucket that fits the widest slot, so at most
# len(SPAN_BUCKETS) specializations of the span functions ever compile
SPAN_BUCKETS = (1, 2, 4, 8, 16)


class SlotPhase(str, Enum):
    DECODING = "decoding"
    JUMPING = "jumping"
    DRAFTING = "drafting"
    VERIFYING = "verifying"
    PREFILLING = "prefilling"   # paged engine: prompt backlog (chunked
                                # prefill) or waiting on shared pages
                                # another slot is still filling


@dataclass
class SpecConfig:
    """Knobs for grammar-aware speculative decoding."""
    jump: bool = True            # forced-continuation (jump-forward) engine
    literal_jump: bool = False   # byte-level forced literals, canonically
                                 # re-tokenized (longer jumps; trades exact
                                 # plain-engine token equivalence — output
                                 # bytes stay grammar-forced and valid)
    draft: bool = True           # draft-verify engine
    draft_k: int = 4             # max draft tokens per slot per step
    max_jump: int = 16           # max forced tokens committed per step
                                 # (jumped tokens drain through the span
                                 # as backlog, so this does not bound the
                                 # span width)
    proposer: str = "sam"        # "sam" (suffix automaton) | "ngram"
    ngram_n: int = 4             # context cap for the ngram proposer
    min_match: int = 2           # min history-suffix match before drafting
    draft_backoff: int = 8       # max steps to pause drafting after a
                                 # fully-rejected window (doubles per miss)

    def __post_init__(self):
        span_max = SPAN_BUCKETS[-1]
        if self.draft_k + 1 > span_max:
            raise ValueError(
                f"draft_k + 1 must fit the widest span bucket "
                f"({span_max}); got {self.draft_k} + 1")


@dataclass
class SlotPlan:
    """One slot's contribution to the current engine step."""
    jumped: list = field(default_factory=list)  # committed by jump-forward
    drafts: list = field(default_factory=list)  # uncommitted, oracle-vetted
    phase: SlotPhase = SlotPhase.DECODING
    stop_mask: object = None   # StepMask for the first selection position
                               # (reused from the jump analysis)


class SpecScheduler:
    """Owns proposers + planning; one instance per engine generate call."""

    def __init__(self, cfg: SpecConfig, tokenizer, telemetry=None):
        self.cfg = cfg
        self.tok = tokenizer
        self._proposers: dict = {}           # rid -> proposer
        self._backoff: dict = {}             # rid -> [skip_steps, misses]
        self._c_plans = None                 # phase -> Counter
        self._c_backoff = None
        if telemetry is not None:
            reg = telemetry.registry
            self._c_plans = {
                ph.value: reg.counter(
                    "repro_spec_plans_total",
                    "slot plans per step by resulting phase",
                    {"phase": ph.value})
                for ph in (SlotPhase.DECODING, SlotPhase.JUMPING,
                           SlotPhase.DRAFTING)}
            self._c_backoff = reg.counter(
                "repro_spec_backoff_entries_total",
                "fully-rejected draft windows that triggered backoff")

    # ------------------------- request lifecycle -------------------------

    def on_admit(self, st) -> None:
        """Seed the slot's proposer with its prompt tokens (drafts may
        copy continuations that started inside the prompt)."""
        p = make_proposer(self.cfg.proposer, self.cfg.ngram_n,
                          self.cfg.min_match)
        p.extend(int(t) for t in st.token_ids)
        self._proposers[st.req.rid] = p
        self._backoff[st.req.rid] = [0, 0]

    def on_commit(self, st, tokens) -> None:
        """Feed committed tokens (jump + accepted + bonus) to the
        proposer so future drafts can reference them."""
        p = self._proposers.get(st.req.rid)
        if p is not None:
            p.extend(int(t) for t in tokens if t != EOS_ID)

    def on_verify(self, st, proposed: int, accepted: int) -> None:
        """Adaptive drafting: a fully-rejected window pauses drafting for
        this slot (exponential backoff, capped), any acceptance resets —
        so low-acceptance regimes stop paying the oracle-filter tax."""
        bo = self._backoff.get(st.req.rid)
        if bo is None or proposed == 0:
            return
        if accepted > 0:
            bo[0] = bo[1] = 0
        else:
            bo[1] = min(bo[1] + 1, 30)
            bo[0] = min(1 << (bo[1] - 1), self.cfg.draft_backoff)
            if self._c_backoff is not None:
                self._c_backoff.inc()

    def on_finish(self, st) -> None:
        self._proposers.pop(st.req.rid, None)
        self._backoff.pop(st.req.rid, None)

    # ----------------------------- planning ------------------------------

    def _budget(self, st, max_len: int) -> int:
        """Tokens this slot may still commit (length + cache caps)."""
        return max(0, min(st.req.max_new_tokens - st.steps,
                          (max_len - 1) - st.pos))

    def plan_slot(self, st, commit, max_len: int,
                  backlog: int = 0) -> SlotPlan:
        plan = self._plan_slot(st, commit, max_len, backlog)
        if self._c_plans is not None:
            c = self._c_plans.get(plan.phase.value)
            if c is not None:
                c.inc()
        return plan

    def _plan_slot(self, st, commit, max_len: int,
                   backlog: int = 0) -> SlotPlan:
        """Plan one slot for this step. `commit(st, token)` is the
        engine's commit hook (updates steps/stats/text); jump-forward
        tokens are committed here, before any device work.

        backlog > 0 means earlier-committed tokens are still draining
        through the span (the slot cannot select this step): planning is
        skipped — the frontier text is unchanged, so a jump re-analysis
        would find exactly what the previous one already reported."""
        plan = SlotPlan()
        cfg = self.cfg
        if backlog > 0:
            return plan

        # ---- jump-forward: grammar-forced run, zero model calls ----
        if cfg.jump and st.constraint is not None and not st.done:
            budget = min(cfg.max_jump, self._budget(st, max_len))
            if budget > 0:
                jr = jump_forward(st.constraint, st.generated, budget,
                                  literal=cfg.literal_jump)
                for t in jr.tokens:
                    if st.done:
                        break
                    st.jump_tokens += 1
                    commit(st, t)
                    plan.jumped.append(t)
                plan.stop_mask = jr.stop_mask
                if jr.eos and not st.done:
                    st.jump_tokens += 1
                    commit(st, EOS_ID)
                if jr.dead_end and not st.done:
                    st.done = True
                    st.finish_reason = "mask_exhausted"
                if plan.jumped or jr.eos:
                    plan.phase = SlotPhase.JUMPING

        if st.done:
            return plan

        # ---- draft-verify: oracle-filtered proposer drafts ----
        if cfg.draft:
            bo = self._backoff.get(st.req.rid)
            if bo is not None and bo[0] > 0:
                bo[0] -= 1                     # backed off: skip drafting
                return plan
            k = min(cfg.draft_k,
                    self._budget(st, max_len) - 1)   # leave room for bonus
            plan.drafts = self._draft(st, k)
            if plan.drafts:
                plan.phase = SlotPhase.DRAFTING
        return plan

    def _draft(self, st, k: int) -> list:
        if k <= 0:
            return []
        prop = self._proposers.get(st.req.rid)
        if prop is None:
            return []
        out = []
        text = st.generated
        for t in prop.propose(k):
            t = int(t)
            tb = self.tok.id_to_bytes[t] if t < len(self.tok.id_to_bytes) \
                else b""
            if not tb:                         # specials never draft
                break
            if st.constraint is not None and \
                    not st.constraint.is_valid_extension(text, t):
                break
            out.append(t)
            text += tb
        return out
