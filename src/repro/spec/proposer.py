"""Host-side draft proposers for draft-verify speculation.

Structured generation is self-similar — JSON keys, SQL column lists and
code idioms repeat within one response — so the cheapest useful draft
model is the slot's *own* emitted history: find the longest suffix of the
history that occurred earlier, and propose whatever followed it then
(prompt-lookup / lookahead-style drafting, no neural draft model).

Two implementations share the interface {``append(token)``,
``propose(k) -> list[int]``, per-request lifetime}:

  * `SuffixAutomatonProposer` — an online suffix automaton over the
    token stream. `append` is amortized O(1); `propose` walks the suffix
    link chain of the last state to the deepest state whose first
    occurrence ended before the current position, i.e. the LONGEST
    previously-seen suffix, with no fixed n-gram cap.
  * `NGramProposer` — a bounded-n last-occurrence hash index; simpler,
    fixed O(max_n) per append/propose.

Proposers never see the grammar: drafts are filtered against the exact
parser oracle by the scheduler before they reach the verify pass.
"""
from __future__ import annotations


class _SamState:
    __slots__ = ("len", "link", "next", "first_end")

    def __init__(self, length: int, link: int, first_end: int):
        self.len = length
        self.link = link
        self.next = {}
        self.first_end = first_end


class SuffixAutomatonProposer:
    """Online suffix automaton over a slot's emitted token ids.

    min_match: shortest previously-seen suffix worth drafting from —
    1-token coincidences draft mostly-rejected continuations."""

    def __init__(self, min_match: int = 1):
        self.min_match = min_match
        self.states = [_SamState(0, -1, -1)]
        self.last = 0
        self.history: list[int] = []

    # ---- classic SAM extend (Blumer et al.), with first_end tracking ----
    def append(self, token: int) -> None:
        self.history.append(token)
        end = len(self.history) - 1
        sts = self.states
        cur = len(sts)
        sts.append(_SamState(sts[self.last].len + 1, -1, end))
        p = self.last
        while p != -1 and token not in sts[p].next:
            sts[p].next[token] = cur
            p = sts[p].link
        if p == -1:
            sts[cur].link = 0
        else:
            q = sts[p].next[token]
            if sts[p].len + 1 == sts[q].len:
                sts[cur].link = q
            else:
                clone = len(sts)
                cs = _SamState(sts[p].len + 1, sts[q].link,
                               sts[q].first_end)
                cs.next = dict(sts[q].next)
                sts.append(cs)
                while p != -1 and sts[p].next.get(token) == q:
                    sts[p].next[token] = clone
                    p = sts[p].link
                sts[q].link = clone
                sts[cur].link = clone
        self.last = cur

    def extend(self, tokens) -> None:
        for t in tokens:
            self.append(t)

    def match_len(self) -> int:
        """Length of the longest suffix of the history that also occurs
        earlier (0 if none)."""
        st = self._earlier_state()
        return self.states[st].len if st else 0

    def _earlier_state(self) -> int:
        """Deepest suffix-link ancestor of `last` whose first occurrence
        ended before the current end — i.e. the longest suffix with an
        earlier occurrence. 0 (root) means no such suffix."""
        n = len(self.history)
        p = self.last
        while p != -1 and self.states[p].first_end >= n - 1:
            p = self.states[p].link
        return max(p, 0)

    def propose(self, k: int) -> list:
        if k <= 0 or len(self.history) < 2:
            return []
        st = self._earlier_state()
        if st == 0 or self.states[st].len < self.min_match:
            return []
        cont = self.states[st].first_end + 1   # index after the earlier hit
        return self.history[cont: cont + k]


class NGramProposer:
    """Last-occurrence n-gram index (bounded context, O(max_n) updates)."""

    def __init__(self, max_n: int = 4, min_match: int = 1):
        self.max_n = max_n
        self.min_match = max(1, min_match)
        self.history: list[int] = []
        self._index: dict = {}     # ngram tuple -> position AFTER occurrence

    def append(self, token: int) -> None:
        self.history.append(token)
        h = self.history
        i = len(h) - 1             # continuations of grams ending at i-1
        for L in range(1, self.max_n + 1):
            if i - L < 0:
                break
            self._index[tuple(h[i - L: i])] = i

    def extend(self, tokens) -> None:
        for t in tokens:
            self.append(t)

    def propose(self, k: int) -> list:
        h = self.history
        n = len(h)
        if k <= 0 or n < 2:
            return []
        for L in range(min(self.max_n, n - 1), self.min_match - 1, -1):
            pos = self._index.get(tuple(h[n - L:]))
            if pos is not None and pos < n:
                return h[pos: pos + k]
        return []


def make_proposer(kind: str = "sam", ngram_n: int = 4, min_match: int = 1):
    if kind == "sam":
        return SuffixAutomatonProposer(min_match=min_match)
    if kind == "ngram":
        return NGramProposer(max_n=ngram_n, min_match=min_match)
    raise ValueError(f"unknown proposer kind: {kind}")
