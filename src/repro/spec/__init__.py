"""Grammar-aware speculative decoding.

Two cooperating engines ride the continuous-batching serving pool:

  * **jump-forward** (`jump.py`) — when the DFA mask store says the
    grammar admits exactly one next token, that token is emitted with
    zero model calls (the model forward only replays it for cache
    consistency, batched into the next span step);
  * **draft-verify** (`proposer.py` + the engine's span path) — a cheap
    host-side proposer drafts K tokens from the slot's own history,
    the grammar filters them, and one fused [B, K+1, V] model + mask
    pass accepts the longest valid prefix.

`scheduler.py` assembles per-slot plans (JUMPING / DRAFTING / VERIFYING /
DECODING) into ragged span batches so speculating and plain-decoding
slots share one device call per step.
"""
from .jump import JumpResult, forced_literal, jump_forward, retokenize_aligned
from .proposer import NGramProposer, SuffixAutomatonProposer, make_proposer
from .scheduler import SlotPhase, SlotPlan, SpecConfig, SpecScheduler

__all__ = [
    "JumpResult", "jump_forward", "forced_literal", "retokenize_aligned",
    "NGramProposer", "SuffixAutomatonProposer", "make_proposer",
    "SlotPhase", "SlotPlan", "SpecConfig", "SpecScheduler",
]
