"""Jump-forward: forced-continuation analysis over the DFA mask store.

In structured outputs the grammar frequently determines the next token
outright — JSON punctuation, keyword tails (`tru` → `e`), mandatory
quotes. The paper's mask store already knows this: when the union of the
step's mask rows has popcount 1 (and EOS is not simultaneously legal),
the masked distribution has a single support point, so ANY selector —
greedy, temperature, top-k/p — must pick it. `jump_forward` chains that
observation: it walks `GrammarConstraint.forced_step` until the grammar
stops forcing, emitting the whole run with zero model forward passes.

Soundness w.r.t. the tokenizer: each forced token is re-checked against
the exact parser oracle (`is_valid_extension`) before it is emitted, so a
mask over-approximation can never smuggle in an invalid token. The
emitted ids are exactly what the plain engine's masked argmax would have
produced (single support point), which is what makes greedy speculative
decoding token-for-token identical to the plain batched engine.

`forced_literal` recovers byte-level forcing the token popcount misses
(many prefix-nested tokens, one shared first byte). In literal mode the
forced literal is emitted as its STANDALONE canonical tokenization
(`tokenizer.encode(literal)`), never as a re-encoding of prefix+literal:
re-encoding could merge a token across the injection point and
retroactively change already-emitted history. `retokenize_aligned` is
the diagnostic for exactly that hazard — when it reports misalignment,
the standalone encoding the engine emits is the same locally-greedy
boundary the plain engine would have produced, just not the globally
canonical one.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.constrain import GrammarConstraint
from repro.core.tokenizer import ByteTokenizer


@dataclass
class JumpResult:
    tokens: list = field(default_factory=list)   # forced token ids, in order
    text: bytes = b""                            # their concatenated bytes
    eos: bool = False       # EOS itself is forced after `tokens`
    dead_end: bool = False  # mask empty, EOS disallowed (engine stops slot)
    stop_mask: object = None  # StepMask at the stop point ("free" only):
                              # the engine reuses it as the first
                              # selection position's rows, so the jump
                              # analysis costs no extra step_rows

    def __len__(self):
        return len(self.tokens)


def jump_forward(gc: GrammarConstraint, text: bytes, max_tokens: int,
                 literal: bool = False) -> JumpResult:
    """Chase forced continuations from `text` for up to `max_tokens`
    emitted tokens.

    Default mode emits only tokens with mask-union popcount 1 (single
    support point of the masked distribution): every selector would pick
    them, so greedy speculative decoding stays token-for-token identical
    to the plain engine.

    literal=True additionally chases byte-level forcing: when the mask
    still holds several tokens but they all START with the same byte
    (prefix-nested merges: 'n'/'na'/'name'), the byte — and often a whole
    literal like '"name":' — is grammar-determined even though the
    tokenization is not. The forced literal is re-tokenized standalone
    with the canonical maximal-munch encoder (see the module docstring
    for why not prefix+literal) and each canonical token is re-validated
    against the exact oracle before emission. This emits more tokens per
    jump (XGrammar-style context expansion) at the price of exact
    plain-engine equivalence: the engine would have spelled the same
    BYTES with a possibly different token split.
    """
    res = JumpResult()
    cur = text
    while True:
        kind, tok, sm = gc.forced_step(cur)
        if kind == "token" and len(res.tokens) < max_tokens:
            res.tokens.append(tok)
            tb = gc.tokenizer.id_to_bytes[tok]
            res.text += tb
            cur += tb
            continue
        if kind == "free" and literal and len(res.tokens) < max_tokens:
            lit = forced_literal(
                gc, cur, max_bytes=4 * (max_tokens - len(res.tokens)),
                first_mask=sm)
            # standalone canonical tokenization tiles the literal
            # exactly, and every literal prefix is in L_p(G) by
            # construction of the byte chain; the (incremental, cheap)
            # oracle re-check below is the belt-and-suspenders the rest
            # of the engine applies to every mask-derived decision
            ids = gc.tokenizer.encode(lit) if lit else []
            emitted = 0
            for t in ids:
                if len(res.tokens) >= max_tokens or \
                        not gc.is_valid_extension(cur, t):
                    break
                tb = gc.tokenizer.id_to_bytes[t]
                res.tokens.append(t)
                res.text += tb
                cur += tb
                emitted += 1
            if emitted == len(ids) and emitted > 0:
                continue            # forcing may resume past the literal
            if emitted:
                break               # partial literal: mask at cur unknown
            # nothing emitted: text unchanged, fall through (sm valid)
        res.eos = kind == "eos"
        res.dead_end = kind == "dead"
        if kind in ("free", "token"):
            # "token" here = budget exhausted mid-run: sm is the (forced)
            # mask at the stop text, still the right selection rows
            res.stop_mask = sm
        break
    return res


def forced_literal(gc: GrammarConstraint, text: bytes,
                   max_bytes: int = 256, first_mask=None) -> bytes:
    """The grammar-forced continuation of `text` as a BYTE string.

    Per step, unions the mask rows and asks the store which FIRST bytes
    the allowed tokens span (`MaskStore.allowed_first_bytes`); exactly
    one surviving byte means every valid tokenization starts with it, so
    it is appended and the walk repeats. Stops at the first real branch,
    at an EOS-legal point (the output may end instead of continuing), or
    at `max_bytes`. `first_mask` reuses an already-computed StepMask for
    the first step."""
    out = b""
    cur = text
    sm = first_mask
    while len(out) < max_bytes:
        if sm is None:
            sm = gc.step_rows(cur)
        if sm.eos_allowed:
            break
        fb = gc.store.allowed_first_bytes(gc.union_packed(sm))
        nz = np.nonzero(fb)[0]
        if nz.size != 1:
            break
        out += bytes([int(nz[0])])
        cur = text + out
        sm = None
    return out


def retokenize_aligned(tok: ByteTokenizer, prefix_ids: list,
                       literal: bytes) -> list | None:
    """Detokenize–retokenize realignment check for a forced literal.

    Encodes (decoded prefix + literal) with the canonical maximal-munch
    encoder and checks the canonical stream preserves `prefix_ids` as an
    exact prefix. Returns the canonical token ids for `literal` if the
    boundary is stable, else None — the merge table fused a token across
    the injection point, so no continuation tokenization can make the
    full stream canonical. `jump_forward` sidesteps the hazard by always
    emitting the STANDALONE encoding of the literal (locally greedy from
    the boundary — the same boundary the plain engine produces when it
    samples token-by-token); this check is the diagnostic/test oracle
    for that reasoning, quantifying how often a jump lands on a
    non-canonical boundary.
    """
    prefix_bytes = b"".join(tok.id_to_bytes[int(t)] for t in prefix_ids
                            if int(t) >= tok.num_special)
    canon = tok.encode(prefix_bytes + literal)
    pref = [int(t) for t in prefix_ids if int(t) >= tok.num_special]
    if canon[: len(pref)] != pref:
        return None
    return canon[len(pref):]
