"""Pallas TPU kernel: fused mask-row gather + bitwise union + logits mask.

This is the paper's accelerator offload (§3.2 "parallelizing ... by
offloading them to a GPU") adapted to TPU:

  * mask rows stay bit-PACKED (uint32, 32 tokens/word) end-to-end; the
    union is a bitwise-OR over a [A, BV/32] VMEM tile (one 128-lane
    vector op per word-block) and bits are tested in-register while the
    logits tile streams through VMEM — the [V] boolean mask never touches
    HBM.
  * the row ids are scalar-prefetched (PrefetchScalarGridSpec) so the
    store row for grid step (b, a) is selected by the BlockSpec
    index_map — the TPU-idiomatic dynamic gather.

Grid: (B, V_blocks, A) with A innermost; the output logits block
(b, vblk) is revisited across a, accumulating the union in a VMEM
scratch, and the masked logits are written on the last a step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(rows_ref,            # scalar-prefetch [B, A] int32
            eos_ref,             # scalar-prefetch [B] int32
            logits_ref,          # [1, BV]
            store_ref,           # [1, BW] uint32 (row selected by index_map)
            cd_ref,              # [1, BW] uint32 context-dependent overlay
            out_ref,             # [1, BV]
            acc_ref,             # scratch [1, BW] uint32
            *, eos_id: int, num_accept: int, block_v: int):
    b = pl.program_id(0)
    vblk = pl.program_id(1)
    a = pl.program_id(2)

    @pl.when(a == 0)
    def _init():
        # seed the union with the context-split residue overlay: the
        # host's few per-step bits ride in with zero extra grid steps
        acc_ref[...] = cd_ref[...]

    rid = rows_ref[b, a]
    word = jnp.where(rid >= 0, store_ref[...], jnp.uint32(0))
    acc_ref[...] |= word

    @pl.when(a == num_accept - 1)
    def _finish():
        words = acc_ref[0, :]                       # [BW] uint32
        # unpack: bit j of word w guards vocab index 32*w + j
        idx = jax.lax.broadcasted_iota(jnp.int32, (block_v,), 0)
        wsel = words[idx // 32]
        bit = (wsel >> (idx % 32).astype(jnp.uint32)) & jnp.uint32(1)
        allow = bit == jnp.uint32(1)
        # EOS override
        gpos = vblk * block_v + idx
        allow |= (gpos == eos_id) & (eos_ref[b] > 0)
        lg = logits_ref[0, :]
        out_ref[0, :] = jnp.where(allow, lg,
                                  jnp.asarray(NEG_INF, lg.dtype))


def _kernel_span(rows_ref,           # scalar-prefetch [B, K, A] int32
                 eos_ref,            # scalar-prefetch [B, K] int32
                 logits_ref,         # [1, 1, BV]
                 store_ref,          # [1, BW] uint32 (row via index_map)
                 cd_ref,             # [1, 1, BW] uint32 overlay
                 out_ref,            # [1, 1, BV]
                 acc_ref,            # scratch [1, BW] uint32
                 *, eos_id: int, num_accept: int, block_v: int):
    """Speculation variant: one grid step per (slot b, span position k,
    vocab block, accept row). Same packed-union-in-VMEM scheme as
    `_kernel`, with the extra span axis so a draft-verify pass masks all
    K positions of every slot in one launch."""
    b = pl.program_id(0)
    k = pl.program_id(1)
    vblk = pl.program_id(2)
    a = pl.program_id(3)

    @pl.when(a == 0)
    def _init():
        acc_ref[...] = cd_ref[0, ...]

    rid = rows_ref[b, k, a]
    word = jnp.where(rid >= 0, store_ref[...], jnp.uint32(0))
    acc_ref[...] |= word

    @pl.when(a == num_accept - 1)
    def _finish():
        words = acc_ref[0, :]
        idx = jax.lax.broadcasted_iota(jnp.int32, (block_v,), 0)
        wsel = words[idx // 32]
        bit = (wsel >> (idx % 32).astype(jnp.uint32)) & jnp.uint32(1)
        allow = bit == jnp.uint32(1)
        gpos = vblk * block_v + idx
        allow |= (gpos == eos_id) & (eos_ref[b, k] > 0)
        lg = logits_ref[0, 0, :]
        out_ref[0, 0, :] = jnp.where(allow, lg,
                                     jnp.asarray(NEG_INF, lg.dtype))


@functools.partial(jax.jit, static_argnames=("eos_id", "block_v",
                                             "interpret"))
def masked_logits_span(logits, store, rows, eos_allowed, cd, *,
                       eos_id: int = 1, block_v: int = 4096,
                       interpret: bool = True):
    """logits [B,K,V], store [R,W] uint32, rows [B,K,A] int32,
    eos_allowed [B,K] bool, cd [B,K,W] uint32 -> [B,K,V] masked logits.

    The [B,K,V] span form of `masked_logits` used by grammar-aware
    speculative decoding: position k of slot b carries its own mask-row
    set (the hypothetical prefix after accepting k draft tokens), and the
    whole draft window is masked in one fused device call."""
    B, K, V = logits.shape
    R, W = store.shape
    A = rows.shape[2]
    block_v = min(block_v, V)
    assert V % block_v == 0 and block_v % 32 == 0, (V, block_v)
    bw = block_v // 32
    nv = V // block_v

    grid = (B, K, nv, A)
    kernel = functools.partial(_kernel_span, eos_id=eos_id, num_accept=A,
                               block_v=block_v)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_v),
                             lambda b, k, v, a, rows, eos: (b, k, v)),
                pl.BlockSpec(
                    (1, bw),
                    lambda b, k, v, a, rows, eos: (
                        jnp.maximum(rows[b, k, a], 0), v)),
                pl.BlockSpec((1, 1, bw),
                             lambda b, k, v, a, rows, eos: (b, k, v)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_v),
                                   lambda b, k, v, a, rows, eos: (b, k, v)),
            scratch_shapes=[pltpu.VMEM((1, bw), jnp.uint32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, V), logits.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(rows.astype(jnp.int32), eos_allowed.astype(jnp.int32), logits, store,
      cd)
    return out


@functools.partial(jax.jit, static_argnames=("eos_id", "block_v",
                                             "interpret"))
def masked_logits(logits, store, rows, eos_allowed, cd, *, eos_id: int = 1,
                  block_v: int = 4096, interpret: bool = True):
    """logits [B,V], store [R,W] uint32, rows [B,A] int32,
    eos_allowed [B] bool, cd [B,W] uint32 -> [B,V] masked logits."""
    B, V = logits.shape
    R, W = store.shape
    A = rows.shape[1]
    block_v = min(block_v, V)
    assert V % block_v == 0 and block_v % 32 == 0, (V, block_v)
    bw = block_v // 32
    nv = V // block_v

    grid = (B, nv, A)
    kernel = functools.partial(_kernel, eos_id=eos_id, num_accept=A,
                               block_v=block_v)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, block_v), lambda b, v, a, rows, eos: (b, v)),
                pl.BlockSpec(
                    (1, bw),
                    lambda b, v, a, rows, eos: (jnp.maximum(rows[b, a], 0), v)),
                pl.BlockSpec((1, bw), lambda b, v, a, rows, eos: (b, v)),
            ],
            out_specs=pl.BlockSpec((1, block_v),
                                   lambda b, v, a, rows, eos: (b, v)),
            scratch_shapes=[pltpu.VMEM((1, bw), jnp.uint32)],
        ),
        out_shape=jax.ShapeDtypeStruct((B, V), logits.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(rows.astype(jnp.int32), eos_allowed.astype(jnp.int32), logits, store,
      cd)
    return out
