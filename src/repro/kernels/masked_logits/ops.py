"""Public op: grammar-mask application on device.

`apply_grammar_mask` dispatches to the Pallas kernel (TPU target;
interpret=True executes the kernel body on CPU for validation) or the
pure-jnp reference — selected by `backend`.

`constrained` [B] bool (optional) lets one fused call serve a mixed batch:
rows where it is False pass through unmasked (the batched engine keeps
unconstrained requests in the same decode pool as constrained ones).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import masked_logits, masked_logits_span
from .ref import masked_logits_ref, masked_logits_span_ref
from ...distributed.api import sharding_active


def apply_grammar_mask(logits, store, rows, eos_allowed, *, eos_id: int = 1,
                       backend: str = "auto", block_v: int = 4096,
                       constrained=None, cd=None):
    """backend: 'pallas' | 'jnp' | 'auto' (pallas-interpret off-TPU).

    `cd` [B, W] uint32 (optional): context-split residue words ORed
    into the row union (see core/constrain.py).

    Under an active serving sharding context the jnp reference is used
    regardless of backend: GSPMD cannot partition a pallas_call, while
    the reference's gather + bitwise-or + where partition cleanly along
    the vocab-sharded store words (docs/sharding.md)."""
    if backend == "jnp" or sharding_active():
        return masked_logits_ref(logits, store, rows, eos_allowed,
                                 eos_id=eos_id, constrained=constrained,
                                 cd=cd)
    interpret = jax.default_backend() != "tpu"
    if backend == "auto" and interpret and logits.shape[-1] > 16384:
        # interpret-mode is slow for big vocabs; use the oracle off-TPU
        return masked_logits_ref(logits, store, rows, eos_allowed,
                                 eos_id=eos_id, constrained=constrained,
                                 cd=cd)
    if cd is None:
        cd = jnp.zeros((logits.shape[0], store.shape[1]), jnp.uint32)
    out = masked_logits(logits, store, rows, eos_allowed, cd, eos_id=eos_id,
                        block_v=min(block_v, logits.shape[-1]),
                        interpret=interpret)
    if constrained is not None:
        out = jnp.where(constrained[:, None], out, logits)
    return out


def apply_grammar_mask_span(logits, store, rows, eos_allowed, *,
                            eos_id: int = 1, backend: str = "auto",
                            block_v: int = 4096, constrained=None, cd=None):
    """Span ([B,K,V]) form of `apply_grammar_mask` for grammar-aware
    speculative decoding: every draft position carries its own mask-row
    set, so mask + accept-test run fused on device over the whole draft
    window. `constrained` [B,K] bool marks positions that actually carry
    a grammar mask (padding / unconstrained positions pass through).
    Routes to the jnp reference under an active sharding context (see
    `apply_grammar_mask`)."""
    if backend == "jnp" or sharding_active():
        return masked_logits_span_ref(logits, store, rows, eos_allowed,
                                      eos_id=eos_id, constrained=constrained,
                                      cd=cd)
    interpret = jax.default_backend() != "tpu"
    if backend == "auto" and interpret and logits.shape[-1] > 16384:
        return masked_logits_span_ref(logits, store, rows, eos_allowed,
                                      eos_id=eos_id, constrained=constrained,
                                      cd=cd)
    if cd is None:
        cd = jnp.zeros(logits.shape[:2] + (store.shape[1],), jnp.uint32)
    out = masked_logits_span(logits, store, rows, eos_allowed, cd,
                             eos_id=eos_id,
                             block_v=min(block_v, logits.shape[-1]),
                             interpret=interpret)
    if constrained is not None:
        out = jnp.where(constrained[:, :, None], out, logits)
    return out
