"""Pure-jnp oracle for the masked_logits kernel.

Semantics: for each batch row b, union the packed mask-store rows
`rows[b, :]` (int32 row ids, -1 = padding), unpack the resulting bitmask,
and replace logits outside the mask with NEG_INF. `eos_allowed[b]`
additionally opens the EOS position (paper: EOS is legal iff C_k ∈ L(G),
decided host-side by the parser).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def masked_logits_ref(logits, store, rows, eos_allowed, eos_id: int = 1,
                      constrained=None, cd=None):
    """logits [B,V], store [R,W] uint32, rows [B,A] int32,
    eos_allowed [B] bool -> masked logits [B,V].

    `constrained` [B] bool (optional): rows where it is False pass through
    unmasked — the batched engine mixes constrained and unconstrained
    requests in one fused call.

    `cd` [B,W] uint32 (optional): the context-split residue overlay —
    per-slot packed words ORed into the row union (the host computed
    only these few context-dependent bits; everything else comes from
    the precomputed rows)."""
    B, V = logits.shape
    safe = jnp.maximum(rows, 0)
    gathered = store[safe]                                   # [B,A,W]
    gathered = jnp.where((rows >= 0)[..., None], gathered, jnp.uint32(0))
    words = jax.lax.reduce(gathered, jnp.uint32(0), jnp.bitwise_or,
                           dimensions=(1,))                  # [B,W]
    if cd is not None:
        words = words | cd
    bits = (words[:, :, None] >> jnp.arange(32, dtype=jnp.uint32)) & \
        jnp.uint32(1)
    mask = bits.reshape(B, -1)[:, :V].astype(bool)
    mask = mask.at[:, eos_id].set(mask[:, eos_id] | eos_allowed)
    if constrained is not None:
        mask = mask | ~constrained[:, None]
    return jnp.where(mask, logits, jnp.asarray(NEG_INF, logits.dtype))


def masked_logits_span_ref(logits, store, rows, eos_allowed, eos_id: int = 1,
                           constrained=None, cd=None):
    """[B,K,V] span form (draft-verify speculation): position k of slot b
    has its own row set / eos flag / constrained flag / cd overlay.
    Delegates to the [B,V] reference on the flattened (b, k) axis so the
    two paths stay numerically identical by construction."""
    B, K, V = logits.shape
    out = masked_logits_ref(
        logits.reshape(B * K, V), store, rows.reshape(B * K, -1),
        eos_allowed.reshape(B * K), eos_id=eos_id,
        constrained=None if constrained is None
        else constrained.reshape(B * K),
        cd=None if cd is None else cd.reshape(B * K, -1))
    return out.reshape(B, K, V)
