"""Public op: fused grammar-mask + filter + sample on device.

`fused_mask_select` turns a decode step's (logits, precomputed row ids,
residue words, per-slot decode configs) into selected token ids — and
the masked logits, which the engine's opportunistic accept/resample
paths reuse — in ONE device call.

Dispatch mirrors the sibling kernels: the Pallas kernel runs for the
noise/greedy variants off-sharding (interpret=True executes the kernel
body on CPU for validation); the jnp reference handles the `keys`
variant (vmapped `jax.random.categorical` belongs in XLA, not a
kernel body), active sharding contexts (GSPMD cannot partition a
pallas_call; the reference partitions cleanly and keeps the
"sample_logits" combine hint), explicit `backend="jnp"`, and the
big-vocab interpret guard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import fused_select
from .ref import fused_select_ref, gumbel_noise  # noqa: F401 (re-export)
from ...distributed.api import sharding_active


def fused_mask_select(logits, store, rows, cd, eos_allowed, constrained,
                      greedy_flags, temperature, top_k, top_p, *,
                      keys=None, noise=None, eos_id: int = 1,
                      backend: str = "auto"):
    """-> (ids [B] int32, masked [B, V]).

    Sampling input: `keys` [B, 2] (legacy categorical streams), `noise`
    [B, V] precomputed Gumbel noise, or neither (all-greedy batch).
    All three select bit-identical tokens for identical configs
    (tests/test_fused_select.py)."""
    if (keys is not None or backend == "jnp" or sharding_active()
            or (backend == "auto"
                and jax.default_backend() != "tpu"
                and logits.shape[-1] > 16384)):
        return fused_select_ref(logits, store, rows, cd, eos_allowed,
                                constrained, greedy_flags, temperature,
                                top_k, top_p, keys=keys, noise=noise,
                                eos_id=eos_id)
    interpret = jax.default_backend() != "tpu"
    if cd is None:
        cd = jnp.zeros((logits.shape[0], store.shape[1]), jnp.uint32)
    mode = "greedy" if noise is None else "sample"
    if noise is None:
        noise = jnp.zeros(logits.shape, jnp.float32)
    return fused_select(logits, store, rows, cd, eos_allowed, constrained,
                        greedy_flags, temperature, top_k, top_p, noise,
                        eos_id=eos_id, mode=mode, interpret=interpret)


def fused_mask_select_span(logits, store, rows, cd, eos_allowed,
                           constrained, greedy_flags, temperature, top_k,
                           top_p, *, keys=None, noise=None, eos_id: int = 1,
                           backend: str = "auto"):
    """Span ([B, S, V]) form for speculative verification: every draft
    position carries its own row set / residue / eos / constrained
    flag; the per-slot decode configs broadcast across the span.
    Flattens (b, s) and delegates — numerically identical to the batch
    form by construction. Returns (ids [B, S], masked [B, S, V])."""
    B, S, V = logits.shape
    rep = lambda a: jnp.repeat(a, S, axis=0)
    ids, masked = fused_mask_select(
        logits.reshape(B * S, V), store, rows.reshape(B * S, -1),
        None if cd is None else cd.reshape(B * S, -1),
        eos_allowed.reshape(B * S), constrained.reshape(B * S),
        rep(greedy_flags), rep(temperature), rep(top_k), rep(top_p),
        keys=None if keys is None else keys.reshape(B * S, 2),
        noise=None if noise is None else noise.reshape(B * S, V),
        eos_id=eos_id, backend=backend)
    return ids.reshape(B, S), masked.reshape(B, S, V)
