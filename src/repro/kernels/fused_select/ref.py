"""Pure-jnp oracle for the fused mask+filter+sample kernel.

One call takes a decode step from raw logits to selected token ids:
packed mask-row union (CI row gather + CD residue overlay) → EOS /
unconstrained handling → temperature scaling → `topk_topp_filter` →
greedy argmax or categorical sample. The reference is the COMPOSITION
of the legacy pieces (`masked_logits_ref` + `select_batch`), so its
outputs are bit-identical to the pre-fusion pipeline by construction —
the Pallas kernel is fuzzed against it (tests/test_fused_select.py).

Two sampling inputs are supported:

  * `keys` [B, 2] uint32 — the legacy path: per-slot
    `jax.random.categorical` streams (vmapped).
  * `noise` [B, V] f32 — precomputed standard-Gumbel noise (see
    `gumbel_noise`). `categorical(key, logits)` IS
    `argmax(logits + gumbel(key))`, so `argmax(filtered + noise)` with
    `noise = gumbel(key, (V,))` selects the *bit-identical* token while
    moving the PRNG work off the mask-time critical path (the engine
    dispatches noise generation speculatively at the end of the
    previous step's resolve).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..masked_logits.ref import masked_logits_ref
from ...core.decoding import select_batch, topk_topp_filter


def gumbel_noise(keys, vocab_size: int) -> jnp.ndarray:
    """[B, V] f32 standard-Gumbel noise, one stream per slot — exactly
    the noise `jax.vmap(jax.random.categorical)(keys, ...)` would draw,
    so argmax(filtered + noise) reproduces the sampled ids bitwise."""
    return jax.vmap(
        lambda k: jax.random.gumbel(k, (vocab_size,), jnp.float32))(keys)


def fused_select_ref(logits, store, rows, cd, eos_allowed, constrained,
                     greedy_flags, temperature, top_k, top_p, *,
                     keys=None, noise=None, eos_id: int = 1):
    """Reference fused step: -> (ids [B] int32, masked [B, V]).

    Exactly one of `keys` / `noise` must be given unless every row is
    greedy (both None). Returns the masked logits too: the engine's
    opportunistic accept test and resample ban-list path both need
    them."""
    masked = masked_logits_ref(logits, store, rows, eos_allowed,
                               eos_id=eos_id, constrained=constrained,
                               cd=cd)
    if keys is not None:
        return select_batch(masked, keys, greedy_flags, temperature,
                            top_k, top_p), masked
    from repro.distributed.api import shard_hint
    hinted = shard_hint(masked, "sample_logits")
    arg = jnp.argmax(hinted, axis=-1).astype(jnp.int32)
    if noise is None:
        # all-greedy host-static variant: no filter, no PRNG
        return arg, masked
    scaled = hinted / jnp.maximum(temperature, 1e-6)[:, None]
    scaled = topk_topp_filter(scaled, top_k, top_p)
    sampled = jnp.argmax(scaled + noise, axis=-1)
    return jnp.where(greedy_flags, arg, sampled).astype(jnp.int32), masked
