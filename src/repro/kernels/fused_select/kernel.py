"""Pallas TPU kernel: fused mask union + filter + sample — ONE device
call takes a decode step from raw logits to selected token ids.

Grid: (B, A) with the accept-row axis innermost. Each (b, a) step ORs
one scalar-prefetch-selected packed store row into a VMEM accumulator
that was SEEDED with the slot's context-dependent residue words (the
context split means the host ships only those few bits; everything
else is a precomputed row id). On the last accept step the union is
unpacked in-register against the whole vocab block and the select
math runs fused:

    masked   = where(allow, logits, NEG_INF)
    greedy   = argmax(masked)
    filtered = topk_topp_filter(masked / temp)     (shared impl!)
    sampled  = argmax(filtered + gumbel_noise)

`topk_topp_filter` is imported from `core.decoding` — the SAME
function the batched reference selector uses, so kept-token sets are
identical by construction. The Gumbel-noise argmax IS
`jax.random.categorical` (categorical(key, x) == argmax(x + gumbel)),
with the noise precomputed off the critical path; parity with the
keys-based reference is fuzz-tested bit-for-bit.

The kernel emits BOTH the selected ids and the masked logits — the
engine's opportunistic accept test and its resample/ban path reuse the
masked logits without a second mask pass.

`mode` is host-static: "greedy" skips the filter/noise math entirely
(an all-greedy batch does no sort), "sample" runs the full path and
resolves per-row greedy flags with a where.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams as _CompilerParams
from ...core.decoding import topk_topp_filter

NEG_INF = -1e30


def _kernel(rows_ref,            # scalar-prefetch [B, A] int32
            eos_ref,             # scalar-prefetch [B] int32
            cons_ref,            # scalar-prefetch [B] int32
            greedy_ref,          # scalar-prefetch [B] int32
            logits_ref,          # [1, V]
            store_ref,           # [1, W] uint32 (row selected by index_map)
            cd_ref,              # [1, W] uint32 residue overlay
            noise_ref,           # [1, V] f32 Gumbel noise
            temp_ref,            # [1, 1] f32
            topk_ref,            # [1, 1] i32
            topp_ref,            # [1, 1] f32
            ids_ref,             # out [1, 1] int32
            masked_ref,          # out [1, V]
            acc_ref,             # scratch [1, W] uint32
            *, eos_id: int, num_accept: int, vocab: int, mode: str):
    b = pl.program_id(0)
    a = pl.program_id(1)

    @pl.when(a == 0)
    def _init():
        acc_ref[...] = cd_ref[...]

    rid = rows_ref[b, a]
    acc_ref[...] |= jnp.where(rid >= 0, store_ref[...], jnp.uint32(0))

    @pl.when(a == num_accept - 1)
    def _finish():
        words = acc_ref[0, :]
        idx = jax.lax.broadcasted_iota(jnp.int32, (vocab,), 0)
        wsel = words[idx // 32]
        bit = (wsel >> (idx % 32).astype(jnp.uint32)) & jnp.uint32(1)
        allow = bit == jnp.uint32(1)
        allow |= (idx == eos_id) & (eos_ref[b] > 0)
        allow |= cons_ref[b] == 0
        lg = logits_ref[0, :]
        masked = jnp.where(allow, lg, jnp.asarray(NEG_INF, lg.dtype))
        masked_ref[0, :] = masked
        arg = jnp.argmax(masked).astype(jnp.int32)
        if mode == "greedy":
            ids_ref[0, 0] = arg
        else:
            scaled = masked / jnp.maximum(temp_ref[0, 0], 1e-6)
            scaled = topk_topp_filter(scaled, topk_ref[0, 0],
                                      topp_ref[0, 0])
            sampled = jnp.argmax(scaled + noise_ref[0, :]).astype(jnp.int32)
            ids_ref[0, 0] = jnp.where(greedy_ref[b] > 0, arg, sampled)


@functools.partial(jax.jit, static_argnames=("eos_id", "mode", "interpret"))
def fused_select(logits, store, rows, cd, eos_allowed, constrained,
                 greedy_flags, temperature, top_k, top_p, noise, *,
                 eos_id: int = 1, mode: str = "sample",
                 interpret: bool = True):
    """logits [B,V], store [R,W] uint32, rows [B,A] int32 (-1 pad),
    cd [B,W] uint32, eos/constrained/greedy [B] bool, temperature/top_p
    [B] f32, top_k [B] i32, noise [B,V] f32 -> (ids [B] i32,
    masked [B,V])."""
    B, V = logits.shape
    R, W = store.shape
    A = rows.shape[1]
    assert V % 32 == 0, V

    grid = (B, A)
    kernel = functools.partial(_kernel, eos_id=eos_id, num_accept=A,
                               vocab=V, mode=mode)
    ids, masked = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, V), lambda b, a, *pf: (b, 0)),
                pl.BlockSpec(
                    (1, W),
                    lambda b, a, rows, *pf: (jnp.maximum(rows[b, a], 0), 0)),
                pl.BlockSpec((1, W), lambda b, a, *pf: (b, 0)),
                pl.BlockSpec((1, V), lambda b, a, *pf: (b, 0)),
                pl.BlockSpec((1, 1), lambda b, a, *pf: (b, 0)),
                pl.BlockSpec((1, 1), lambda b, a, *pf: (b, 0)),
                pl.BlockSpec((1, 1), lambda b, a, *pf: (b, 0)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1), lambda b, a, *pf: (b, 0)),
                pl.BlockSpec((1, V), lambda b, a, *pf: (b, 0)),
            ],
            scratch_shapes=[pltpu.VMEM((1, W), jnp.uint32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, V), logits.dtype),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(rows.astype(jnp.int32), eos_allowed.astype(jnp.int32),
      constrained.astype(jnp.int32), greedy_flags.astype(jnp.int32),
      logits, store, cd, noise,
      temperature.reshape(B, 1).astype(jnp.float32),
      top_k.reshape(B, 1).astype(jnp.int32),
      top_p.reshape(B, 1).astype(jnp.float32))
    return ids[:, 0], masked
