"""Shared Pallas TPU compat shims for the kernel packages."""
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; accept both
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
