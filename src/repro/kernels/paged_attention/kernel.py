"""Pallas TPU kernel: page-table attention for the paged KV-cache engine.

A slot's KV lives scattered across a global page pool
`[num_pages, page_size, K, Dh]`; its page table `[nP]` (int32, -1 =
unmapped) names the pages that make up its logical sequence. The kernel
streams the slot's pages into VMEM via scalar-prefetched BlockSpec
index_maps (`pool` block for grid step (b, j) is page
`page_table[b, j]` — the TPU-idiomatic dynamic gather, same scheme as
`masked_logits`), then runs ONE exact softmax over the assembled
`[L, K, Dh]` KV buffer on the last page step.

Doing the softmax once over the gathered buffer (instead of an online
softmax per page) costs L·K·Dh·2 words of VMEM scratch — fine for
serving-length sequences — and buys bit-exactness with the jnp
reference and the dense decode path: the compute phase uses the
REFERENCE'S einsum specs with only the leading batch dim peeled off
("qkgd,skd->kgqs" / "kgqs,skd->qkgd"), which XLA lowers to the same
per-element contractions (verified down to S = 1, where a per-head
dot_general would take a differently-rounded gemv path).

Grid: (B, nP) with nP innermost ("arbitrary"); q/out blocks revisit b
across the page steps; compute fires on the last one. Two entry points
share the body: `paged_attention_decode` ([B, 1] queries, the plain
engine step) and `paged_attention_span` ([B, S], the speculative /
chunked-prefill span step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(pt_ref,              # scalar-prefetch [B, nP] int32 page table
            pos_ref,             # scalar-prefetch [B] int32 start positions
            q_ref,               # [1, S, H, Dh]
            k_ref,               # [1, ps, K, Dh]  (page pt[b, j])
            v_ref,               # [1, ps, K, Dh]
            o_ref,               # [1, S, H, Dh]
            kbuf, vbuf,          # VMEM [L, K, Dh] gathered KV
            map_ref,             # VMEM [1, L] int32 page-mapped flags
            *, page_size: int, num_pages: int, span: int, groups: int):
    b = pl.program_id(0)
    j = pl.program_id(1)
    ps = page_size

    # ---- gather phase: copy page j into its slice of the KV buffer ----
    kbuf[pl.ds(j * ps, ps)] = k_ref[0]
    vbuf[pl.ds(j * ps, ps)] = v_ref[0]
    mapped = (pt_ref[b, j] >= 0).astype(jnp.int32)
    map_ref[0, pl.ds(j * ps, ps)] = mapped * jnp.ones((ps,), jnp.int32)

    # ---- compute phase: one exact softmax over the whole buffer ----
    @pl.when(j == num_pages - 1)
    def _compute():
        L = num_pages * ps
        S, H, Dh = q_ref.shape[1:]
        K = kbuf.shape[1]
        G = groups
        scale = 1.0 / (Dh ** 0.5)
        qg = (q_ref[0] * scale).reshape(S, K, G, Dh)
        s = jnp.einsum("qkgd,skd->kgqs", qg, kbuf[...],
                       preferred_element_type=jnp.float32)
        qpos = pos_ref[b] + jax.lax.broadcasted_iota(
            jnp.int32, (span, L), 0)
        lpos = jax.lax.broadcasted_iota(jnp.int32, (span, L), 1)
        valid = (map_ref[0, :][None, :] > 0) & (lpos <= qpos)
        s = jnp.where(valid[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("kgqs,skd->qkgd", p.astype(vbuf.dtype), vbuf[...],
                       preferred_element_type=jnp.float32)
        o_ref[0] = o.reshape(S, H, Dh).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_span(q, k_pool, v_pool, page_table, pos, *,
                         interpret: bool = True):
    """q [B,S,H,Dh] (roped, unscaled); k_pool/v_pool [P,ps,K,Dh];
    page_table [B,nP] int32 (-1 = unmapped); pos [B] int32 absolute start
    positions -> [B,S,H,Dh]. Full causal attention; GQA via the in-cell
    group reshape (kv head = h // G)."""
    B, S, H, Dh = q.shape
    P, ps, K, _ = k_pool.shape
    nP = page_table.shape[1]
    L = nP * ps

    kernel = functools.partial(_kernel, page_size=ps, num_pages=nP,
                               span=S, groups=H // K)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nP),
            in_specs=[
                pl.BlockSpec((1, S, H, Dh),
                             lambda b, j, pt, pos: (b, 0, 0, 0)),
                pl.BlockSpec(
                    (1, ps, K, Dh),
                    lambda b, j, pt, pos: (
                        jnp.maximum(pt[b, j], 0), 0, 0, 0)),
                pl.BlockSpec(
                    (1, ps, K, Dh),
                    lambda b, j, pt, pos: (
                        jnp.maximum(pt[b, j], 0), 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, S, H, Dh),
                                   lambda b, j, pt, pos: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((L, K, Dh), k_pool.dtype),
                pltpu.VMEM((L, K, Dh), v_pool.dtype),
                pltpu.VMEM((1, L), jnp.int32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, H, Dh), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32), q, k_pool, v_pool)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_decode(q, k_pool, v_pool, page_table, pos, *,
                           interpret: bool = True):
    """Decode ([B, 1]) variant: q [B,H,Dh] -> [B,H,Dh]."""
    return paged_attention_span(q[:, None], k_pool, v_pool, page_table,
                                pos, interpret=interpret)[:, 0]
