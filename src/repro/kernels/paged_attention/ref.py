"""Pure-jnp reference for paged attention: gather-then-attend.

The reference reconstructs the dense cache view of a slot from its page
table (`pool[page_table[b]]` → `[B, L, K, Dh]` with L = n_pages·ps) and
then runs EXACTLY the einsum/softmax sequence of the dense decode path
(`layers._self_attention_decode`) on it — same einsum specs, same mask
constant, same dtypes — so paged attention is bit-identical to the dense
engine's attention, and the Pallas kernel has an executable oracle.

Position convention: the token stored at (page_table[b, j], o) sits at
absolute position j·ps + o of slot b's logical sequence; validity needs
no kv_pos array — entry l is attendable iff its page is mapped
(page_table ≥ 0) and l ≤ q_pos (positions beyond the frontier hold
stale/unwritten data and are masked, which is also what rolls back
rejected speculative writes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, page_table, pos):
    """q [B,S,H,Dh] (roped, unscaled); k_pool/v_pool [P,ps,K,Dh];
    page_table [B,nP] int32 (-1 = unmapped); pos [B] int32 absolute start
    positions (span query i of slot b sits at pos[b] + i).
    Returns [B,S,H,Dh] in q.dtype. Full causal attention (no sliding
    window — the paged engine is gated to window-free archs)."""
    P, ps, K, Dh = k_pool.shape
    B, S, H, _ = q.shape
    nP = page_table.shape[1]
    L = nP * ps
    G = H // K

    safe = jnp.maximum(page_table, 0)                        # [B, nP]
    kc = k_pool[safe].reshape(B, L, K, Dh)
    vc = v_pool[safe].reshape(B, L, K, Dh)

    qpos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]  # [B,S]
    idx = jnp.arange(L, dtype=jnp.int32)                     # absolute pos
    mapped = jnp.repeat(page_table >= 0, ps, axis=1)         # [B, L]
    valid = mapped[:, None, :] & \
        (idx[None, None, :] <= qpos[:, :, None])             # [B, S, L]

    # identical math to layers._self_attention_decode (bit-exact twin)
    scale = 1.0 / (Dh ** 0.5)
    qg = (q * scale).reshape(B, S, K, G, -1)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc,
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr.astype(vc.dtype), vc,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, Dh).astype(q.dtype)


def paged_attention_decode_ref(q, k_pool, v_pool, page_table, pos):
    """Decode ([B,1]) convenience wrapper: q [B,H,Dh] -> [B,H,Dh]."""
    return paged_attention_ref(q[:, None], k_pool, v_pool, page_table,
                               pos)[:, 0]
