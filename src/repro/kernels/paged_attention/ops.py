"""Public op: page-table attention on device.

`paged_attention` dispatches between the Pallas kernel (TPU target;
interpret=True executes the kernel body on CPU for validation) and the
pure-jnp gather-based reference — selected by `backend`, mirroring
`repro.kernels.masked_logits.ops`.

Both paths are bit-exact twins of the dense decode attention in
`models/layers.py` (same einsum dtypes, mask constant and reduction
axes), which is what lets the paged engine promise token-for-token
identical output to the dense engine.
"""
from __future__ import annotations

import jax

from .kernel import paged_attention_decode, paged_attention_span
from .ref import paged_attention_ref
from ...distributed.api import sharding_active


def paged_attention(q, k_pool, v_pool, page_table, pos, *,
                    backend: str = "auto"):
    """q [B,S,H,Dh] (roped, unscaled); k_pool/v_pool [P,ps,K,Dh];
    page_table [B,nP] int32 (-1 = unmapped); pos [B] int32 absolute
    start positions -> [B,S,H,Dh].

    backend: 'pallas' | 'jnp' | 'auto'. 'auto' picks the kernel on TPU
    and the jnp reference elsewhere (interpret-mode gathers are far
    slower than XLA's native gather on CPU; the kernel stays covered by
    the parity tests). Under an active serving sharding context the jnp
    reference is used regardless: GSPMD cannot partition a pallas_call
    (docs/sharding.md)."""
    if backend == "jnp" or sharding_active():
        return paged_attention_ref(q, k_pool, v_pool, page_table, pos)
    on_tpu = jax.default_backend() == "tpu"
    if backend == "auto" and not on_tpu:
        return paged_attention_ref(q, k_pool, v_pool, page_table, pos)
    return paged_attention_span(q, k_pool, v_pool, page_table, pos,
                                interpret=not on_tpu)
