"""Pallas TPU flash attention (blocked online-softmax, causal + sliding
window + GQA).

Grid (B, H, nQ, nK) with nK innermost ("arbitrary"); q/k/v tiles live in
VMEM via BlockSpecs, MXU-aligned (block sizes multiples of 128 on the
lane dim). Running max/denominator/accumulator persist in VMEM scratch
across the nK steps of one (b, h, iq) cell; output is written on the last
step. K/V BlockSpecs map head h -> kv head h//G (GQA without
materializing repeated KV).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, num_k: int, q_offset: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # [BQ, Dh]
    k = k_ref[0, 0].astype(jnp.float32)                # [BK, Dh]
    v = v_ref[0, 0].astype(jnp.float32)                # [BK, Dh]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [BQ, BK]

    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[:, 0] = m_new

    @pl.when(ik == num_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q [B,Sq,H,Dh]; k,v [B,Sk,K,Dh] -> [B,Sq,H,Dh].
    q positions right-aligned to k (q_offset = Sk - Sq)."""
    B, Sq, H, Dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / (Dh ** 0.5)

    # layout to [B, H, S, Dh] for clean blocking
    qh = q.swapaxes(1, 2)
    kh = k.swapaxes(1, 2)
    vh = v.swapaxes(1, 2)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k=nk, q_offset=Sk - Sq)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, Dh),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dh),
                         lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dh),
                               lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(qh, kh, vh)
    return out.swapaxes(1, 2)
