"""Public op: attention with kernel/oracle dispatch."""
from __future__ import annotations

import jax

from .kernel import flash_attention
from .ref import attention_ref


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              backend: str = "auto", block_q: int = 128,
              block_k: int = 128):
    if backend == "jnp":
        return attention_ref(q, k, v, causal=causal, window=window)
    interpret = jax.default_backend() != "tpu"
    if backend == "auto" and interpret and q.shape[1] * k.shape[1] > 1 << 18:
        return attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=min(block_q, q.shape[1]),
                           block_k=min(block_k, k.shape[1]),
                           interpret=interpret)
