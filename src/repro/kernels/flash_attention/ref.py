"""Pure-jnp oracle for the flash_attention kernel (direct softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q [B,Sq,H,Dh]; k,v [B,Sk,K,Dh] (GQA, H multiple of K) -> [B,Sq,H,Dh].
    q positions are right-aligned to k positions (q_offset = Sk - Sq)."""
    B, Sq, H, Dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / (Dh ** 0.5)
    qg = (q * scale).astype(jnp.float32).reshape(B, Sq, K, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    iq = jnp.arange(Sq)[:, None] + (Sk - Sq)
    ik = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ik <= iq
    if window:
        mask &= ik > iq - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)
