"""Minimal streaming HTTP endpoint over the AsyncEngine (stdlib only).

`python -m repro.launch.serve --serve --port 8400` starts it; clients
POST JSON and read newline-delimited JSON (NDJSON) chunks as tokens
commit — the paper's constrained decoding, served live:

  POST /generate
      {"prompt": "...", "grammar": "json" | null,
       "grammar_mode": "grammar_mask" | "grammar_strict" | null,
       "max_new_tokens": 64, "method": "greedy" | "sample",
       "temperature": 1.0, "top_k": 0, "top_p": 1.0, "seed": 0,
       "deadline": null | seconds, "stream": true}
  ->  {"token": 17, "text": "{\""}        one line per committed token
      ...
      {"done": true, "finish_reason": "eos", "tokens": 12,
       "text": "<full output>"}           terminal line

  `"stream": false` returns only the terminal line. Disconnecting
  mid-stream cancels the request — its slot and KV pages free at the
  next engine step. `"grammar_mode"` null/omitted uses the engine
  default (--grammar-mode).

  POST /grammars
      {"name": "my_dsl", "text": "<lark grammar source>"}
  ->  {"ok": true, "grammar": "my_dsl", "terminals": n, "rows": r}

  compiles the grammar, builds its mask store, and hot-loads it into
  the live engine between steps (AsyncEngine.load_grammar) — requests
  already streaming keep running; the next /generate may use it.

  GET /healthz -> {"ok": true, "slots": B, "active": n,
                   "grammars": [...], "uptime_seconds": s,
                   "queue_depth": q, "finish_reasons": {...}}

Observability surfaces (docs/observability.md):

  GET  /metrics  -> Prometheus text exposition: step-phase seconds,
                    TTFT/ITL/queue-wait histograms, token/mask/overlap
                    counters, KV pool gauges, device-attribution
                    counters and (in profile mode) device intervals.
  GET  /stats    -> the same data as one JSON snapshot (plus request
                    p50/p99 summaries, build identity, the per-step
                    attribution split and trace-buffer state).
  POST /trace    -> {"action": "start" | "stop" | "dump" | "clear"}.
                    start/stop toggle span capture into the bounded
                    ring buffer; dump returns Chrome trace-event JSON
                    (loadable in ui.perfetto.dev) without stopping.
  POST /profile  -> {"action": "start" | "stop" | "dump"}. Live
                    profiler capture: start flips device spans into
                    sync-on-exit mode (the documented profile-mode
                    exception to the serving no-sync contract), starts
                    trace capture AND a jax.profiler trace; dump (after
                    stop) returns ONE Chrome trace document with the
                    host phase spans, the synced device brackets, and
                    the profiler's kernel-thread slices merged on a
                    shared host-clock timeline.

The HTTP layer is deliberately tiny (HTTP/1.1, Content-Length bodies,
chunked responses); production fronting belongs in a real proxy — this
endpoint's job is exercising live admission, streaming, cancellation
and backpressure against the persistent step loop.
"""
from __future__ import annotations

import asyncio
import json
from typing import Optional

from repro.core.constrain import GrammarConstraint
from repro.core.decoding import DecodeConfig
from repro.obs import build_info
from repro.serving.async_engine import AsyncEngine
from repro.serving.engine import Request

_MAX_BODY = 1 << 20


class ServerError(Exception):
    def __init__(self, status: int, msg: str):
        super().__init__(msg)
        self.status = status
        self.msg = msg


async def _read_request(reader) -> tuple[str, str, bytes]:
    line = await reader.readline()
    if not line:
        raise ConnectionError("closed")
    try:
        method, path, _ = line.decode("latin-1").split(" ", 2)
    except ValueError:
        raise ServerError(400, "bad request line")
    clen = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        name, _, val = h.decode("latin-1").partition(":")
        if name.strip().lower() == "content-length":
            try:
                clen = int(val.strip())
            except ValueError:
                raise ServerError(400, "bad content-length")
    if clen > _MAX_BODY:
        raise ServerError(413, "body too large")
    body = await reader.readexactly(clen) if clen else b""
    return method, path, body


def _start_response(writer, status: int, reason: str,
                    content_type: str = "application/x-ndjson",
                    chunked: bool = True,
                    body: Optional[bytes] = None) -> None:
    hdr = [f"HTTP/1.1 {status} {reason}",
           f"Content-Type: {content_type}",
           "Connection: close"]
    if chunked:
        hdr.append("Transfer-Encoding: chunked")
    else:
        hdr.append(f"Content-Length: {len(body or b'')}")
    writer.write(("\r\n".join(hdr) + "\r\n\r\n").encode("latin-1"))
    if not chunked and body:
        writer.write(body)


def _chunk(writer, data: bytes) -> None:
    writer.write(f"{len(data):x}\r\n".encode("latin-1") + data + b"\r\n")


def _end_chunks(writer) -> None:
    writer.write(b"0\r\n\r\n")


def _parse_generate(body: bytes, grammars, rid: int) -> tuple[Request, bool]:
    try:
        spec = json.loads(body.decode() or "{}")
    except (ValueError, UnicodeDecodeError):
        raise ServerError(400, "body is not JSON")
    grammar = spec.get("grammar")
    if grammar is not None and grammar not in grammars:
        raise ServerError(400, f"unknown grammar {grammar!r}; "
                               f"have {sorted(grammars)}")
    gmode = spec.get("grammar_mode")
    if gmode is not None and gmode not in GrammarConstraint.MODES:
        raise ServerError(400, f"bad grammar_mode {gmode!r}; expected "
                               f"one of {list(GrammarConstraint.MODES)}")
    method = spec.get("method", "greedy")
    if method not in ("greedy", "sample"):
        raise ServerError(400, f"bad method {method!r}")
    dc = DecodeConfig(method=method,
                      temperature=float(spec.get("temperature", 1.0)),
                      top_k=spec.get("top_k") or None,
                      top_p=spec.get("top_p"))
    deadline = spec.get("deadline")
    req = Request(rid=rid,
                  prompt=str(spec.get("prompt", "")).encode(),
                  grammar=grammar,
                  grammar_mode=gmode,
                  max_new_tokens=int(spec.get("max_new_tokens", 64)),
                  decode=dc,
                  seed=int(spec.get("seed", 0)),
                  deadline=float(deadline) if deadline is not None
                  else None)
    return req, bool(spec.get("stream", True))


class EngineServer:
    def __init__(self, async_engine: AsyncEngine):
        self.aeng = async_engine
        self._server: Optional[asyncio.AbstractServer] = None

    # ------------------------------ routes ----------------------------

    async def _generate(self, reader, writer, body: bytes) -> None:
        req, stream = _parse_generate(body, self.aeng.engine.bundles,
                                      self.aeng.next_rid())
        handle = self.aeng.submit(req)      # raises pre-response: the
                                            # generic 503 path applies
        # disconnect watch: streamed responses notice a dead peer at the
        # next chunk write, but a "stream": false request writes nothing
        # until the end — watch the read side for EOF so a disconnect
        # cancels (frees the slot + KV pages) in that mode too
        def on_eof(t):
            if not t.cancelled():
                t.exception()               # retrieve; reset == EOF here
                if not handle.finished:
                    handle.cancel()
        eof_watch = asyncio.ensure_future(reader.read())
        eof_watch.add_done_callback(on_eof)
        _start_response(writer, 200, "OK")
        n = 0
        try:
            async for tid, tb in handle.tokens():
                n += 1
                if stream:
                    _chunk(writer, json.dumps(
                        {"token": tid,
                         "text": tb.decode("utf-8", "replace")}
                    ).encode() + b"\n")
                    await writer.drain()
            st = await handle.result()
            _chunk(writer, json.dumps(
                {"done": True,
                 "finish_reason": st.finish_reason if st else "error",
                 "tokens": n,
                 "text": (st.generated if st else b"").decode(
                     "utf-8", "replace")}).encode() + b"\n")
            _end_chunks(writer)
            await writer.drain()
        except (ConnectionError, BrokenPipeError, asyncio.CancelledError):
            # client went away mid-stream: free the slot + KV pages now
            handle.cancel()
            raise
        except Exception:
            # mid-stream engine failure: the chunked body has already
            # started, so no status line can help — cancel the request
            # and close; the truncated chunked stream signals the error
            handle.cancel()
        finally:
            eof_watch.cancel()

    async def _load_grammar(self, writer, body: bytes) -> None:
        """Compile + hot-load a grammar into the live engine (no restart).

        The compile and mask-store build run in a worker thread (they are
        pure CPU and can take seconds); only the final registration —
        growing the concatenated device store — crosses onto the step
        loop's control queue between steps."""
        try:
            spec = json.loads(body.decode() or "{}")
        except (ValueError, UnicodeDecodeError):
            raise ServerError(400, "body is not JSON")
        name = spec.get("name")
        text = spec.get("text")
        if not name or not isinstance(name, str):
            raise ServerError(400, "missing grammar 'name'")
        if not text or not isinstance(text, str):
            raise ServerError(400, "missing grammar 'text'")
        if name in self.aeng.engine.bundles:
            raise ServerError(409, f"grammar {name!r} already loaded")

        def compile_bundle():
            from repro.core.grammar import Grammar
            from repro.core.lr import build_lr_table
            from repro.core.mask_store import build_mask_store
            g = Grammar(text, name=name)
            tab = build_lr_table(g)
            store = build_mask_store(g, self.aeng.engine.tok)
            return g, tab, store
        try:
            bundle = await asyncio.get_running_loop().run_in_executor(
                None, compile_bundle)
        except Exception as e:
            raise ServerError(400, f"grammar compile failed: {e}")
        await self.aeng.load_grammar(name, bundle)
        g = bundle[0]
        out = json.dumps({"ok": True, "grammar": name,
                          "terminals": len(g.terminal_names),
                          "rows": int(bundle[2].packed.shape[0])}).encode()
        _start_response(writer, 200, "OK", "application/json",
                        chunked=False, body=out)

    async def _healthz(self, writer) -> None:
        loop = self.aeng._loop_obj
        tele = self.aeng.telemetry
        active = 0 if loop is None else len(loop.active())
        body = json.dumps({
            "ok": True,
            "slots": self.aeng.engine.slots,
            "active": active,
            "grammars": sorted(self.aeng.engine.bundles),
            "uptime_seconds": tele.uptime(),
            "queue_depth": len(self.aeng._source),
            "finish_reasons": tele.lifecycle.finish_reasons(),
            "build": build_info(),
        }).encode()
        _start_response(writer, 200, "OK", "application/json",
                        chunked=False, body=body)

    async def _metrics(self, writer) -> None:
        text = self.aeng.telemetry.registry.render_prometheus()
        _start_response(writer, 200, "OK",
                        "text/plain; version=0.0.4; charset=utf-8",
                        chunked=False, body=text.encode())

    async def _stats(self, writer) -> None:
        body = json.dumps(self.aeng.telemetry.stats_json()).encode()
        _start_response(writer, 200, "OK", "application/json",
                        chunked=False, body=body)

    async def _trace(self, writer, body: bytes) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
        except (ValueError, UnicodeDecodeError):
            raise ServerError(400, "body is not JSON")
        action = spec.get("action")
        tele = self.aeng.telemetry
        if action == "start":
            if not tele.enabled:
                raise ServerError(409, "telemetry disabled "
                                       "(engine started with "
                                       "telemetry=False)")
            tele.tracer.clear()
            tele.tracer.start()
            out = {"ok": True, "tracing": True}
        elif action == "stop":
            tele.tracer.stop()
            out = {"ok": True, "tracing": False,
                   "buffered_events": len(tele.tracer)}
        elif action == "dump":
            out = tele.tracer.export_chrome()
        elif action == "clear":
            tele.tracer.clear()
            out = {"ok": True, "buffered_events": 0}
        else:
            raise ServerError(400, f"bad trace action {action!r}; "
                                   f"expected start|stop|dump|clear")
        _start_response(writer, 200, "OK", "application/json",
                        chunked=False, body=json.dumps(out).encode())

    async def _profile(self, writer, body: bytes) -> None:
        """Live profiler capture: devtime sync-on-exit + jax.profiler
        trace, dumped as one merged host+device Chrome timeline."""
        try:
            spec = json.loads(body.decode() or "{}")
        except (ValueError, UnicodeDecodeError):
            raise ServerError(400, "body is not JSON")
        action = spec.get("action")
        tele = self.aeng.telemetry
        prof = tele.profiler
        if action == "start":
            if not tele.enabled:
                raise ServerError(409, "telemetry disabled "
                                       "(engine started with "
                                       "telemetry=False)")
            if prof.active:
                raise ServerError(409, "profile capture already active")
            out = {"ok": True, "profiling": True, **prof.start()}
        elif action == "stop":
            if not prof.active:
                raise ServerError(409, "no profile capture active")
            out = {"ok": True, "profiling": False, **prof.stop()}
        elif action == "dump":
            if prof.active:
                raise ServerError(409, "stop the capture before dump")
            if prof.log_dir is None:
                raise ServerError(409, "no profile capture to dump")
            out = tele.tracer.export_chrome(
                extra_events=prof.collect_chrome_events())
        else:
            raise ServerError(400, f"bad profile action {action!r}; "
                                   f"expected start|stop|dump")
        _start_response(writer, 200, "OK", "application/json",
                        chunked=False, body=json.dumps(out).encode())

    # ---------------------------- connection --------------------------

    async def _handle(self, reader, writer) -> None:
        try:
            try:
                method, path, body = await _read_request(reader)
                if method == "POST" and path == "/generate":
                    await self._generate(reader, writer, body)
                elif method == "POST" and path == "/grammars":
                    await self._load_grammar(writer, body)
                elif method == "GET" and path == "/healthz":
                    await self._healthz(writer)
                elif method == "GET" and path == "/metrics":
                    await self._metrics(writer)
                elif method == "GET" and path == "/stats":
                    await self._stats(writer)
                elif method == "POST" and path == "/trace":
                    await self._trace(writer, body)
                elif method == "POST" and path == "/profile":
                    await self._profile(writer, body)
                else:
                    raise ServerError(404, f"no route {method} {path}")
            except ServerError as e:
                body = json.dumps({"error": e.msg}).encode()
                _start_response(writer, e.status, "Error",
                                "application/json", chunked=False,
                                body=body)
            except (ConnectionError, BrokenPipeError,
                    asyncio.CancelledError):
                raise
            except Exception as e:
                # engine-side failures before any bytes went out (e.g.
                # submit() during drain) become a JSON 503 instead of a
                # silent connection reset. Mid-stream failures can only
                # append garbage to an already-started chunked body, so
                # _generate keeps its own narrower handling.
                body = json.dumps(
                    {"error": f"engine unavailable: {e}"}).encode()
                _start_response(writer, 503, "Service Unavailable",
                                "application/json", chunked=False,
                                body=body)
            await writer.drain()
        except (ConnectionError, BrokenPipeError, asyncio.CancelledError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, BrokenPipeError):
                pass

    # ----------------------------- lifecycle --------------------------

    async def start(self, host: str = "127.0.0.1", port: int = 8400):
        self._server = await asyncio.start_server(self._handle, host, port)
        return self._server.sockets[0].getsockname()[:2]

    async def serve_forever(self) -> None:
        async with self._server:
            await self._server.serve_forever()

    async def stop(self, drain: bool = True) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if drain:
            await self.aeng.drain()
        else:
            await self.aeng.abort()


async def run_server(async_engine: AsyncEngine, host: str = "127.0.0.1",
                     port: int = 8400) -> None:
    srv = EngineServer(async_engine)
    addr = await srv.start(host, port)
    print(f"serving on http://{addr[0]}:{addr[1]} "
          f"(POST /generate, POST /grammars, POST /trace, "
          f"POST /profile, GET /healthz, GET /metrics, GET /stats)")
    await srv.serve_forever()
