"""Grammar-constrained serving engine (paper Algorithm 3 as a runtime).

The engine is built around **continuous batching** over a fixed pool of
`B = slots` decode slots:

  * one jitted `[B, V]` decode step advances every active request at once
    (decode caches are allocated `[.., B, ..]` up front; per-request
    prefill results are inserted into their slot on admission),
  * the host side of Algorithm 2 fills a `[B, A]` mask-row matrix + `[B]`
    eos vector for all constrained slots in one pass
    (`GrammarConstraint.step_rows_batch`),
  * a single fused mask+sample device call applies the packed mask-store
    rows (`repro.kernels.masked_logits`) and draws every slot's next token
    with per-request greedy/temperature/top-k/top-p (`select_batch`) —
    only the `[B]` sampled ids come back to the host, never `[B, V]`,
  * the paper's *opportunistic masking* fast path (§5 Baselines) validates
    the whole batch's unconstrained proposals first and computes mask rows
    only for the slots whose proposal was rejected,
  * the exactness wrapper survives batching: because the α≤1 mask store
    over-approximates (sound, not complete — paper §4.4), sampled ids are
    verified against the precise parser oracle; invalid picks are demoted
    and the affected rows resampled on device, so emitted text provably
    stays in L_p(G) and terminates only when in L(G),
  * finished requests free their slot and the next queued request is
    admitted immediately (no round-robin sweep), so the pool stays full
    under load.

`generate_sequential` keeps the original one-request-at-a-time stepping
path for comparison benchmarks (benchmarks/bench_tables.py::
batched_engine_throughput) and as an oracle for the batched scheduler.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constrain import GrammarConstraint, MAX_ACCEPT
from repro.core.decoding import (DecodeConfig, NEG_INF, select_batch,
                                 select_span)
from repro.core.tokenizer import BOS_ID, ByteTokenizer, EOS_ID
from repro.kernels.masked_logits.ops import (apply_grammar_mask,
                                             apply_grammar_mask_span)
from repro.spec.scheduler import (SPAN_BUCKETS, SlotPhase, SpecConfig,
                                  SpecScheduler)


@dataclass
class Request:
    rid: int
    prompt: bytes = b""
    grammar: Optional[str] = None           # None = unconstrained
    max_new_tokens: int = 128
    decode: DecodeConfig = field(default_factory=DecodeConfig)
    seed: int = 0


@dataclass
class RequestState:
    req: Request
    caches: object = None                   # sequential path only
    pos: int = 0
    generated: bytes = b""
    token_ids: list = field(default_factory=list)
    constraint: Optional[GrammarConstraint] = None
    done: bool = False
    finish_reason: str = ""
    pending_logits: object = None
    mask_time: float = 0.0
    mask_computations: int = 0
    opportunistic_hits: int = 0
    steps: int = 0
    slot: int = -1
    # --- speculation (generate_speculative) ---
    phase: str = SlotPhase.DECODING.value   # jumping/drafting/verifying/…
    jump_tokens: int = 0                    # grammar-forced, zero model calls
    draft_proposed: int = 0
    draft_accepted: int = 0


@dataclass
class EngineStats:
    requests: int = 0
    tokens: int = 0
    wall: float = 0.0
    mask_time: float = 0.0
    mask_computations: int = 0
    opportunistic_hits: int = 0
    decode_steps: int = 0                   # batched [B,V] device steps
    batch_slots: int = 0
    # --- speculation (generate_speculative) ---
    jump_tokens: int = 0                    # emitted with zero model calls
    draft_proposed: int = 0
    draft_accepted: int = 0
    plan_time: float = 0.0                  # host planning (jump + draft)

    @property
    def tokens_per_sec(self):
        return self.tokens / max(self.wall, 1e-9)

    @property
    def jump_fraction(self):
        return self.jump_tokens / max(self.tokens, 1)

    @property
    def acceptance_rate(self):
        return self.draft_accepted / max(self.draft_proposed, 1)


class Engine:
    def __init__(self, model, params, tokenizer: ByteTokenizer,
                 grammar_bundles: dict, max_len: int = 512,
                 opportunistic: bool = False, mask_backend: str = "jnp",
                 slots: int = 4):
        """grammar_bundles: name -> (grammar, table, store).
        slots: decode-pool width B of the batched scheduler."""
        self.model = model
        self.params = params
        self.tok = tokenizer
        self.bundles = grammar_bundles
        self.max_len = max_len
        self.opportunistic = opportunistic
        self.mask_backend = mask_backend
        self.slots = max(1, int(slots))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=max_len))
        self._decode = jax.jit(model.decode_step)
        # one concatenated device store for all grammars: a request's rows
        # index its grammar's block via the per-grammar row offset (shared
        # by the batched and sequential paths — the store lives on device
        # exactly once)
        self._row_offset: dict[str, int] = {}
        parts, off = [], 0
        for name, b in grammar_bundles.items():
            self._row_offset[name] = off
            parts.append(b[2].packed)
            off += b[2].packed.shape[0]
        words = (tokenizer.vocab_size + 31) // 32
        cat = (np.concatenate(parts, axis=0) if parts
               else np.zeros((1, words), np.uint32))
        self._store_cat = jnp.asarray(cat)
        self._build_batched_fns()

    def _build_batched_fns(self):
        backend = self.mask_backend

        def mask_sample(logits, store, rows, eos, constrained,
                        greedy, temp, top_k, top_p, keys):
            masked = apply_grammar_mask(logits, store, rows, eos,
                                        backend=backend,
                                        constrained=constrained)
            ids = select_batch(masked, keys, greedy, temp, top_k, top_p)
            ok = jnp.any(masked > NEG_INF / 2, axis=-1)
            return masked, ids, ok

        def resample(masked, ban, redo, greedy, temp, top_k, top_p, keys):
            V = masked.shape[-1]
            hit = (jnp.arange(V)[None, :] == ban[:, None]) & redo[:, None]
            masked = jnp.where(hit, jnp.asarray(NEG_INF, masked.dtype),
                               masked)
            ids = select_batch(masked, keys, greedy, temp, top_k, top_p)
            ok = jnp.any(masked > NEG_INF / 2, axis=-1)
            return masked, ids, ok

        def insert(full, one, b):
            return jax.tree.map(
                lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                    f, o.astype(f.dtype), b, axis=1), full, one)

        def span_mask_select(logits, store, rows, eos, constrained,
                             greedy, temp, top_k, top_p, keys):
            """Fused speculation pass: grammar-mask a [B, S, V] span and
            select a token at every position (constrained positions via
            the packed store rows, padding/unconstrained pass through).
            The accept test is a host-side == against the [B, S] ids."""
            masked = apply_grammar_mask_span(logits, store, rows, eos,
                                             backend=backend,
                                             constrained=constrained)
            ids = select_span(masked, keys, greedy, temp, top_k, top_p)
            ok = jnp.any(masked > NEG_INF / 2, axis=-1)
            return masked, ids, ok

        self._mask_sample = jax.jit(mask_sample)
        self._resample = jax.jit(resample)
        self._sample_plain = jax.jit(select_batch)
        self._insert_caches = jax.jit(insert)
        self._span_mask_select = jax.jit(span_mask_select)
        self._span_decode = jax.jit(
            lambda p, c, toks, pos, fm: self.model.decode_span(
                p, c, toks, pos, feed_mask=fm))

    # ------------------------------ lifecycle -----------------------------

    def _make_constraint(self, req: Request) -> Optional[GrammarConstraint]:
        if req.grammar is None:
            return None
        g, tab, store = self.bundles[req.grammar]
        return GrammarConstraint(g, tab, store, self.tok)

    def _admit_common(self, req: Request, b: int, caches):
        """Shared slot admission: build request state, prefill the
        prompt, insert its caches into slot b. Returns (state, caches);
        per-loop array updates stay with the caller."""
        st = RequestState(req=req, slot=b)
        st.constraint = self._make_constraint(req)
        ids = self._prompt_ids(req)
        if len(ids) == 1:
            # prefill needs >= 1 token before the decode loop takes
            # over; re-feeding the last prompt token would double-step
            # recurrent caches, so prepend BOS instead
            ids = [BOS_ID] + ids
        prompt = jnp.asarray([ids[:-1]], jnp.int32)
        _, pc = self._prefill(self.params, {"tokens": prompt})
        caches = self._insert_caches(caches, pc, jnp.int32(b))
        st.token_ids = list(ids)
        st.pos = len(ids)
        return st, caches

    def _prompt_ids(self, req: Request) -> list[int]:
        ids = self.tok.encode(req.prompt) if req.prompt else []
        if not ids:
            ids = [BOS_ID]
        return ids

    def _commit(self, st: RequestState, token: int):
        st.token_ids.append(token)
        st.pos += 1
        if token == EOS_ID:
            st.done = True
            st.finish_reason = "eos"
            return
        st.generated += self.tok.id_to_bytes[token]
        if st.steps >= st.req.max_new_tokens:
            st.done = True
            st.finish_reason = "length"
        if st.pos >= self.max_len - 1:
            st.done = True
            st.finish_reason = "max_len"

    # ============================ batched path ============================

    def _step_keys(self, seeds: np.ndarray, step: int,
                   attempt: int) -> np.ndarray:
        """[B, 2] uint32 threefry key data: one counter-mode stream per
        slot, advanced by (step, attempt). Greedy rows ignore keys."""
        k = np.empty((seeds.shape[0], 2), np.uint32)
        k[:, 0] = seeds
        k[:, 1] = np.uint32((step << 4) | (attempt & 0xF))
        return k

    def _fallback_exact(self, st: RequestState, row: np.ndarray,
                        attempt_salt: int) -> Optional[int]:
        """Rare slow path: the sampled ids kept failing the oracle (or the
        mask emptied after demotions). Exact-filter the remaining allowed
        set (|allowed| oracle calls) and draw host-side, so the step never
        dead-ends while a valid continuation exists. top-k/top-p are not
        re-applied here — this path fires when the mask kept only a
        handful of candidates anyway."""
        gc = st.constraint
        allowed = np.where(row > NEG_INF / 2)[0]
        valid = [int(t) for t in allowed
                 if t == EOS_ID or gc.is_valid_extension(st.generated,
                                                         int(t))]
        if not valid:
            return None
        sub = row[valid].astype(np.float64)
        if st.req.decode.method == "greedy":
            return valid[int(np.argmax(sub))]
        temp = max(st.req.decode.temperature, 1e-6)
        p = np.exp((sub - sub.max()) / temp)
        p /= p.sum()
        rng = np.random.default_rng(
            (st.req.seed * 1000003 + st.steps * 31 + attempt_salt)
            & 0xFFFFFFFF)
        return int(rng.choice(valid, p=p))

    def generate(self, requests: list[Request], verbose: bool = False):
        """Continuous batching over a fixed pool of `self.slots` slots.

        Per engine step: ONE [B, V] decode for every active slot, ONE
        fused mask+sample call (constrained and unconstrained slots mixed
        via the `constrained` flag), and only [B]-sized transfers back to
        the host. Finished slots are refilled from the queue immediately.
        """
        t0 = time.time()
        B = self.slots
        queue = deque(requests)
        all_states: list[RequestState] = []
        caches = self.model.init_decode_caches(B, self.max_len)
        cur_tok = np.zeros(B, np.int32)
        feed_pos = np.zeros(B, np.int32)
        slot_state: list[Optional[RequestState]] = [None] * B
        seeds = np.zeros(B, np.uint32)
        constrained = np.zeros(B, bool)
        greedy = np.ones(B, bool)
        temp = np.ones(B, np.float32)
        top_k = np.zeros(B, np.int32)
        top_p = np.ones(B, np.float32)
        step = 0
        decode_steps = 0
        mask_time = 0.0
        mask_computations = 0
        opportunistic_hits = 0

        def admit(b: int):
            nonlocal caches
            req = queue.popleft()
            st, caches = self._admit_common(req, b, caches)
            slot_state[b] = st
            cur_tok[b] = st.token_ids[-1]
            feed_pos[b] = st.pos - 1
            seeds[b] = np.uint32(req.seed & 0xFFFFFFFF)
            constrained[b] = st.constraint is not None
            g, t, k, p = DecodeConfig.batch_arrays([req.decode])
            greedy[b], temp[b], top_k[b], top_p[b] = g[0], t[0], k[0], p[0]
            all_states.append(st)

        def finish(b: int):
            st = slot_state[b]
            slot_state[b] = None
            constrained[b] = False
            cur_tok[b] = 0
            feed_pos[b] = 0
            if verbose:
                print(f"[req {st.req.rid}] {st.finish_reason}: "
                      f"{st.generated[:70]!r}")

        while queue or any(s is not None for s in slot_state):
            for b in range(B):
                if slot_state[b] is None and queue:
                    admit(b)
            active = [b for b in range(B) if slot_state[b] is not None]
            step += 1

            # ---- ONE [B, V] decode step for the whole pool --------------
            logits, caches = self._decode(
                self.params, caches, jnp.asarray(cur_tok),
                jnp.asarray(feed_pos))
            decode_steps += 1
            for b in active:
                slot_state[b].steps += 1
            committed: dict[int, int] = {}
            pending = set(active)

            # ---- opportunistic fast path (whole batch at once) ----------
            if self.opportunistic and any(constrained[b] for b in active):
                keys = self._step_keys(seeds, step, 0)
                prop = np.asarray(self._sample_plain(
                    logits, jnp.asarray(keys), jnp.asarray(greedy),
                    jnp.asarray(temp), jnp.asarray(top_k),
                    jnp.asarray(top_p)))
                for b in list(pending):
                    st = slot_state[b]
                    t = int(prop[b])
                    if st.constraint is None:
                        committed[b] = t
                        pending.discard(b)
                    elif st.constraint.is_valid_extension(st.generated, t):
                        st.opportunistic_hits += 1
                        opportunistic_hits += 1
                        committed[b] = t
                        pending.discard(b)

            # ---- fused mask + batched sample for the rest ---------------
            if pending:
                t_mask = time.time()
                cons = [slot_state[b].constraint
                        if (b in pending and slot_state[b] is not None)
                        else None for b in range(B)]
                texts = [slot_state[b].generated if slot_state[b] else b""
                         for b in range(B)]
                offs = np.array(
                    [self._row_offset.get(slot_state[b].req.grammar, 0)
                     if slot_state[b] is not None else 0
                     for b in range(B)], np.int64)
                rows, eos, _ = GrammarConstraint.step_rows_batch(
                    cons, texts, max_accept=MAX_ACCEPT, row_offsets=offs)
                need_mask = np.array([c is not None for c in cons], bool)
                keys = self._step_keys(seeds, step, 1)
                masked, ids, ok = self._mask_sample(
                    logits, self._store_cat, jnp.asarray(rows),
                    jnp.asarray(eos), jnp.asarray(need_mask),
                    jnp.asarray(greedy), jnp.asarray(temp),
                    jnp.asarray(top_k), jnp.asarray(top_p),
                    jnp.asarray(keys))
                ids_h, ok_h = np.asarray(ids), np.asarray(ok)
                n_masked = int(need_mask.sum())
                mask_computations += n_masked
                elapsed = time.time() - t_mask
                mask_time += elapsed
                for b in np.where(need_mask)[0]:
                    slot_state[b].mask_computations += 1
                    slot_state[b].mask_time += elapsed / max(n_masked, 1)

                # rejection wrapper: the α<=1 mask is sound but over-
                # approximate; verify with the exact oracle, demote invalid
                # picks on device, resample only the affected rows. Only
                # [B] ids/flags ever cross back to the host here.
                for attempt in range(2, 6):
                    redo = np.zeros(B, bool)
                    ban = np.zeros(B, np.int32)
                    for b in sorted(pending):
                        st = slot_state[b]
                        if st.constraint is None:
                            committed[b] = int(ids_h[b])
                            pending.discard(b)
                            continue
                        if not ok_h[b]:
                            continue        # mask exhausted -> fallback
                        t = int(ids_h[b])
                        if t == EOS_ID or st.constraint.is_valid_extension(
                                st.generated, t):
                            committed[b] = t
                            pending.discard(b)
                        else:
                            redo[b] = True
                            ban[b] = t
                    if not redo.any():
                        break
                    keys = self._step_keys(seeds, step, attempt)
                    masked, ids, ok = self._resample(
                        masked, jnp.asarray(ban), jnp.asarray(redo),
                        jnp.asarray(greedy), jnp.asarray(temp),
                        jnp.asarray(top_k), jnp.asarray(top_p),
                        jnp.asarray(keys))
                    ids_h, ok_h = np.asarray(ids), np.asarray(ok)

                # exact-filter fallback for slots that never validated
                for b in sorted(pending):
                    st = slot_state[b]
                    nxt = self._fallback_exact(
                        st, np.asarray(masked[b]), step)
                    if nxt is None:
                        # nothing valid (should not happen for C_k in
                        # L_p(G)) — stop this request
                        st.done = True
                        st.finish_reason = "mask_exhausted"
                    else:
                        committed[b] = nxt
                    pending.discard(b)

            # ---- commit + immediate slot replacement --------------------
            for b, t in committed.items():
                st = slot_state[b]
                self._commit(st, t)
                cur_tok[b] = t
                feed_pos[b] = st.pos - 1
            for b in active:
                st = slot_state[b]
                if st is not None and st.done:
                    finish(b)

        stats = EngineStats(
            requests=len(all_states),
            tokens=sum(s.steps for s in all_states),
            wall=time.time() - t0,
            mask_time=mask_time,
            mask_computations=mask_computations,
            opportunistic_hits=opportunistic_hits,
            decode_steps=decode_steps,
            batch_slots=B,
        )
        return all_states, stats

    # ========================== speculative path ==========================
    # Grammar-aware speculative decoding on top of the batched pool:
    # jump-forward (grammar-forced tokens committed with zero model
    # calls) + draft-verify (host proposer drafts, one fused [B, S, V]
    # span decode + mask + select verifies the whole window). Greedy
    # speculative decoding is token-for-token identical to generate():
    # forced tokens are the masked argmax's only support point, accepted
    # drafts equal the span selection the plain engine would have made,
    # and the bonus/demote path replays the same deterministic order.

    def _resolve_span_selection(self, st: RequestState, masked_dev, b: int,
                                idx: int, proposed: int, row_ok: bool,
                                salt: int) -> Optional[int]:
        """Validate one span selection against the exact oracle, demoting
        invalid picks in the same order as generate()'s device-side
        rejection wrapper (4 demote rounds, then the exact-filter
        fallback). Pulls the [V] masked row to the host only when the
        first pick fails (rare)."""
        gc = st.constraint
        if gc is None:
            return proposed
        row = None
        t = proposed
        if row_ok:
            for attempt in range(4):
                if t == EOS_ID or gc.is_valid_extension(st.generated, t):
                    return t
                if row is None:
                    row = np.asarray(masked_dev[b, idx], np.float32)
                row[t] = NEG_INF
                if not (row > NEG_INF / 2).any():
                    break
                if st.req.decode.method == "greedy":
                    t = int(np.argmax(row))
                else:
                    # host-side redraw (temperature softmax over the
                    # demoted row; sampling carries no equivalence
                    # obligation — see docs/speculation.md)
                    temp = max(st.req.decode.temperature, 1e-6)
                    r = row.astype(np.float64)
                    finite = r > NEG_INF / 2
                    p = np.where(finite, np.exp((r - r[finite].max())
                                                / temp), 0.0)
                    p /= p.sum()
                    rng = np.random.default_rng(
                        (st.req.seed * 1000003 + st.steps * 31
                         + salt * 7 + attempt) & 0xFFFFFFFF)
                    t = int(rng.choice(len(r), p=p))
        if row is None:
            row = np.asarray(masked_dev[b, idx], np.float32)
        return self._fallback_exact(st, row, salt)

    @staticmethod
    def _choose_span(desired: list) -> int:
        """Pick the span bucket maximizing committed-tokens-per-compute:
        a span of width S costs ~B*S model work, and serves min(d, S)
        useful positions per slot. The +0.3 denominator models the fixed
        per-step overhead, breaking ties toward wider spans."""
        top = max(desired)
        best, best_score = 1, -1.0
        for S in SPAN_BUCKETS:
            score = sum(min(d, S) for d in desired) / (S + 0.3)
            if score > best_score:
                best, best_score = S, score
            if S >= top:
                break
        return best

    def _span_keys(self, seeds: np.ndarray, S: int, step: int) -> np.ndarray:
        """[B, S, 2] uint32 threefry key data: one counter-mode stream
        per (slot, span position). Greedy rows ignore keys."""
        B = seeds.shape[0]
        k = np.empty((B, S, 2), np.uint32)
        k[:, :, 0] = seeds[:, None]
        k[:, :, 1] = (np.uint32((step << 6) & 0xFFFFFFFF)
                      + np.arange(S, dtype=np.uint32)[None, :])
        return k

    def generate_speculative(self, requests: list[Request],
                             spec: Optional[SpecConfig] = None,
                             verbose: bool = False):
        """Continuous batching with grammar-aware speculation.

        Per engine step and per active slot: the scheduler first chases
        grammar-FORCED tokens (jump-forward, committed host-side with no
        model call), then drafts up to K oracle-vetted tokens from the
        slot's own history. One fused span decode replays forced tokens
        and scores drafts for every slot at once ([B, S, V], S bucketed),
        one fused span mask+select turns that into per-position picks,
        and the host accepts each slot's longest matching draft prefix
        plus a bonus token. Slots with nothing to speculate ride the same
        span at width 1 — identical cost to generate()'s step.
        """
        spec = spec or SpecConfig()
        if not self.model.supports_span_decode:
            raise ValueError(
                "speculative decoding needs position-addressed decode "
                "caches (attn/moe layer kinds); this arch has recurrent "
                "or side-input state")
        t0 = time.time()
        B = self.slots
        sched = SpecScheduler(spec, self.tok)
        queue = deque(requests)
        all_states: list[RequestState] = []
        caches = self.model.init_decode_caches(B, self.max_len)
        # the feed cursor: slot b's tokens at positions < feed_pos[b] are
        # in the decode caches; token_ids[feed_pos[b]:pos] are committed
        # but pending feed (cur-token + jump backlog)
        feed_pos = np.zeros(B, np.int32)
        slot_state: list[Optional[RequestState]] = [None] * B
        seeds = np.zeros(B, np.uint32)
        greedy = np.ones(B, bool)
        temp = np.ones(B, np.float32)
        top_k = np.zeros(B, np.int32)
        top_p = np.ones(B, np.float32)
        step = 0
        decode_steps = 0
        plan_time = 0.0
        mask_time = 0.0
        mask_computations = 0
        jump_tokens = 0
        draft_proposed = 0
        draft_accepted = 0

        def admit(b: int):
            nonlocal caches
            req = queue.popleft()
            st, caches = self._admit_common(req, b, caches)
            slot_state[b] = st
            feed_pos[b] = st.pos - 1
            seeds[b] = np.uint32(req.seed & 0xFFFFFFFF)
            g, t, k, p = DecodeConfig.batch_arrays([req.decode])
            greedy[b], temp[b], top_k[b], top_p[b] = g[0], t[0], k[0], p[0]
            sched.on_admit(st)
            all_states.append(st)

        def finish(b: int):
            st = slot_state[b]
            slot_state[b] = None
            feed_pos[b] = 0
            sched.on_finish(st)
            if verbose:
                print(f"[req {st.req.rid}] {st.finish_reason}: "
                      f"{st.generated[:70]!r}")

        def commit_one(st: RequestState, token: int):
            st.steps += 1
            self._commit(st, token)

        while queue or any(s is not None for s in slot_state):
            for b in range(B):
                if slot_state[b] is None and queue:
                    admit(b)
            active = [b for b in range(B) if slot_state[b] is not None]
            step += 1

            # ---- host planning: jump-forward commits + drafting ---------
            # Jumped tokens commit immediately but drain through the span
            # as per-slot BACKLOG (feed cursor trails the commit
            # frontier), so a long jump never inflates the pool's span
            # width on its own.
            plans = {}
            t_plan = time.time()
            for b in active:
                st = slot_state[b]
                backlog = (st.pos - 1) - int(feed_pos[b])
                pre = st.jump_tokens
                plans[b] = sched.plan_slot(st, commit_one, self.max_len,
                                           backlog=backlog)
                jump_tokens += st.jump_tokens - pre
                st.phase = plans[b].phase.value
            plan_time += time.time() - t_plan
            for b in active:
                st = slot_state[b]
                if st.done:      # finished mid-jump: nothing left to feed
                    sched.on_commit(st, plans[b].jumped)
                    finish(b)
            live = [b for b in active if slot_state[b] is not None]
            if not live:
                continue

            # ---- span width: maximize commits per unit of compute -------
            # pend = committed-but-unfed tokens (current token + backlog);
            # desired = pend + drafts. The bucket is chosen to maximize
            # sum(min(desired, S)) / S so one deep slot cannot force the
            # whole pool through a mostly-padding span.
            pend_n = {b: slot_state[b].pos - int(feed_pos[b]) for b in live}
            S = self._choose_span(
                [pend_n[b] + len(plans[b].drafts) for b in live])
            tokens = np.zeros((B, S), np.int32)
            fmask = np.zeros((B, S), bool)
            sel0 = {}        # b -> span index of first selection (-1 none)
            for b in live:
                st = slot_state[b]
                pend = st.token_ids[int(feed_pos[b]): st.pos]
                if len(pend) > S:          # backlog drain: feed only
                    feed = pend[:S]
                    sel0[b] = -1
                    plans[b].drafts = []
                else:
                    plans[b].drafts = plans[b].drafts[: S - len(pend)]
                    feed = pend + plans[b].drafts
                    sel0[b] = len(pend) - 1
                tokens[b, : len(feed)] = feed
                fmask[b, : len(feed)] = True
                if plans[b].drafts:
                    st.phase = SlotPhase.VERIFYING.value
            logits, caches = self._span_decode(
                self.params, caches, jnp.asarray(tokens),
                jnp.asarray(feed_pos), jnp.asarray(fmask))
            decode_steps += 1

            # ---- mask rows for every selection position -----------------
            t_mask = time.time()
            rows = np.full((B, S, MAX_ACCEPT), -1, np.int32)
            eosm = np.zeros((B, S), bool)
            consm = np.zeros((B, S), bool)
            for b in live:
                st = slot_state[b]
                pl = plans[b]
                if st.constraint is None or sel0[b] < 0:
                    continue
                off = self._row_offset[st.req.grammar]
                text = st.generated
                for i in range(len(pl.drafts) + 1):
                    if i > 0:
                        text = text + self.tok.id_to_bytes[pl.drafts[i - 1]]
                    if i == 0 and pl.stop_mask is not None:
                        sm = pl.stop_mask   # reuse the jump analyzer's mask
                    else:
                        sm = st.constraint.step_rows(text)
                    f = sel0[b] + i
                    rows[b, f] = np.where(sm.rows >= 0, sm.rows + off,
                                          sm.rows)
                    eosm[b, f] = sm.eos_allowed
                    consm[b, f] = True
                    st.mask_computations += 1
                    mask_computations += 1
            keys = self._span_keys(seeds, S, step)
            masked, ids, ok = self._span_mask_select(
                logits, self._store_cat, jnp.asarray(rows),
                jnp.asarray(eosm), jnp.asarray(consm), jnp.asarray(greedy),
                jnp.asarray(temp), jnp.asarray(top_k), jnp.asarray(top_p),
                jnp.asarray(keys))
            ids_h, ok_h = np.asarray(ids), np.asarray(ok)
            mask_time += time.time() - t_mask

            # ---- accept: longest valid draft prefix + bonus token -------
            for b in live:
                st = slot_state[b]
                pl = plans[b]
                if sel0[b] < 0:
                    # pure backlog drain: advance the feed cursor; the
                    # step's jump commits (nonempty only on the first
                    # drain step) must still reach the proposer history
                    sched.on_commit(st, pl.jumped)
                    feed_pos[b] += S
                    continue
                idx = sel0[b]
                committed = []
                for d in pl.drafts:
                    if st.done or int(ids_h[b, idx]) != d:
                        break
                    # d is oracle-vetted; selection == d is exactly what
                    # the plain engine would have committed here
                    commit_one(st, d)
                    committed.append(d)
                    idx += 1
                st.draft_proposed += len(pl.drafts)
                st.draft_accepted += len(committed)
                draft_proposed += len(pl.drafts)
                draft_accepted += len(committed)
                sched.on_verify(st, len(pl.drafts), len(committed))
                if not st.done:
                    nxt = self._resolve_span_selection(
                        st, masked, b, idx, int(ids_h[b, idx]),
                        bool(ok_h[b, idx]), step)
                    if nxt is None:
                        st.done = True
                        st.finish_reason = "mask_exhausted"
                    else:
                        commit_one(st, nxt)
                        committed.append(nxt)
                sched.on_commit(st, pl.jumped + committed)
                if st.done:
                    finish(b)
                else:
                    feed_pos[b] = st.pos - 1
                    st.phase = SlotPhase.DECODING.value

        stats = EngineStats(
            requests=len(all_states),
            tokens=sum(s.steps for s in all_states),
            wall=time.time() - t0,
            mask_time=mask_time,
            mask_computations=mask_computations,
            decode_steps=decode_steps,
            batch_slots=B,
            jump_tokens=jump_tokens,
            draft_proposed=draft_proposed,
            draft_accepted=draft_accepted,
            plan_time=plan_time,
        )
        return all_states, stats

    # =========================== sequential path ==========================
    # The original one-request-at-a-time engine (paper Algorithm 3,
    # round-robin). Kept as the baseline the batched scheduler is
    # benchmarked against, and as a behavioral oracle in tests.

    def _start(self, req: Request) -> RequestState:
        st = RequestState(req=req)
        st.constraint = self._make_constraint(req)
        ids = self._prompt_ids(req)
        tokens = jnp.asarray([ids], jnp.int32)
        logits, caches = self._prefill(self.params, {"tokens": tokens})
        st.caches = caches
        st.pos = len(ids)
        st.token_ids = list(ids)
        st.pending_logits = logits[:, -1]       # prediction for next token
        return st

    def _logits(self, st: RequestState):
        if getattr(st, "pending_logits", None) is not None:
            lg = st.pending_logits
            st.pending_logits = None
            return lg
        tok = jnp.asarray([st.token_ids[-1]], jnp.int32)
        pos = jnp.asarray([st.pos - 1], jnp.int32)
        lg, st.caches = self._decode(self.params, st.caches, tok, pos)
        return lg  # [1, V] device array

    def _select(self, st: RequestState, logits, key) -> int:
        return int(st.req.decode.select(logits, key)[0])

    def _step(self, st: RequestState, key) -> None:
        logits = self._logits(st)
        st.steps += 1
        req = st.req

        if st.constraint is None:
            nxt = self._select(st, logits, key)
            self._commit(st, nxt)
            return

        gc = st.constraint
        text = st.generated

        if self.opportunistic:
            proposal = self._select(st, logits, key)
            if gc.is_valid_extension(text, proposal):
                st.opportunistic_hits += 1
                self._commit(st, proposal)
                return

        t0 = time.time()
        sm = gc.step_rows(text)
        off = self._row_offset[req.grammar]
        rows = jnp.asarray(np.where(sm.rows >= 0, sm.rows + off,
                                    sm.rows)[None, :])
        eos = jnp.asarray([sm.eos_allowed])
        masked = apply_grammar_mask(logits, self._store_cat,
                                    rows, eos, backend=self.mask_backend)
        st.mask_time += time.time() - t0
        st.mask_computations += 1

        # rejection wrapper (see generate() for the batched variant)
        masked = np.asarray(masked, np.float32)
        for attempt in range(4):
            key, sub = jax.random.split(key)
            nxt = self._select(st, jnp.asarray(masked), sub)
            if masked[0, nxt] <= NEG_INF / 2:
                break
            if nxt == EOS_ID or gc.is_valid_extension(text, nxt):
                self._commit(st, nxt)
                return
            masked[0, nxt] = NEG_INF

        allowed = np.where(masked[0] > NEG_INF / 2)[0]
        for t in allowed:
            if not (t == EOS_ID or gc.is_valid_extension(text, int(t))):
                masked[0, t] = NEG_INF
        if (masked[0] > NEG_INF / 2).any():
            key, sub = jax.random.split(key)
            nxt = self._select(st, jnp.asarray(masked), sub)
            self._commit(st, nxt)
            return
        # nothing valid (should not happen for C_k in L_p(G)) — stop
        st.done = True
        st.finish_reason = "mask_exhausted"

    def generate_sequential(self, requests: list[Request],
                            verbose: bool = False):
        """Round-robin continuous stepping, one request per device call."""
        t0 = time.time()
        states = [self._start(r) for r in requests]
        keys = {r.rid: jax.random.PRNGKey(r.seed) for r in requests}
        active = list(states)
        while active:
            for st in list(active):
                keys[st.req.rid], sub = jax.random.split(keys[st.req.rid])
                self._step(st, sub)
                if st.done:
                    active.remove(st)
                    if verbose:
                        print(f"[req {st.req.rid}] {st.finish_reason}: "
                              f"{st.generated[:70]!r}")
        stats = EngineStats(
            requests=len(states),
            tokens=sum(s.steps for s in states),
            wall=time.time() - t0,
            mask_time=sum(s.mask_time for s in states),
            mask_computations=sum(s.mask_computations for s in states),
            opportunistic_hits=sum(s.opportunistic_hits for s in states),
            decode_steps=sum(s.steps for s in states),
            batch_slots=1,
        )
        return states, stats
