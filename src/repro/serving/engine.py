"""Grammar-constrained serving engine (paper Algorithm 3 as a runtime).

The engine is built around **continuous batching** over a fixed pool of
`B = slots` decode slots:

  * one jitted `[B, V]` decode step advances every active request at once
    (decode caches are allocated `[.., B, ..]` up front; per-request
    prefill results are inserted into their slot on admission),
  * the host side of Algorithm 2 runs in two context-split stages
    (`GrammarConstraint.ci_rows_batch` + `cd_overlay_batch`): a `[B, A]`
    matrix of PRECOMPUTED store row ids and a `[B, W]` residue-word
    overlay covering the few context-dependent tokens per step,
  * a single fused mask+filter+sample device call unions the packed
    store rows with the residue overlay and draws every slot's next
    token with per-request greedy/temperature/top-k/top-p
    (`repro.kernels.fused_select`; an all-greedy batch rides a
    host-static argmax-only variant, sampling batches precomputed
    Gumbel noise) — only the `[B]` sampled ids come back to the host,
    never `[B, V]`,
  * the paper's *opportunistic masking* fast path (§5 Baselines) validates
    the whole batch's unconstrained proposals first and computes mask rows
    only for the slots whose proposal was rejected,
  * the exactness wrapper survives batching: because the α≤1 mask store
    over-approximates (sound, not complete — paper §4.4), sampled ids are
    verified against the precise parser oracle; invalid picks are demoted
    and the affected rows resampled on device, so emitted text provably
    stays in L_p(G) and terminates only when in L(G),
  * finished requests free their slot and the next queued request is
    admitted immediately (no round-robin sweep), so the pool stays full
    under load.

The per-step bodies of every mode (dense / paged / speculative) live on
ONE shared step-loop core, `serving/loop.py` — the `generate*` entry
points here drive that loop to completion over a fixed request list,
and `serving/async_engine.py` drives the same loop persistently with
live admission, streaming, cancellation and deadlines (docs/serving.md).
This module keeps the engine's device plumbing (jitted decode / fused
mask+sample / paged feed builders), the admission and selection
machinery the modes call into, and the request/stats dataclasses.

`generate_sequential` keeps the original one-request-at-a-time stepping
path for comparison benchmarks (benchmarks/bench_tables.py::
batched_engine_throughput) and as an oracle for the batched scheduler.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constrain import GrammarConstraint, MAX_ACCEPT
from repro.core.decoding import (DecodeConfig, NEG_INF, select_batch,
                                 select_span)
from repro.core.tokenizer import BOS_ID, ByteTokenizer, EOS_ID
from repro.distributed.api import use_sharding
from repro.distributed.sharding import (serving_cache_shardings,
                                        serving_param_shardings,
                                        serving_rules,
                                        serving_store_sharding)
from repro.core.constrain import accept_width
from repro.kernels.fused_select.ops import (fused_mask_select,
                                            gumbel_noise)
from repro.kernels.masked_logits.ops import (apply_grammar_mask,
                                             apply_grammar_mask_span)
from repro.obs import Telemetry
from repro.serving.kvpool import PagedAllocator, PoolExhausted
from repro.spec.scheduler import SPAN_BUCKETS, SlotPhase, SpecConfig

# shared disabled telemetry: the `obs=None` default of the selection
# helpers — span() returns the no-op NULL_SPAN, so un-instrumented
# callers (tests poking _select_tokens directly) pay nothing
_OBS_OFF = Telemetry(enabled=False)

# span widths the paged feed path jits against (chunked prefill drains
# prompt backlog through these; decode-only steps ride the width-1 bucket
# at exactly the dense engine's per-step cost)
FEED_BUCKETS = (1, 2, 4, 8, 16, 32)


@dataclass
class Request:
    rid: int
    prompt: bytes = b""
    grammar: Optional[str] = None           # None = unconstrained
    grammar_mode: Optional[str] = None      # "grammar_mask" (overapprox.) |
                                            # "grammar_strict" (underapprox.,
                                            # terminal-boundary-aligned);
                                            # None = engine default
    max_new_tokens: int = 128
    decode: DecodeConfig = field(default_factory=DecodeConfig)
    seed: int = 0
    deadline: Optional[float] = None        # seconds from admission; on
                                            # expiry the request finishes
                                            # with reason "deadline"


@dataclass
class RequestState:
    req: Request
    caches: object = None                   # sequential path only
    pos: int = 0
    generated: bytes = b""
    token_ids: list = field(default_factory=list)
    constraint: Optional[GrammarConstraint] = None
    done: bool = False
    finish_reason: str = ""
    pending_logits: object = None
    mask_time: float = 0.0
    mask_computations: int = 0
    opportunistic_hits: int = 0
    steps: int = 0
    slot: int = -1
    # --- speculation (generate_speculative) ---
    phase: str = SlotPhase.DECODING.value   # jumping/drafting/verifying/…
    jump_tokens: int = 0                    # grammar-forced, zero model calls
    draft_proposed: int = 0
    draft_accepted: int = 0
    # --- paged KV (engine paged mode) ---
    prompt_len: int = 0
    write_from: int = 0         # first position this slot may write into
                                # its pages (below = shared prefix pages)
    kv_pages: int = 0           # pages held when the request finished
    # --- async lifecycle (serving/loop.py) ---
    cancelled: bool = False     # set from any thread; the loop frees the
                                # slot (and its KV pages) next step
    deadline_at: Optional[float] = None     # perf_counter() expiry
    admit_t: Optional[float] = None         # perf_counter() at admission
                                            # (telemetry: slot trace span)


@dataclass
class EngineStats:
    requests: int = 0
    tokens: int = 0
    wall: float = 0.0
    mask_time: float = 0.0
    mask_computations: int = 0
    opportunistic_hits: int = 0
    decode_steps: int = 0                   # CONSUMED batched [B,V] device
                                            # steps (one per engine step; a
                                            # discarded speculative forward
                                            # is extra device work counted
                                            # as overlap_dispatched -
                                            # overlap_hits, not here)
    batch_slots: int = 0
    mesh_devices: int = 1                   # tensor-parallel mesh size
    # --- host/device overlap (serving/loop.py::DenseMode) ---
    overlap_dispatched: int = 0             # speculative forwards launched
    overlap_hits: int = 0                   # ...that the next step consumed
    # --- speculation (generate_speculative) ---
    jump_tokens: int = 0                    # emitted with zero model calls
    draft_proposed: int = 0
    draft_accepted: int = 0
    plan_time: float = 0.0                  # host planning (jump + draft)
    # --- paged KV cache (engine paged mode) ---
    kv_pages_in_use: int = 0                # pages still referenced at end
    kv_peak_utilization: float = 0.0        # peak pages-in-use / pool size
    prefix_hit_rate: float = 0.0            # shared / total prompt tokens
    kv_page_allocs: int = 0                 # page allocations over the run
    kv_evictions: int = 0                   # cold pages evicted
    kv_cow_copies: int = 0                  # copy-on-write device copies
    # --- device-time attribution (obs/devtime; nonzero only when the
    # run had device timing on, i.e. bench/profile mode) ---
    device_forward_s: float = 0.0           # synced forward intervals
    device_mask_sample_s: float = 0.0       # synced mask+sample intervals
    overlap_hidden_s: float = 0.0           # device time hidden under
                                            # host work by the overlap gate
    attribution: Optional[dict] = None      # Telemetry.attribution() split

    @property
    def tokens_per_sec(self):
        return self.tokens / max(self.wall, 1e-9)

    @property
    def jump_fraction(self):
        return self.jump_tokens / max(self.tokens, 1)

    @property
    def acceptance_rate(self):
        return self.draft_accepted / max(self.draft_proposed, 1)

    @property
    def overlap_hit_rate(self):
        return self.overlap_hits / max(self.overlap_dispatched, 1)


@dataclass
class _SelectCtx:
    """In-flight state between `_select_dispatch` and `_select_resolve`.

    `ids` is the FIRST-round sampled ids still on device — the overlap
    path (serving/loop.py::DenseMode) feeds it straight into the next
    forward. `clean` ends True iff the host changed nothing: every
    pending slot committed exactly its first-round device id."""
    committed: dict
    pending: set
    ctr: dict
    salts: np.ndarray
    masked: object = None
    ids: object = None
    ok: object = None
    need_mask: object = None
    clean: bool = True
    mask_elapsed: float = 0.0   # ci_lookup + cd_check + mask_dispatch
                                # span seconds (resolve adds its sync
                                # span, then distributes the total per
                                # slot)


class Engine:
    def __init__(self, model, params, tokenizer: ByteTokenizer,
                 grammar_bundles: dict, max_len: int = 512,
                 opportunistic: bool = False, mask_backend: str = "jnp",
                 slots: int = 4, paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None, prefill_chunk: int = 32,
                 attn_backend: str = "auto", mesh=None,
                 trunk_shard: bool = False, overlap: bool = True,
                 grammar_mode: str = "grammar_mask",
                 telemetry: bool = True, devtime: bool = False):
        """grammar_bundles: name -> (grammar, table, store).
        slots: decode-pool width B of the batched scheduler.
        paged: serve KV through the paged pool (docs/kv_paging.md) —
        page-table attention, refcounted prefix sharing and chunked
        prefill; token-for-token identical to the dense engine.
        num_pages defaults to slots * ceil(max_len / page_size), i.e.
        the dense engine's exact KV memory budget.
        mesh: a jax Mesh with a "model" axis (launch/mesh.py::
        make_serving_mesh) — serve tensor-parallel across its devices:
        embed/lm_head, the [.., V] logits, the packed mask store and
        the whole mask hot path run vocab-sharded, with one gather in
        the selector; output stays token-for-token identical to the
        single-device engine (docs/sharding.md).
        trunk_shard: additionally shard the trunk megatron-style
        (param_spec/cache_shardings) — TPU-scale memory relief that
        gives up bit-exactness vs the single-device engine.
        overlap: host/device overlap in the dense step loop — dispatch
        step k+1's forward with the on-device sampled ids while the
        host validates step k and builds step k+1's mask rows
        (serving/loop.py). Token-for-token identical; auto-disabled
        for recurrent archs and under opportunistic masking.
        grammar_mode: default approximation family for requests that
        don't set one — "grammar_mask" (the paper's overapproximating
        dmatch rows) or "grammar_strict" (underapproximating,
        terminal-boundary-aligned rows).
        telemetry: default for the step loop's observability layer
        (docs/observability.md) — phase spans, latency histograms,
        request lifecycle, trace capture. False keeps only the exact
        count stats (tokens/mask computations/...); timing fields of
        EngineStats then read 0. Token streams are identical either
        way — instrumentation wraps host-side work only and never
        adds a device synchronization.
        devtime: bench/profile mode — device-span brackets around the
        jitted calls sync on exit (obs/devtime.py), so EngineStats and
        /stats carry true device intervals instead of dispatch lower
        bounds. OFF for serving: the default preserves the no-sync
        contract above."""
        if grammar_mode not in GrammarConstraint.MODES:
            raise ValueError(f"unknown grammar_mode {grammar_mode!r}; "
                             f"expected one of {GrammarConstraint.MODES}")
        self.model = model
        self.params = params
        self.tok = tokenizer
        self.bundles = dict(grammar_bundles)
        self.grammar_mode = grammar_mode
        self.max_len = max_len
        self.opportunistic = opportunistic
        self.mask_backend = mask_backend
        self.slots = max(1, int(slots))
        self.paged = bool(paged)
        self.page_size = max(1, int(page_size))
        self.max_pages = -(-max_len // self.page_size)
        self.num_pages = int(num_pages or self.slots * self.max_pages)
        self.prefill_chunk = max(1, int(prefill_chunk))
        self.attn_backend = attn_backend
        self.mesh = mesh
        self.trunk_shard = bool(trunk_shard)
        self.overlap = bool(overlap)
        self.telemetry_enabled = bool(telemetry)
        # bench/profile mode: step loops sync devtime brackets on exit
        # (the documented exception to the serving no-sync contract)
        self.devtime_enabled = bool(devtime)
        if mesh is not None:
            if "model" not in mesh.axis_names:
                raise ValueError(
                    "serving mesh needs a 'model' axis "
                    "(launch/mesh.py::make_serving_mesh)")
            self._rules = serving_rules(mesh, model.cfg,
                                        trunk_shard=self.trunk_shard)
            self.params = jax.device_put(
                params, serving_param_shardings(
                    params, mesh, model.cfg,
                    trunk_shard=self.trunk_shard))
        else:
            self._rules = None
        if self.paged and not model.supports_span_decode:
            raise ValueError(
                "paged KV serving needs position-addressed decode caches "
                "(attn/moe layer kinds); this arch has recurrent or "
                "side-input state")
        if self.paged and model.cfg.sliding_window:
            raise ValueError(
                "paged KV serving does not support sliding-window "
                "attention")
        self._prefill = self._shard_jit(
            lambda p, b, tl: model.prefill(p, b, cache_len=max_len,
                                           true_len=tl))
        self._decode = self._shard_jit(model.decode_step)
        # one concatenated device store for all grammars: a request's rows
        # index its grammar's block via the per-grammar row offset (shared
        # by the batched and sequential paths — the store lives on device
        # exactly once)
        self._row_offset: dict[str, int] = {}
        self._rebuild_store_cat()
        self._build_batched_fns()

    def _rebuild_store_cat(self):
        """(Re)build the concatenated device store from self.bundles.
        Insertion order fixes each grammar's block, so registering a new
        grammar appends a block without moving existing offsets."""
        self._row_offset = {}
        parts, off = [], 0
        for name, b in self.bundles.items():
            self._row_offset[name] = off
            parts.append(b[2].packed)
            off += b[2].packed.shape[0]
        words = (self.tok.vocab_size + 31) // 32
        cat = (np.concatenate(parts, axis=0) if parts
               else np.zeros((1, words), np.uint32))
        if self.mesh is not None:
            # the packed mask store lives vocab-sharded on the mesh:
            # word w of every row sits on the shard owning vocab ids
            # [w*32, (w+1)*32) — the row gather + bitwise union +
            # logits mask in kernels/masked_logits stay shard-local
            self._store_cat = jax.device_put(
                cat, serving_store_sharding(self.mesh, cat.shape[1]))
        else:
            self._store_cat = jnp.asarray(cat)

    def register_grammar(self, name: str, bundle) -> None:
        """Hot-register a freshly compiled (grammar, table, store) bundle.

        Appends the store's rows to the concatenated device store and
        makes `name` servable by subsequent requests — no engine restart.
        NOT safe concurrent with a running step: callers must invoke it
        between steps (AsyncEngine.load_grammar posts it onto the step
        loop's control queue, which drains at the top of each loop
        iteration). Jitted mask fns take the store as a call argument,
        so the grown array just triggers one benign retrace.
        """
        if name in self.bundles:
            raise ValueError(f"grammar {name!r} already registered")
        store = bundle[2]
        if store.packed.shape[1] * 32 < self.tok.vocab_size:
            raise ValueError(
                f"store for {name!r} built for a smaller vocab "
                f"({store.packed.shape[1] * 32} < {self.tok.vocab_size})")
        self.bundles[name] = bundle
        self._rebuild_store_cat()

    def _shard_jit(self, fn):
        """jit, plus (when a mesh is configured) the serving
        `use_sharding` context around every call — shard_hint rules
        bind at trace time, and per-bucket retraces re-enter them."""
        jf = jax.jit(fn)
        if self.mesh is None:
            return jf

        def call(*args, **kwargs):
            with use_sharding(self.mesh, self._rules):
                return jf(*args, **kwargs)
        return call

    def _note_jit_cost(self, tele, name: str, fn, *args) -> None:
        """Lazily attach static roofline terms (distributed/hlo_cost
        over the compiled HLO) for a jitted fn to the devtime registry —
        once per fn name, only in bench/profile mode, at the exact
        shapes the caller just dispatched (the lowering hits the
        compilation cache, so this is a walk, not a recompile). Sharded
        closures and anything else without .lower() are skipped."""
        devtime = tele.devtime
        if not devtime.enabled or name in devtime.costs:
            return
        devtime.costs[name] = {"flops": 0.0, "hbm_bytes": 0.0,
                               "wire_bytes": 0.0}     # one attempt only
        try:
            from repro.distributed.hlo_cost import estimate_jit_cost
            c = estimate_jit_cost(fn, *args)
        except Exception:
            return
        devtime.set_cost(name, c["flops"], c["hbm_bytes"],
                         c.get("wire_bytes", 0.0))

    def _place_caches(self, caches):
        """Commit freshly-initialized decode caches / paged pools to the
        mesh (replicated in the bit-exact default; kv-head-sharded under
        trunk_shard). No-op without a mesh."""
        if self.mesh is None:
            return caches
        return jax.device_put(
            caches, serving_cache_shardings(caches, self.mesh,
                                            self.model.cfg,
                                            trunk_shard=self.trunk_shard))

    def _build_batched_fns(self):
        backend = self.mask_backend
        vocab = self.model.cfg.vocab_size

        def fused_greedy(logits, store, rows, cd, eos, constrained):
            """Host-static all-greedy variant: one fused mask+argmax
            device call — no filter math, no PRNG (the selected ids are
            the masked argmax regardless of the per-slot configs)."""
            B = logits.shape[0]
            ids, masked = fused_mask_select(
                logits, store, rows, cd, eos, constrained,
                jnp.ones((B,), bool), jnp.ones((B,), jnp.float32),
                jnp.zeros((B,), jnp.int32), jnp.ones((B,), jnp.float32),
                backend=backend)
            ok = jnp.any(masked > NEG_INF / 2, axis=-1)
            return masked, ids, ok

        def fused_sample(logits, store, rows, cd, eos, constrained,
                         greedy, temp, top_k, top_p, noise):
            """Sampling variant: precomputed Gumbel noise replaces the
            per-call categorical streams — `argmax(filtered + noise)`
            selects the bit-identical token (kernels/fused_select) while
            the PRNG work rides the previous step's resolve."""
            ids, masked = fused_mask_select(
                logits, store, rows, cd, eos, constrained,
                greedy, temp, top_k, top_p, noise=noise, backend=backend)
            ok = jnp.any(masked > NEG_INF / 2, axis=-1)
            return masked, ids, ok

        def resample(masked, ban, redo, greedy, temp, top_k, top_p, keys):
            V = masked.shape[-1]
            hit = (jnp.arange(V)[None, :] == ban[:, None]) & redo[:, None]
            masked = jnp.where(hit, jnp.asarray(NEG_INF, masked.dtype),
                               masked)
            ids = select_batch(masked, keys, greedy, temp, top_k, top_p)
            ok = jnp.any(masked > NEG_INF / 2, axis=-1)
            return masked, ids, ok

        def insert(full, one, b):
            return jax.tree.map(
                lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                    f, o.astype(f.dtype), b, axis=1), full, one)

        def span_mask_select(logits, store, rows, cd, eos, constrained,
                             greedy, temp, top_k, top_p, keys):
            """Fused speculation pass: grammar-mask a [B, S, V] span and
            select a token at every position (constrained positions via
            the precomputed store rows + per-position residue overlay,
            padding/unconstrained pass through). The accept test is a
            host-side == against the [B, S] ids."""
            masked = apply_grammar_mask_span(logits, store, rows, eos,
                                             backend=backend,
                                             constrained=constrained,
                                             cd=cd)
            ids = select_span(masked, keys, greedy, temp, top_k, top_p)
            ok = jnp.any(masked > NEG_INF / 2, axis=-1)
            return masked, ids, ok

        def span_feed_paged(p, c, toks, pos, fm, pt, sel):
            """Paged feed for the plain engine: decode a [B, S] span
            through the page tables and return each slot's logits at its
            selection index (clamped; non-selecting rows are ignored by
            the caller), so the downstream mask/sample machinery sees
            the same [B, V] it would from a dense decode_step."""
            logits, c = self.model.decode_span(
                p, c, toks, pos, feed_mask=fm,
                batch_ctx={"page_table": pt,
                           "paged_backend": self.attn_backend})
            B, S = toks.shape
            sel_logits = logits[jnp.arange(B), jnp.clip(sel, 0, S - 1)]
            return sel_logits, c

        def copy_page(c, s, d):
            """Apply one allocator-directed COW copy to the page pools
            (leaves are [count, P, ps, K, Dh])."""
            return jax.tree.map(lambda a: a.at[:, d].set(a[:, s]), c)

        self._fused_greedy = self._shard_jit(fused_greedy)
        self._fused_sample = self._shard_jit(fused_sample)
        self._gumbel = self._shard_jit(
            lambda keys: gumbel_noise(keys, vocab))
        self._noise_cache = None    # (keys bytes, [B, V] device noise)
                                    # speculatively dispatched by the
                                    # previous step's resolve
        self._resample = self._shard_jit(resample)
        self._sample_plain = self._shard_jit(select_batch)
        self._insert_caches = self._shard_jit(insert)
        self._span_mask_select = self._shard_jit(span_mask_select)
        self._span_decode = self._shard_jit(
            lambda p, c, toks, pos, fm: self.model.decode_span(
                p, c, toks, pos, feed_mask=fm))
        self._span_decode_paged = self._shard_jit(
            lambda p, c, toks, pos, fm, pt: self.model.decode_span(
                p, c, toks, pos, feed_mask=fm,
                batch_ctx={"page_table": pt,
                           "paged_backend": self.attn_backend}))
        self._span_feed_paged = self._shard_jit(span_feed_paged)
        self._copy_page = self._shard_jit(copy_page)

    # ------------------------------ lifecycle -----------------------------

    def _make_constraint(self, req: Request) -> Optional[GrammarConstraint]:
        if req.grammar is None:
            return None
        g, tab, store = self.bundles[req.grammar]
        return GrammarConstraint(g, tab, store, self.tok,
                                 mode=req.grammar_mode or self.grammar_mode)

    def _request_ids(self, req: Request) -> list[int]:
        ids = self._prompt_ids(req)
        if len(ids) == 1:
            # prefill needs >= 1 token before the decode loop takes
            # over; re-feeding the last prompt token would double-step
            # recurrent caches, so prepend BOS instead
            ids = [BOS_ID] + ids
        return ids

    def _bucketed_prompt(self, ids: list[int]):
        """Zero-pad a prompt to its power-of-two jit bucket (capped at
        max_len) -> ([1, bucket] int32, n). The prefill specializes once
        per bucket instead of once per length; `true_len = n` masks the
        padded tail's cache entries. Recurrent/SSM layer kinds fold a
        padded tail into their carried state (true_len can't mask it),
        so those archs keep exact-length prefill."""
        n = len(ids)
        bucket = n
        if self.model.prefill_padding_safe:
            bucket = max(n, min(1 << max(0, n - 1).bit_length(),
                                self.max_len))
        prompt = np.zeros((1, bucket), np.int32)
        prompt[0, :n] = ids
        return jnp.asarray(prompt), n

    def _admit_common(self, req: Request, b: int, caches):
        """Shared slot admission: build request state, prefill the
        prompt, insert its caches into slot b. Returns (state, caches);
        per-loop array updates stay with the caller.

        The prompt is zero-padded to a power-of-two bucket before the
        prefill call, so the jitted prefill specializes once per bucket
        instead of once per distinct prompt length (true_len masks the
        padded tail's cache entries); admission cost amortizes across
        requests."""
        st = RequestState(req=req, slot=b)
        st.constraint = self._make_constraint(req)
        ids = self._request_ids(req)
        prompt, n = self._bucketed_prompt(ids[:-1])
        _, pc = self._prefill(self.params, {"tokens": prompt}, jnp.int32(n))
        caches = self._insert_caches(caches, pc, jnp.int32(b))
        st.token_ids = list(ids)
        st.pos = len(ids)
        st.prompt_len = len(ids)
        return st, caches

    def _prompt_ids(self, req: Request) -> list[int]:
        ids = self.tok.encode(req.prompt) if req.prompt else []
        if not ids:
            ids = [BOS_ID]
        return ids

    def _commit(self, st: RequestState, token: int):
        st.token_ids.append(token)
        st.pos += 1
        if token == EOS_ID:
            st.done = True
            st.finish_reason = "eos"
            return
        st.generated += self.tok.id_to_bytes[token]
        if st.steps >= st.req.max_new_tokens:
            st.done = True
            st.finish_reason = "length"
        if st.pos >= self.max_len - 1:
            st.done = True
            st.finish_reason = "max_len"

    # ============================ batched path ============================

    def _step_keys(self, seeds: np.ndarray, salts: np.ndarray,
                   attempt: int) -> np.ndarray:
        """[B, 2] uint32 threefry key data: one counter-mode stream per
        slot, advanced by (salts[b], attempt). salts are PER-SLOT step
        counters (st.steps), not the global engine step, so a slot's
        sample stream depends only on its own progress — which is what
        keeps the paged engine (whose chunked prefill consumes engine
        steps) token-for-token identical to the dense one. Greedy rows
        ignore keys."""
        k = np.empty((seeds.shape[0], 2), np.uint32)
        k[:, 0] = seeds
        k[:, 1] = (salts.astype(np.uint32) << np.uint32(4)) | \
            np.uint32(attempt & 0xF)
        return k

    def _fallback_exact(self, st: RequestState, row: np.ndarray,
                        attempt_salt: int) -> Optional[int]:
        """Rare slow path: the sampled ids kept failing the oracle (or the
        mask emptied after demotions). Exact-filter the remaining allowed
        set (|allowed| oracle calls) and draw host-side, so the step never
        dead-ends while a valid continuation exists. top-k/top-p are not
        re-applied here — this path fires when the mask kept only a
        handful of candidates anyway."""
        gc = st.constraint
        allowed = np.where(row > NEG_INF / 2)[0]
        valid = [int(t) for t in allowed
                 if t == EOS_ID or gc.is_valid_extension(st.generated,
                                                         int(t))]
        if not valid:
            return None
        sub = row[valid].astype(np.float64)
        if st.req.decode.method == "greedy":
            return valid[int(np.argmax(sub))]
        temp = max(st.req.decode.temperature, 1e-6)
        p = np.exp((sub - sub.max()) / temp)
        p /= p.sum()
        rng = np.random.default_rng(
            (st.req.seed * 1000003 + st.steps * 31 + attempt_salt)
            & 0xFFFFFFFF)
        return int(rng.choice(valid, p=p))

    def _select_dispatch(self, logits, slot_state, pending: set,
                         seeds, greedy, temp, top_k, top_p, obs=None):
        """Phase A of per-step token selection: the opportunistic fast
        path (host sync) and the fused mask+sample DISPATCH — no sync of
        the sampled ids. Returns a `_SelectCtx` whose `.ids` device array
        is what the overlap path feeds into the next forward before the
        host ever sees it. `_select_resolve` is phase B. `obs` is the
        step loop's Telemetry; its spans only bracket host work that was
        already timed — no device sync is added."""
        if obs is None:
            obs = _OBS_OFF
        # reprolint: mutated-inflight=greedy,temp,top_k,top_p admit() rewrites the decode configs while dispatches are in flight
        B = self.slots
        committed: dict[int, int] = {}
        pending = set(pending)
        ctr = {"mask_computations": 0, "opportunistic_hits": 0}
        salts = np.array([slot_state[b].steps if slot_state[b] else 0
                          for b in range(B)], np.uint32)
        ctx = _SelectCtx(committed=committed, pending=pending, ctr=ctr,
                         salts=salts)

        # ---- opportunistic fast path (whole batch at once) ----------
        if self.opportunistic and any(
                slot_state[b].constraint is not None for b in pending):
            with obs.span("opportunistic"):
                keys = self._step_keys(seeds, salts, 0)
                prop = np.asarray(self._sample_plain(
                    logits, jnp.asarray(keys),
                    jnp.asarray(greedy.copy()),
                    jnp.asarray(temp.copy()),
                    jnp.asarray(top_k.copy()),
                    jnp.asarray(top_p.copy())))
                ctx.clean = False   # committed ids came from the
                                    # unmasked proposal stream
                for b in list(pending):
                    st = slot_state[b]
                    t = int(prop[b])
                    if st.constraint is None:
                        committed[b] = t
                        pending.discard(b)
                    elif st.constraint.is_valid_extension(st.generated, t):
                        st.opportunistic_hits += 1
                        ctr["opportunistic_hits"] += 1
                        committed[b] = t
                        pending.discard(b)

        if not pending:
            return ctx

        # ---- context-split host stages + fused mask/select dispatch -
        # Three spans partition the old rows_build+mask_dispatch
        # bracket: ci_lookup (parse, group, emit precomputed row ids),
        # cd_check (the context-dependent residue overlay — a handful
        # of packed words per slot), mask_dispatch (the device call).
        # Their sum (ctx.mask_elapsed) keeps the historical mask_time
        # accounting byte-identical.
        with obs.span("ci_lookup") as sp_ci:
            cons = [slot_state[b].constraint
                    if (b in pending and slot_state[b] is not None)
                    else None for b in range(B)]
            texts = [slot_state[b].generated if slot_state[b] else b""
                     for b in range(B)]
            offs = np.array(
                [self._row_offset.get(slot_state[b].req.grammar, 0)
                 if slot_state[b] is not None else 0
                 for b in range(B)], np.int64)
            rows, eos, _, groups = GrammarConstraint.ci_rows_batch(
                cons, texts, max_accept=MAX_ACCEPT, row_offsets=offs)
        with obs.span("cd_check") as sp_cd:
            cd = GrammarConstraint.cd_overlay_batch(
                cons, groups, int(self._store_cat.shape[1]))
        with obs.device_span("mask_sample") as dv:
            with obs.span("mask_dispatch") as sp_disp:
                need_mask = np.array([c is not None for c in cons], bool)
                # numpy args go into the jitted calls DIRECTLY — an
                # explicit jnp.asarray round-trip costs ~25x the
                # dispatch itself on CPU. The per-step arrays (rows,
                # cd, eos, need_mask, keys) are freshly allocated each
                # step; the long-lived decode-config arrays are mutated
                # by admit(), so they ship private copies (the same
                # zero-copy aliasing hazard class as the paged feed).
                if bool(np.all(greedy)):
                    ctx.masked, ctx.ids, ctx.ok = self._fused_greedy(  # reprolint: dispatch
                        logits, self._store_cat, rows, cd, eos,
                        need_mask)
                    cost_args = (logits, self._store_cat, rows, cd,
                                 eos, need_mask)
                    cost_fn = self._fused_greedy
                else:
                    keys = self._step_keys(seeds, salts, 1)
                    noise = self._noise_take(keys)
                    ctx.masked, ctx.ids, ctx.ok = self._fused_sample(  # reprolint: dispatch
                        logits, self._store_cat, rows, cd, eos,
                        need_mask, greedy.copy(), temp.copy(),
                        top_k.copy(), top_p.copy(), noise)
                    cost_args = (logits, self._store_cat, rows, cd,
                                 eos, need_mask, greedy.copy(),
                                 temp.copy(), top_k.copy(),
                                 top_p.copy(), noise)
                    cost_fn = self._fused_sample
            # host span stays dispatch-only; in bench/profile mode the
            # device bracket blocks on the sampled ids here
            dv.done((ctx.ids, ctx.ok))
        self._note_jit_cost(obs, "mask_sample", cost_fn, *cost_args)
        ctx.need_mask = need_mask
        ctr["mask_computations"] += int(need_mask.sum())
        ctx.mask_elapsed = sp_ci.dur + sp_cd.dur + sp_disp.dur
        return ctx

    # --------------------- Gumbel-noise speculation ---------------------

    def _noise_take(self, keys: np.ndarray):
        """[B, V] device Gumbel noise for exactly these threefry keys.
        The previous step's resolve usually dispatched it speculatively
        (`_noise_prefetch`); a miss — admission changed a seed, a slot
        finished — computes it inline. Either way the noise is the
        bitwise `jax.random.gumbel` stream of `keys`, so sampling
        equivalence never depends on the cache."""
        kb = keys.tobytes()
        cached, self._noise_cache = self._noise_cache, None
        if cached is not None and cached[0] == kb:
            return cached[1]
        return self._gumbel(keys)

    def _noise_prefetch(self, slot_state, seeds: np.ndarray) -> None:
        """Dispatch next step's first-round noise with PREDICTED salts
        (every live slot advances one step). The dispatch is async —
        the host returns immediately; the device fills the noise while
        the host runs the oracle loop and the next forward."""
        B = self.slots
        salts = np.array(
            [slot_state[b].steps + 1
             if slot_state[b] is not None and not slot_state[b].done
             else 0 for b in range(B)], np.uint32)
        keys = self._step_keys(seeds, salts, 1)
        self._noise_cache = (keys.tobytes(), self._gumbel(keys))

    def _select_resolve(self, ctx, slot_state,
                        seeds, greedy, temp, top_k, top_p, obs=None):
        """Phase B: sync the sampled ids, verify against the exact
        oracle, demote+resample on device, exact-filter fallback.
        Returns (committed, counters); `ctx.clean` stays True only when
        every pending slot committed its FIRST-round device id — the
        overlap path's speculative forward is valid exactly then."""
        if obs is None:
            obs = _OBS_OFF
        B = self.slots
        committed, pending, ctr = ctx.committed, ctx.pending, ctx.ctr
        salts = ctx.salts
        if ctx.ids is None:
            return committed, ctr
        masked = ctx.masked
        with obs.span("select_resolve") as sp_sync:
            ids_h, ok_h = np.asarray(ctx.ids), np.asarray(ctx.ok)
        # speculative Gumbel dispatch for the NEXT step: the device
        # draws the noise while this step's oracle loop runs
        if not bool(np.all(greedy)):
            self._noise_prefetch(slot_state, seeds)
        n_masked = int(ctx.need_mask.sum())
        # ci lookup + cd check + dispatch + sync — the historical
        # mask_time definition (the oracle loop was never part of it)
        elapsed = sp_sync.dur + ctx.mask_elapsed
        for b in np.where(ctx.need_mask)[0]:
            slot_state[b].mask_computations += 1
            slot_state[b].mask_time += elapsed / max(n_masked, 1)

        # rejection wrapper: the α<=1 mask is sound but over-
        # approximate; verify with the exact oracle, demote invalid
        # picks on device, resample only the affected rows. Only
        # [B] ids/flags ever cross back to the host here.
        with obs.span("host_oracle"):
            for attempt in range(2, 6):
                redo = np.zeros(B, bool)
                ban = np.zeros(B, np.int32)
                for b in sorted(pending):
                    st = slot_state[b]
                    if st.constraint is None:
                        committed[b] = int(ids_h[b])
                        pending.discard(b)
                        continue
                    if not ok_h[b]:
                        ctx.clean = False
                        continue    # mask exhausted -> fallback
                    t = int(ids_h[b])
                    if t == EOS_ID or st.constraint.is_valid_extension(
                            st.generated, t):
                        committed[b] = t
                        pending.discard(b)
                    else:
                        redo[b] = True
                        ban[b] = t
                if not redo.any():
                    break
                ctx.clean = False
                keys = self._step_keys(seeds, salts, attempt)
                masked, ids, ok = self._resample(
                    masked, jnp.asarray(ban), jnp.asarray(redo),
                    jnp.asarray(greedy), jnp.asarray(temp),
                    jnp.asarray(top_k), jnp.asarray(top_p),
                    jnp.asarray(keys))
                ids_h, ok_h = np.asarray(ids), np.asarray(ok)

            # exact-filter fallback for slots that never validated
            for b in sorted(pending):
                ctx.clean = False
                st = slot_state[b]
                nxt = self._fallback_exact(st, np.asarray(masked[b]),
                                           st.steps)
                if nxt is None:
                    # nothing valid (should not happen for C_k in
                    # L_p(G)) — stop this request
                    st.done = True
                    st.finish_reason = "mask_exhausted"
                else:
                    committed[b] = nxt
                pending.discard(b)
        return committed, ctr

    def _select_tokens(self, logits, slot_state, pending: set,
                       seeds, greedy, temp, top_k, top_p, obs=None):
        """Shared per-step token selection for the batched engines (the
        dense loop and the paged feed loop run this IDENTICAL code on a
        [B, V] logits matrix — equivalence by construction): the
        opportunistic fast path, one fused mask+sample device call, the
        on-device demote/resample rejection wrapper, and the exact-filter
        fallback. `pending` names the slots that need a token this step;
        rows outside it are ignored. Returns (committed: {slot: token},
        counters). Slots whose mask dead-ends are marked done
        ("mask_exhausted") and excluded from `committed`."""
        ctx = self._select_dispatch(logits, slot_state, pending, seeds,
                                    greedy, temp, top_k, top_p, obs=obs)
        return self._select_resolve(ctx, slot_state, seeds, greedy, temp,
                                    top_k, top_p, obs=obs)

    def generate(self, requests: list[Request], verbose: bool = False):
        """Continuous batching over a fixed pool of `self.slots` slots.

        Per engine step: ONE [B, V] decode for every active slot, ONE
        fused mask+sample call (constrained and unconstrained slots mixed
        via the `constrained` flag), and only [B]-sized transfers back to
        the host. Finished slots are refilled from the queue immediately.
        With `overlap` (the default) the next step's forward is
        dispatched with the on-device sampled ids before the host syncs,
        hiding the host-side grammar work behind device compute.

        In paged mode the same selection machinery runs behind the paged
        feed loop: chunked prefill, prefix sharing and page-table
        attention replace the dense per-slot caches.

        The step body lives in serving/loop.py (one shared loop for the
        sync and async engines, all modes)."""
        from repro.serving.loop import ListSource, StepLoop, make_mode
        loop = StepLoop(self, make_mode(self), ListSource(requests),
                        verbose=verbose)
        return loop.run()

    # ============================= paged path =============================
    # Paged KV serving (docs/kv_paging.md): the dense per-slot decode
    # caches are replaced by ONE global page pool per attention layer;
    # slots read/write through refcounted page tables. Admission
    # chain-hashes the prompt at page granularity and ATTACHES matching
    # shared pages instead of re-prefilling them; the unmatched tail
    # drains as chunked prefill through the same per-step span call that
    # decoding slots ride at width 1 — so one long admission never
    # stalls the pool, and N requests sharing a schema/system prompt pay
    # its prefill once and hold one physical copy.

    def _paged_setup(self, B):
        """Fresh allocator + zeroed device page pools for one run."""
        alloc = PagedAllocator(self.num_pages, self.page_size, B,
                               self.max_pages)
        caches = self._place_caches(
            self.model.init_paged_caches(self.num_pages, self.page_size))
        return alloc, caches

    def _admit_paged(self, req: Request, b: int, alloc, ids=None):
        """Paged admission: no prefill device call here — the prompt is
        attached from shared pages where its page-aligned prefix
        chain-hash hits, and the rest becomes feed backlog drained by
        the chunked-prefill span steps."""
        st = RequestState(req=req, slot=b)
        st.constraint = self._make_constraint(req)
        if ids is None:
            ids = self._request_ids(req)
        st.token_ids = list(ids)
        st.pos = len(ids)
        st.prompt_len = len(ids)
        plan = alloc.admit(b, ids)
        st.write_from = plan.write_from
        return st, plan

    def _paged_can_admit(self, alloc, req, ids_cache) -> bool:
        """Admission gate: only admit a request when its whole prompt's
        pages can be reserved (prefix hits just reduce the need). Its
        token ids are computed once and cached by rid, so a request
        blocked for many steps isn't re-tokenized each step."""
        ids = ids_cache.get(req.rid)
        if ids is None:
            ids = ids_cache[req.rid] = self._request_ids(req)
        return alloc.can_admit(len(ids))

    def _paged_wake(self, alloc, b, st, feed_pos, waiting) -> bool:
        """Re-check a waiting slot (shared prefix pages still being
        filled by another slot); on wake, adopt the — possibly
        orphan-claim-lowered — feed/write cursors. True = slot live."""
        if not waiting[b]:
            return True
        r = alloc.ready(b)
        if r is None:
            return False
        waiting[b] = False
        feed_pos[b], st.write_from = r
        return True

    def _feed_width(self, pend: list) -> int:
        """Smallest feed bucket covering the widest per-slot backlog,
        capped at prefill_chunk (steady-state decode rides width 1)."""
        cands = [s for s in FEED_BUCKETS if s <= self.prefill_chunk] or [1]
        top = max(pend)
        for S in cands:
            if S >= top:
                return S
        return cands[-1]

    def _prepare_feed(self, alloc, caches, b, st, fs, k):
        """Reserve/COW pages for slot b's feed of positions [fs, fs+k)
        (only [max(fs, write_from), fs+k) is actually written) and apply
        any copy-on-write device copies. Returns the updated caches, or
        None if the pool is truly exhausted — the caller finishes the
        request with 'kv_oom' instead of crashing the pool."""
        ws = max(fs, st.write_from)
        if fs + k > ws:
            try:
                for s_, d_ in alloc.prepare_write(b, ws, fs + k):
                    caches = self._copy_page(caches, jnp.int32(s_),
                                             jnp.int32(d_))
            except PoolExhausted:
                st.done = True
                st.finish_reason = "kv_oom"
                return None
        return caches

    def _kv_stats(self, stats: EngineStats, alloc) -> EngineStats:
        stats.kv_pages_in_use = alloc.pages_in_use
        stats.kv_peak_utilization = alloc.peak_in_use / max(alloc.P, 1)
        stats.prefix_hit_rate = alloc.prefix_hit_rate
        stats.kv_page_allocs = alloc.total_allocs
        stats.kv_evictions = alloc.evictions
        stats.kv_cow_copies = alloc.cow_copies
        return stats

    # ========================== speculative path ==========================
    # Grammar-aware speculative decoding on top of the batched pool:
    # jump-forward (grammar-forced tokens committed with zero model
    # calls) + draft-verify (host proposer drafts, one fused [B, S, V]
    # span decode + mask + select verifies the whole window). Greedy
    # speculative decoding is token-for-token identical to generate():
    # forced tokens are the masked argmax's only support point, accepted
    # drafts equal the span selection the plain engine would have made,
    # and the bonus/demote path replays the same deterministic order.

    def _resolve_span_selection(self, st: RequestState, masked_dev, b: int,
                                idx: int, proposed: int, row_ok: bool,
                                salt: int) -> Optional[int]:
        """Validate one span selection against the exact oracle, demoting
        invalid picks in the same order as generate()'s device-side
        rejection wrapper (4 demote rounds, then the exact-filter
        fallback). Pulls the [V] masked row to the host only when the
        first pick fails (rare)."""
        gc = st.constraint
        if gc is None:
            return proposed
        row = None
        t = proposed
        if row_ok:
            for attempt in range(4):
                if t == EOS_ID or gc.is_valid_extension(st.generated, t):
                    return t
                if row is None:
                    row = np.asarray(masked_dev[b, idx], np.float32)
                row[t] = NEG_INF
                if not (row > NEG_INF / 2).any():
                    break
                if st.req.decode.method == "greedy":
                    t = int(np.argmax(row))
                else:
                    # host-side redraw (temperature softmax over the
                    # demoted row; sampling carries no equivalence
                    # obligation — see docs/speculation.md)
                    temp = max(st.req.decode.temperature, 1e-6)
                    r = row.astype(np.float64)
                    finite = r > NEG_INF / 2
                    p = np.where(finite, np.exp((r - r[finite].max())
                                                / temp), 0.0)
                    p /= p.sum()
                    rng = np.random.default_rng(
                        (st.req.seed * 1000003 + st.steps * 31
                         + salt * 7 + attempt) & 0xFFFFFFFF)
                    t = int(rng.choice(len(r), p=p))
        if row is None:
            row = np.asarray(masked_dev[b, idx], np.float32)
        return self._fallback_exact(st, row, salt)

    @staticmethod
    def _choose_span(desired: list) -> int:
        """Pick the span bucket maximizing committed-tokens-per-compute:
        a span of width S costs ~B*S model work, and serves min(d, S)
        useful positions per slot. The +0.3 denominator models the fixed
        per-step overhead, breaking ties toward wider spans."""
        top = max(desired)
        best, best_score = 1, -1.0
        for S in SPAN_BUCKETS:
            score = sum(min(d, S) for d in desired) / (S + 0.3)
            if score > best_score:
                best, best_score = S, score
            if S >= top:
                break
        return best

    def _span_keys(self, seeds: np.ndarray,
                   salts: np.ndarray, S: int) -> np.ndarray:
        """[B, S, 2] uint32 threefry key data: one counter-mode stream
        per (slot, span position). `salts` are PER-SLOT step counters
        (st.steps), like `_step_keys` — a slot's sample stream depends
        only on its own progress, never on the loop-global step count,
        so async admission timing cannot change sampled speculative
        streams (a slot commits >= 1 token per selecting span, so
        consecutive spans' salt<<6 windows never collide for S <= 64).
        Greedy rows ignore keys."""
        B = seeds.shape[0]
        k = np.empty((B, S, 2), np.uint32)
        k[:, :, 0] = seeds[:, None]
        k[:, :, 1] = ((salts.astype(np.uint32)[:, None] << np.uint32(6))
                      + np.arange(S, dtype=np.uint32)[None, :])
        return k

    def generate_speculative(self, requests: list[Request],
                             spec: Optional[SpecConfig] = None,
                             verbose: bool = False):
        """Continuous batching with grammar-aware speculation.

        Per engine step and per active slot: the scheduler first chases
        grammar-FORCED tokens (jump-forward, committed host-side with no
        model call), then drafts up to K oracle-vetted tokens from the
        slot's own history. One fused span decode replays forced tokens
        and scores drafts for every slot at once ([B, S, V], S bucketed),
        one fused span mask+select turns that into per-position picks,
        and the host accepts each slot's longest matching draft prefix
        plus a bonus token. Slots with nothing to speculate ride the same
        span at width 1 — identical cost to generate()'s step.

        The step body lives in serving/loop.py::SpecMode (dense or
        paged, on the same shared loop as every other mode).
        """
        from repro.serving.loop import ListSource, SpecMode, StepLoop
        loop = StepLoop(self, SpecMode(self, spec), ListSource(requests),
                        verbose=verbose)
        return loop.run()


    # =========================== sequential path ==========================
    # The original one-request-at-a-time engine (paper Algorithm 3,
    # round-robin). Kept as the baseline the batched scheduler is
    # benchmarked against, and as a behavioral oracle in tests.

    def _start(self, req: Request) -> RequestState:
        st = RequestState(req=req)
        st.constraint = self._make_constraint(req)
        ids = self._prompt_ids(req)
        prompt, n = self._bucketed_prompt(ids)
        logits, caches = self._prefill(self.params, {"tokens": prompt},
                                       jnp.int32(n))
        st.caches = caches
        st.pos = n
        st.token_ids = list(ids)
        st.pending_logits = logits[:, n - 1]    # prediction for next token
        return st

    def _logits(self, st: RequestState):
        if getattr(st, "pending_logits", None) is not None:
            lg = st.pending_logits
            st.pending_logits = None
            return lg
        tok = jnp.asarray([st.token_ids[-1]], jnp.int32)
        pos = jnp.asarray([st.pos - 1], jnp.int32)
        lg, st.caches = self._decode(self.params, st.caches, tok, pos)
        return lg  # [1, V] device array

    def _select(self, st: RequestState, logits, key) -> int:
        return int(st.req.decode.select(logits, key)[0])

    def _step(self, st: RequestState, key, obs=None) -> None:
        if obs is None:
            obs = _OBS_OFF
        logits = self._logits(st)
        st.steps += 1
        req = st.req

        if st.constraint is None:
            nxt = self._select(st, logits, key)
            self._commit(st, nxt)
            return

        gc = st.constraint
        text = st.generated

        if self.opportunistic:
            with obs.span("opportunistic"):
                proposal = self._select(st, logits, key)
                hit = gc.is_valid_extension(text, proposal)
            if hit:
                st.opportunistic_hits += 1
                self._commit(st, proposal)
                return

        with obs.span("ci_lookup") as sp_rows:
            sg = gc.step_groups(text)
            rlist = gc.group_rows(sg.groups)
            off = self._row_offset[req.grammar]
            rows = np.full((1, accept_width(len(rlist), gc.max_accept)),
                           -1, np.int32)
            rows[0, :len(rlist)] = [r + off for r in rlist]
            eos = np.array([sg.eos_allowed])
        with obs.span("cd_check") as sp_cd:
            cdw = gc.cd_overlay(sg.groups)
            cd = None if cdw is None else cdw[None, :]
        with obs.span("mask_dispatch") as sp_disp:
            masked = apply_grammar_mask(logits, self._store_cat,
                                        rows, eos,
                                        backend=self.mask_backend,
                                        cd=cd)
        st.mask_time += sp_rows.dur + sp_cd.dur + sp_disp.dur
        st.mask_computations += 1

        # rejection wrapper (see generate() for the batched variant)
        masked = np.asarray(masked, np.float32)
        for attempt in range(4):
            key, sub = jax.random.split(key)
            # masked is a long-lived host buffer mutated in place below
            # (the demote line) while jnp.asarray may zero-copy alias it
            # — safe today only because _select syncs before returning.
            # Ship a private copy, same invariant as every other
            # dispatch site (RL001).
            nxt = self._select(st, jnp.asarray(masked.copy()), sub)
            if masked[0, nxt] <= NEG_INF / 2:
                break
            if nxt == EOS_ID or gc.is_valid_extension(text, nxt):
                self._commit(st, nxt)
                return
            masked[0, nxt] = NEG_INF

        allowed = np.where(masked[0] > NEG_INF / 2)[0]
        for t in allowed:
            if not (t == EOS_ID or gc.is_valid_extension(text, int(t))):
                masked[0, t] = NEG_INF
        if (masked[0] > NEG_INF / 2).any():
            key, sub = jax.random.split(key)
            nxt = self._select(st, jnp.asarray(masked), sub)
            self._commit(st, nxt)
            return
        # nothing valid (should not happen for C_k in L_p(G)) — stop
        st.done = True
        st.finish_reason = "mask_exhausted"

    def generate_sequential(self, requests: list[Request],
                            verbose: bool = False):
        """Round-robin continuous stepping, one request per device call."""
        obs = Telemetry(enabled=self.telemetry_enabled)
        t0 = time.perf_counter()
        states = [self._start(r) for r in requests]
        keys = {r.rid: jax.random.PRNGKey(r.seed) for r in requests}
        active = list(states)
        while active:
            for st in list(active):
                keys[st.req.rid], sub = jax.random.split(keys[st.req.rid])
                self._step(st, sub, obs)
                if st.done:
                    active.remove(st)
                    if verbose:
                        print(f"[req {st.req.rid}] {st.finish_reason}: "
                              f"{st.generated[:70]!r}")
        stats = EngineStats(
            requests=len(states),
            tokens=sum(s.steps for s in states),
            wall=time.perf_counter() - t0,
            mask_time=sum(s.mask_time for s in states),
            mask_computations=sum(s.mask_computations for s in states),
            opportunistic_hits=sum(s.opportunistic_hits for s in states),
            decode_steps=sum(s.steps for s in states),
            batch_slots=1,
            mesh_devices=self.mesh.size if self.mesh else 1,
        )
        return states, stats
