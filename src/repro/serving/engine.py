"""Grammar-constrained serving engine (paper Algorithm 3 as a runtime).

Responsibilities:
  * request queue + round-robin continuous stepping,
  * per-request incremental parser / GrammarConstraint state (host side),
  * device decode steps with KV/SSM caches,
  * masked sampling via the masked_logits kernel path,
  * the paper's *opportunistic masking* fast path (validate the model's
    unconstrained proposal before paying for the mask — §5 Baselines),
  * an exactness wrapper: because the α≤1 mask store over-approximates
    (sound, not complete — paper §4.4), sampled tokens are verified with
    the precise parser oracle and rejected/resampled, so emitted text
    provably stays in L_p(G) and terminates only when in L(G).

The engine is single-host (CPU demo substrate); the batched device path
used on real meshes is exercised by launch/serve.py and the dry-run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constrain import GrammarConstraint
from repro.core.decoding import DecodeConfig, NEG_INF
from repro.core.tokenizer import ByteTokenizer, EOS_ID
from repro.kernels.masked_logits.ops import apply_grammar_mask


@dataclass
class Request:
    rid: int
    prompt: bytes = b""
    grammar: Optional[str] = None           # None = unconstrained
    max_new_tokens: int = 128
    decode: DecodeConfig = field(default_factory=DecodeConfig)
    seed: int = 0


@dataclass
class RequestState:
    req: Request
    caches: object = None
    pos: int = 0
    generated: bytes = b""
    token_ids: list = field(default_factory=list)
    constraint: Optional[GrammarConstraint] = None
    done: bool = False
    finish_reason: str = ""
    pending_logits: object = None
    mask_time: float = 0.0
    mask_computations: int = 0
    opportunistic_hits: int = 0
    steps: int = 0


@dataclass
class EngineStats:
    requests: int = 0
    tokens: int = 0
    wall: float = 0.0
    mask_time: float = 0.0
    mask_computations: int = 0
    opportunistic_hits: int = 0

    @property
    def tokens_per_sec(self):
        return self.tokens / max(self.wall, 1e-9)


class Engine:
    def __init__(self, model, params, tokenizer: ByteTokenizer,
                 grammar_bundles: dict, max_len: int = 512,
                 opportunistic: bool = False, mask_backend: str = "jnp"):
        """grammar_bundles: name -> (grammar, table, store)."""
        self.model = model
        self.params = params
        self.tok = tokenizer
        self.bundles = grammar_bundles
        self.max_len = max_len
        self.opportunistic = opportunistic
        self.mask_backend = mask_backend
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len=max_len))
        self._decode = jax.jit(model.decode_step)
        self._store_dev = {name: jnp.asarray(b[2].packed)
                           for name, b in grammar_bundles.items()}

    # ------------------------------ lifecycle -----------------------------

    def _start(self, req: Request) -> RequestState:
        st = RequestState(req=req)
        if req.grammar is not None:
            g, tab, store = self.bundles[req.grammar]
            st.constraint = GrammarConstraint(g, tab, store, self.tok)
        ids = self.tok.encode(req.prompt) if req.prompt else []
        if not ids:
            ids = [2]  # BOS
        tokens = jnp.asarray([ids], jnp.int32)
        logits, caches = self._prefill(self.params, {"tokens": tokens})
        st.caches = caches
        st.pos = len(ids)
        st.token_ids = list(ids)
        st.pending_logits = logits[:, -1]       # prediction for next token
        return st

    def _logits(self, st: RequestState):
        if getattr(st, "pending_logits", None) is not None:
            lg = st.pending_logits
            st.pending_logits = None
            return lg
        tok = jnp.asarray([st.token_ids[-1]], jnp.int32)
        pos = jnp.asarray([st.pos - 1], jnp.int32)
        lg, st.caches = self._decode(self.params, st.caches, tok, pos)
        return lg  # [1, V] device array

    # --------------------------- one decode step --------------------------

    def _select(self, st: RequestState, logits, key) -> int:
        return int(st.req.decode.select(logits, key)[0])

    def _step(self, st: RequestState, key) -> None:
        logits = self._logits(st)
        st.steps += 1
        req = st.req

        if st.constraint is None:
            nxt = self._select(st, logits, key)
            self._commit(st, nxt)
            return

        gc = st.constraint
        text = st.generated

        if self.opportunistic:
            proposal = self._select(st, logits, key)
            if gc.is_valid_extension(text, proposal):
                st.opportunistic_hits += 1
                self._commit(st, proposal)
                return

        t0 = time.time()
        sm = gc.step_rows(text)
        rows = jnp.asarray(sm.rows[None, :])
        eos = jnp.asarray([sm.eos_allowed])
        masked = apply_grammar_mask(logits, self._store_dev[req.grammar],
                                    rows, eos, backend=self.mask_backend)
        st.mask_time += time.time() - t0
        st.mask_computations += 1

        # rejection wrapper: the α<=1 mask is sound but over-approximate;
        # verify with the exact oracle, demote invalid picks, resample. If a
        # few samples fail, fall back to exact-filtering the allowed set
        # (cheap: |allowed| oracle calls) so the step never dead-ends while
        # a valid continuation exists.
        masked = np.asarray(masked, np.float32)
        for attempt in range(4):
            key, sub = jax.random.split(key)
            nxt = self._select(st, jnp.asarray(masked), sub)
            if masked[0, nxt] <= NEG_INF / 2:
                break
            if nxt == EOS_ID or gc.is_valid_extension(text, nxt):
                self._commit(st, nxt)
                return
            masked[0, nxt] = NEG_INF

        allowed = np.where(masked[0] > NEG_INF / 2)[0]
        for t in allowed:
            if not (t == EOS_ID or gc.is_valid_extension(text, int(t))):
                masked[0, t] = NEG_INF
        if (masked[0] > NEG_INF / 2).any():
            key, sub = jax.random.split(key)
            nxt = self._select(st, jnp.asarray(masked), sub)
            self._commit(st, nxt)
            return
        # nothing valid (should not happen for C_k in L_p(G)) — stop
        st.done = True
        st.finish_reason = "mask_exhausted"

    def _commit(self, st: RequestState, token: int):
        st.token_ids.append(token)
        st.pos += 1
        if token == EOS_ID:
            st.done = True
            st.finish_reason = "eos"
            return
        st.generated += self.tok.id_to_bytes[token]
        if st.steps >= st.req.max_new_tokens:
            st.done = True
            st.finish_reason = "length"
        if st.pos >= self.max_len - 1:
            st.done = True
            st.finish_reason = "max_len"

    # ------------------------------- serve --------------------------------

    def generate(self, requests: list[Request], verbose: bool = False):
        """Round-robin continuous stepping over all requests."""
        t0 = time.time()
        states = [self._start(r) for r in requests]
        keys = {r.rid: jax.random.PRNGKey(r.seed) for r in requests}
        active = list(states)
        while active:
            for st in list(active):
                keys[st.req.rid], sub = jax.random.split(keys[st.req.rid])
                self._step(st, sub)
                if st.done:
                    active.remove(st)
                    if verbose:
                        print(f"[req {st.req.rid}] {st.finish_reason}: "
                              f"{st.generated[:70]!r}")
        stats = EngineStats(
            requests=len(states),
            tokens=sum(s.steps for s in states),
            wall=time.time() - t0,
            mask_time=sum(s.mask_time for s in states),
            mask_computations=sum(s.mask_computations for s in states),
            opportunistic_hits=sum(s.opportunistic_hits for s in states),
        )
        return states, stats
