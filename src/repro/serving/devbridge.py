"""The one place the observability stack touches jax.

repro.obs is import-pure (no jax/numpy, source- and transitively-
asserted) and serving/{loop,engine,async_engine,server}.py are
source-scanned for device-sync tokens — so neither side may hold the
actual sync or profiler calls. This module is the deliberate exception:
it binds the two device capabilities into a Telemetry instance as
injected callables:

  * `DeviceTimer.sync_fn`  — blocks on dispatched arrays so a devtime
    bracket measures dispatch + execution. Only ever invoked when the
    timer is explicitly enabled (bench / profile mode); in serving mode
    span() returns the shared no-op before the callable is reachable,
    which tests/test_devtime.py proves by counting sync calls.
  * `ProfilerSession.{start,stop}` — jax.profiler trace capture for
    `POST /profile`, written to a temp dir and merged into the Chrome
    export by the server.

Binding is idempotent and failure-tolerant: a backend without a
profiler (or a jax too old to expose one) degrades to devtime-only
capture instead of breaking the server.
"""
from __future__ import annotations

import jax


def attach(tele) -> None:
    """Bind jax sync + profiler capabilities into *tele* (Telemetry).

    Safe to call repeatedly (first bind wins) and safe on a disabled
    Telemetry (binding is inert until devtime/profile mode turns on).
    """
    tele.devtime.bind(jax.block_until_ready)
    try:
        prof = jax.profiler
        tele.profiler.bind(prof.start_trace, prof.stop_trace)
    except AttributeError:
        pass        # devtime spans still capture device intervals
