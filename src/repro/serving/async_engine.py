"""AsyncEngine: asyncio front-end over the persistent step loop.

One background thread runs ONE persistent `StepLoop` (serving/loop.py)
against a live `QueueSource`; asyncio callers talk to it through
`AsyncRequest` handles:

  * `submit(req)`       — enqueue for live admission; returns a handle.
  * `handle.tokens()`   — async iterator of (token_id, bytes) pairs, one
                          per committed token, as they are committed
                          (jump-forward tokens stream mid-step).
  * `handle.result()`   — await the finished RequestState.
  * `handle.cancel()`   — frees the slot and (paged) its KV pages at the
                          next loop step; finish_reason "cancelled". A
                          still-queued request is withdrawn immediately.
  * `Request.deadline`  — seconds from admission; on expiry the request
                          finishes with reason "deadline".
  * `generate(reqs)`    — batch convenience: submit all, await all (the
                          async twin of Engine.generate, token-for-token
                          identical because it drives the same loop).
  * `drain()`           — stop admission, wait for in-flight requests,
                          stop the loop thread. `abort()` cancels
                          everything first.

Thread bridging: the loop thread never touches the event loop directly —
tokens and finishes are posted with `call_soon_threadsafe` onto
per-request asyncio queues. Cancellation crosses the other way as a
plain bool on RequestState (safe under the GIL; the loop reads it at the
next step boundary).
"""
from __future__ import annotations

import asyncio
import threading
from typing import AsyncIterator, Optional

from repro.core.tokenizer import EOS_ID
from repro.obs import Telemetry
from repro.serving.engine import Engine, Request, RequestState
from repro.serving.loop import QueueSource, StepLoop, make_mode
from repro.spec.scheduler import SpecConfig

_DONE = object()


class AsyncRequest:
    """Caller-side handle for one submitted request."""

    def __init__(self, req: Request, loop: asyncio.AbstractEventLoop):
        self.req = req
        self._aio = loop
        self._events: asyncio.Queue = asyncio.Queue()
        self._state: Optional[RequestState] = None
        self._cancelled = False
        self._finished = asyncio.Event()
        self._withdraw = None       # set by AsyncEngine (cancel-in-queue)

    # ---- loop-thread side (called via engine callbacks) ----

    def _on_admit(self, st: RequestState) -> None:
        self._state = st
        if self._cancelled:
            st.cancelled = True

    def _on_token(self, st: RequestState, token: int) -> None:
        self._aio.call_soon_threadsafe(self._events.put_nowait, token)

    def _on_finish(self, st: RequestState) -> None:
        self._state = st

        def fin():
            self._events.put_nowait(_DONE)
            self._finished.set()
        self._aio.call_soon_threadsafe(fin)

    # ---- asyncio side ----

    def cancel(self) -> None:
        """Cancel: a queued request is withdrawn immediately; an active
        one frees its slot (and KV pages) at the next loop step."""
        self._cancelled = True
        if self._state is not None:
            self._state.cancelled = True
        elif self._withdraw is not None and self._withdraw():
            st = RequestState(req=self.req)
            st.done = True
            st.finish_reason = "cancelled"
            self._state = st
            self._events.put_nowait(_DONE)
            self._finished.set()

    async def tokens(self) -> AsyncIterator[tuple[int, bytes]]:
        """Stream (token_id, token_bytes) as tokens commit. EOS is not
        yielded; the iterator just ends (await `result()` for the
        finish reason)."""
        while True:
            ev = await self._events.get()
            if ev is _DONE:
                return
            t = int(ev)
            if t == EOS_ID:
                continue
            yield t, self._tokenizer.id_to_bytes[t]

    async def text(self) -> AsyncIterator[bytes]:
        """Stream just the byte chunks."""
        async for _, tb in self.tokens():
            yield tb

    async def result(self) -> RequestState:
        await self._finished.wait()
        return self._state

    @property
    def finished(self) -> bool:
        return self._finished.is_set()


class AsyncEngine:
    """Persistent async serving wrapper around a (sync) Engine.

    The mode — dense / paged / speculative — mirrors the Engine flags,
    exactly like the synchronous entry points; `spec` switches to the
    speculative step body. The loop thread starts lazily on the first
    submit and runs until `drain()`/`abort()`.
    """

    def __init__(self, engine: Engine, spec: Optional[SpecConfig] = None,
                 speculative: bool = False,
                 overlap: Optional[bool] = None, verbose: bool = False,
                 telemetry: Optional[Telemetry] = None):
        self.engine = engine
        self._mode = make_mode(engine, spec=spec, speculative=speculative,
                               overlap=overlap)
        self._verbose = verbose
        # ONE persistent Telemetry for the engine's whole lifetime: the
        # HTTP server scrapes it live (/metrics, /stats, /trace) while
        # the loop streams — cumulative across requests, unlike the
        # per-run instance a sync generate() call creates
        self.telemetry = telemetry if telemetry is not None else \
            Telemetry(enabled=engine.telemetry_enabled)
        # bind jax sync/profiler capabilities now, not at lazy loop
        # start: POST /profile must work before the first request
        from repro.serving.devbridge import attach as _attach
        _attach(self.telemetry)
        self._source = QueueSource()
        self._handles: dict[int, AsyncRequest] = {}
        self._hlock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._loop_obj: Optional[StepLoop] = None
        self._loop_error: Optional[BaseException] = None
        self._aio: Optional[asyncio.AbstractEventLoop] = None
        self._next_rid = 0

    # ------------------------------ loop ------------------------------

    def _ensure_started(self) -> None:
        if self._thread is not None:
            return
        self._aio = asyncio.get_running_loop()
        self._loop_obj = StepLoop(
            self.engine, self._mode, self._source,
            verbose=self._verbose,
            on_token=self._dispatch_token,
            on_admit=self._dispatch_admit,
            on_finish=self._dispatch_finish,
            keep_states=False,
            telemetry=self.telemetry)
        self._thread = threading.Thread(
            target=self._run_loop, name="repro-step-loop", daemon=True)
        self._thread.start()

    def _run_loop(self) -> None:
        try:
            self._loop_obj.run()
        except BaseException as e:         # surface in result()/drain()
            self._loop_error = e
            if self._aio is not None:
                self._aio.call_soon_threadsafe(self._fail_all, e)

    def _fail_all(self, e: BaseException) -> None:
        with self._hlock:
            handles = list(self._handles.values())
            self._handles.clear()
        for h in handles:
            if not h.finished:
                h._events.put_nowait(_DONE)
                h._finished.set()

    def _handle_for(self, st: RequestState) -> Optional[AsyncRequest]:
        with self._hlock:
            return self._handles.get(st.req.rid)

    def _dispatch_admit(self, st: RequestState) -> None:
        h = self._handle_for(st)
        if h is not None:
            h._on_admit(st)

    def _dispatch_token(self, st: RequestState, token: int) -> None:
        h = self._handle_for(st)
        if h is not None:
            h._on_token(st, token)

    def _dispatch_finish(self, st: RequestState) -> None:
        h = self._handle_for(st)
        if h is not None:
            # Pop BEFORE signalling the finish: `result()` returning must
            # imply the rid is free for re-submission (the pop runs on the
            # loop thread; the finish event fires later on the asyncio
            # thread, so the reverse order races with a fresh submit()).
            with self._hlock:
                self._handles.pop(st.req.rid, None)
            h._on_finish(st)

    # ---------------------------- interface ---------------------------

    def submit(self, req: Request) -> AsyncRequest:
        """Enqueue a request for live admission. Must be called from a
        running asyncio event loop. rid must be unique among in-flight
        requests (use `next_rid()`)."""
        self._ensure_started()
        if self._loop_error is not None:
            raise RuntimeError("step loop died") from self._loop_error
        h = AsyncRequest(req, self._aio)
        h._tokenizer = self.engine.tok

        def withdraw():
            if self._source.remove(req):
                with self._hlock:
                    self._handles.pop(req.rid, None)
                self.telemetry.lifecycle.on_finish(req.rid, "cancelled")
                return True
            return False
        h._withdraw = withdraw
        with self._hlock:
            if req.rid in self._handles:
                raise ValueError(f"rid {req.rid} already in flight")
            self._handles[req.rid] = h
        # enqueue-time stamp BEFORE the queue insert: the loop thread
        # can admit the request the instant it lands in the source
        self.telemetry.lifecycle.on_enqueue(req.rid)
        try:
            self._source.submit(req)
        except BaseException:
            # e.g. the source closed (drain) between checks: don't leak
            # the registered handle (or its lifecycle record)
            with self._hlock:
                self._handles.pop(req.rid, None)
            self.telemetry.lifecycle.on_finish(req.rid, "rejected")
            raise
        return h

    def next_rid(self) -> int:
        self._next_rid += 1
        return self._next_rid - 1

    async def load_grammar(self, name: str, bundle) -> None:
        """Hot-load a freshly compiled (grammar, table, store) bundle
        into the LIVE engine — no restart, no dropped requests.

        The registration itself (growing the concatenated device store)
        runs on the step-loop thread between steps via the loop's
        control queue; this coroutine resolves once it has been applied,
        after which `name` is valid in Request.grammar. If the loop
        thread has not started yet (nothing submitted so far), the
        engine is mutated directly — there is no concurrent step to
        race with.
        """
        if self._loop_error is not None:
            raise RuntimeError("step loop died") from self._loop_error
        if self._thread is None or not self._thread.is_alive():
            self.engine.register_grammar(name, bundle)
            return
        aio = asyncio.get_running_loop()
        done = asyncio.Event()
        box: list = [None]

        def apply():
            try:
                self.engine.register_grammar(name, bundle)
            except BaseException as e:     # deliver to the awaiting caller
                box[0] = e
            aio.call_soon_threadsafe(done.set)

        self._loop_obj.post_control(apply)
        while not done.is_set():
            try:
                await asyncio.wait_for(done.wait(), timeout=0.2)
            except asyncio.TimeoutError:
                if not self._thread.is_alive() and not done.is_set():
                    # the loop exited (drain/death) without running the
                    # control: no concurrent steps remain, apply directly
                    if name not in self.engine.bundles:
                        self.engine.register_grammar(name, bundle)
                    return
        if box[0] is not None:
            raise box[0]

    async def generate(self, requests: list[Request]):
        """Async twin of Engine.generate/generate_speculative: submit
        everything, await everything. Token-for-token identical to the
        sync engine because it drives the same StepLoop + mode."""
        handles = [self.submit(r) for r in requests]
        states = [await h.result() for h in handles]
        if self._loop_error is not None:
            raise RuntimeError("step loop died") from self._loop_error
        return states, self.stats()

    def stats(self):
        if self._loop_obj is None:
            raise RuntimeError("loop not started")
        return self._loop_obj.stats()

    async def drain(self) -> None:
        """Graceful drain: no new submissions; in-flight requests run to
        completion; the loop thread exits."""
        if self._thread is None:
            return
        self._source.close()
        while self._thread.is_alive():
            await asyncio.sleep(0.01)
        if self._loop_error is not None:
            raise RuntimeError("step loop died") from self._loop_error

    async def abort(self) -> None:
        """Cancel everything in flight, then drain."""
        with self._hlock:
            handles = list(self._handles.values())
        for h in handles:
            h.cancel()
        await self.drain()
