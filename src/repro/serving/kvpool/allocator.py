"""Host-side page allocator for the paged KV-cache subsystem.

The device holds ONE global page pool per attention layer
(`[num_pages, page_size, K, Dh]`, see `Model.init_paged_caches`); this
allocator owns every piece of host metadata that decides which slot may
touch which page:

  * **page tables** — per-slot ordered page lists; the token at logical
    position p of slot b lives at (tables[b][p // ps], p % ps),
  * **refcounts** — a page is shared by any number of slots plus
    (optionally) the prefix cache; it returns to the free list only when
    the last reference drops,
  * **prefix cache** — prompt token-id chunks are chain-hashed at page
    granularity (key_i = (key_{i-1}, chunk_i), so a hit at depth i
    guarantees the whole prefix matches); admission attaches every
    matching full page instead of re-prefilling it, and registers its
    own full prompt pages so later admissions can attach them — even
    while this slot is still filling them (readiness is gated by
    `ready()` until the writer's chunked prefill catches up),
  * **copy-on-write** — `prepare_write` never lets a slot write a page
    another reference can see: a shared page overlapping the write range
    is swapped for a fresh page (with a device copy only when the write
    starts mid-page, i.e. older content in the page must survive);
    `fork` clones a slot's table by just bumping refcounts,
  * **eviction** — cached pages whose only reference is the cache itself
    ("cold") are kept as a reuse pool and evicted LRU-first when the
    free list runs dry; truly exhausted allocation raises
    `PoolExhausted`, which the engine turns into a graceful per-request
    `kv_oom` finish.

The allocator never touches device memory: `prepare_write` returns the
(src, dst) page copies the engine must apply to the pools, and
everything else is pure bookkeeping — which is what makes it
shadow-testable (tests/test_kvpool.py fuzzes it against a dense shadow
cache).
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field


class PoolExhausted(RuntimeError):
    """No free page and nothing cold to evict."""


@dataclass
class AdmitPlan:
    """What the engine must do to finish admitting a prompt.

    matched_len: positions [0, matched_len) are attached shared pages —
        already (or about to be) filled by an earlier admission.
    feed_from:   first position the engine must feed through the model
        (min(matched_len, plen - 1): at least the last prompt token is
        re-fed, read-only, to produce the first selection logits).
    write_from:  first position whose KV the engine may write
        (= matched_len; positions below are shared pages). May be
        lowered later by `ready()` if this slot claims orphaned pages.
    """
    matched_len: int
    feed_from: int
    write_from: int


@dataclass
class _SlotMeta:
    plen: int
    n_attached: int
    feed_from: int
    write_from: int


class PagedAllocator:
    def __init__(self, num_pages: int, page_size: int, slots: int,
                 max_pages_per_slot: int):
        self.P = int(num_pages)
        self.ps = int(page_size)
        self.slots = int(slots)
        self.max_pages = int(max_pages_per_slot)
        self.refcount = [0] * self.P
        self.free: deque[int] = deque(range(self.P))
        self.tables: list[list[int]] = [[] for _ in range(self.slots)]
        self.meta: dict[int, _SlotMeta] = {}
        # prefix cache: chain key -> page, page -> chain key
        self._cached: dict = {}
        self._rev: dict[int, object] = {}
        self._cold: OrderedDict[int, None] = OrderedDict()  # LRU order
        self.full: list[bool] = [False] * self.P
        self.writer: dict[int, int] = {}     # page -> slot filling it
        # stats
        self.total_allocs = 0
        self.evictions = 0
        self.cow_copies = 0
        self.prefix_hit_tokens = 0
        self.prompt_tokens = 0
        self.peak_in_use = 0

    # ------------------------------ stats --------------------------------

    @property
    def pages_in_use(self) -> int:
        return self.P - len(self.free)

    @property
    def cold_pages(self) -> int:
        return len(self._cold)

    @property
    def prefix_hit_rate(self) -> float:
        return self.prefix_hit_tokens / max(self.prompt_tokens, 1)

    def available(self) -> int:
        """Pages allocatable right now (free + evictable cold)."""
        return len(self.free) + len(self._cold)

    def can_admit(self, prompt_len: int) -> bool:
        """Conservative check (ignores prefix hits, which only reduce
        the need): enough pages for the whole prompt plus one."""
        return self.available() >= self._pages_for(prompt_len + 1)

    def metrics(self) -> dict:
        """Point-in-time pool state for telemetry scrape-time gauges
        (obs.Telemetry.register_kv). Plain ints/floats only."""
        return {
            "pages_total": self.P,
            "pages_in_use": self.pages_in_use,
            "pages_free": len(self.free),
            "pages_cold": len(self._cold),
            "peak_in_use": self.peak_in_use,
            "prefix_hit_rate": self.prefix_hit_rate,
            "page_allocs": self.total_allocs,
            "evictions": self.evictions,
            "cow_copies": self.cow_copies,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prompt_tokens": self.prompt_tokens,
        }

    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.ps)

    # --------------------------- page lifecycle --------------------------

    def _alloc(self) -> int:
        if self.free:
            p = self.free.popleft()
        elif self._cold:
            victim, _ = self._cold.popitem(last=False)      # LRU first
            self._deregister(victim)
            self.refcount[victim] -= 1                      # cache's ref
            assert self.refcount[victim] == 0, "cold page was referenced"
            self.evictions += 1
            p = victim
        else:
            raise PoolExhausted(
                f"KV page pool exhausted ({self.P} pages of {self.ps})")
        self.refcount[p] = 1
        self.full[p] = False
        self.total_allocs += 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return p

    def _deregister(self, p: int) -> None:
        key = self._rev.pop(p, None)
        if key is not None:
            self._cached.pop(key, None)
        self.writer.pop(p, None)

    def _decref(self, p: int) -> None:
        self.refcount[p] -= 1
        assert self.refcount[p] >= 0, "double free"
        if self.refcount[p] == 0:
            self._deregister(p)
            self._cold.pop(p, None)
            self.full[p] = False
            self.free.append(p)
        elif self.refcount[p] == 1 and p in self._rev:
            # cache-only reference
            if self.full[p]:
                self._cold[p] = None        # evictable, most recent last
                self._cold.move_to_end(p)
            elif p not in self.writer:
                # registered but its writer died before filling and no
                # waiter is attached: nobody will ever fill it — purge
                self._deregister(p)
                self.refcount[p] = 0
                self.full[p] = False
                self.free.append(p)

    def _attach(self, b: int, p: int) -> None:
        self.refcount[p] += 1
        self._cold.pop(p, None)             # warm again
        self.tables[b].append(p)

    # ------------------------------ admission ----------------------------

    def admit(self, b: int, ids: list[int]) -> AdmitPlan:
        """Build slot b's page table for prompt `ids`: attach every
        chain-matching cached full page, allocate + register the rest of
        the prompt's full pages (so concurrent admissions can share them
        while this slot chunk-prefills), and allocate the partial tail.
        All prompt pages are reserved up front, so a prefill in flight
        can never hit PoolExhausted (only generation growth can)."""
        assert not self.tables[b], f"slot {b} already admitted"
        ps = self.ps
        plen = len(ids)
        if self._pages_for(plen + 1) > self.max_pages:
            raise ValueError(
                f"prompt of {plen} tokens exceeds max_pages_per_slot="
                f"{self.max_pages} (page_size={ps})")
        n_full = plen // ps
        try:
            key = ()
            n_att = 0
            matching = True
            for i in range(n_full):
                key = (key, tuple(ids[i * ps:(i + 1) * ps]))
                if matching and key in self._cached:
                    self._attach(b, self._cached[key])  # prefix hit
                    n_att += 1
                    continue
                matching = False
                p = self._alloc()
                self.tables[b].append(p)
                self.writer[p] = b
                if key not in self._cached:  # may exist as a stale child
                    self._cached[key] = p    # of an evicted chain: keep it
                    self._rev[p] = key
                    self.refcount[p] += 1    # the cache's own reference
            while len(self.tables[b]) < self._pages_for(plen):
                self.tables[b].append(self._alloc())    # partial tail
        except PoolExhausted:
            self.release(b)
            raise
        matched = n_att * ps
        self.prompt_tokens += plen
        self.prefix_hit_tokens += min(matched, plen - 1)
        self.meta[b] = _SlotMeta(plen=plen, n_attached=n_att,
                                 feed_from=min(matched, plen - 1),
                                 write_from=matched)
        return AdmitPlan(matched_len=matched,
                         feed_from=self.meta[b].feed_from,
                         write_from=matched)

    def ready(self, b: int):
        """None = the slot's attached shared pages are still being
        filled by another slot's chunked prefill — keep waiting.
        Otherwise (feed_from, write_from): go. write_from drops below
        the admit plan's only if an attached page was orphaned (its
        writer released before filling it); this slot then claims the
        remaining prefix pages and re-feeds them itself."""
        m = self.meta[b]
        for i in range(m.n_attached):
            p = self.tables[b][i]
            if self.full[p]:
                continue
            w = self.writer.get(p)
            if w is not None and w != b:
                return None                 # live writer: wait
            # claim the contiguous orphaned run only — a page further
            # on with a live writer keeps its writer (we wait on it,
            # or COW off it, when our refill frontier gets there)
            for j in range(i, m.n_attached):
                pj = self.tables[b][j]
                if self.full[pj]:
                    continue
                wj = self.writer.get(pj)
                if wj is not None and wj != b:
                    break
                self.writer[pj] = b
            m.write_from = min(m.write_from, i * self.ps)
            m.feed_from = min(m.feed_from, m.write_from)
            break
        return (m.feed_from, m.write_from)

    # ------------------------------- writes ------------------------------

    def prepare_write(self, b: int, start: int, end: int
                      ) -> list[tuple[int, int]]:
        """Make positions [start, end) of slot b writable: grow the page
        table to cover `end`, and copy-on-write any shared page in the
        write range. Returns (src, dst) device page copies the engine
        must apply BEFORE the write (non-empty only when the write
        starts mid-page inside a shared page, so older content in that
        page must survive; shared pages fully covered by the write are
        simply replaced). Raises PoolExhausted under true pressure.

        ATOMIC: every allocation this call needs (growth + COW
        replacements) is counted against `available()` up front, and the
        failure path acquires nothing. A mid-call failure used to leave
        the grown head of a multi-page feed referenced in the table and
        its completed COW swaps stripped of their pending device copies
        — harmless for a caller that immediately finishes the request
        (release() returns the pages), but a page-refcount leak plus a
        garbage-head page for any caller that keeps the slot alive
        after catching PoolExhausted."""
        t = self.tables[b]
        need = self._pages_for(end)
        if need > self.max_pages:
            raise ValueError(
                f"slot {b} needs {need} pages > max {self.max_pages}")
        ps = self.ps
        # clamp: a write range ending inside an already-longer table has
        # negative headroom, which must not offset the COW count below
        grow = max(0, need - len(t))
        cow = sum(1 for i in range(start // ps, min(len(t), need))
                  if self.refcount[t[i]] > 1 and self.writer.get(t[i]) != b)
        if grow + cow > self.available():
            raise PoolExhausted(
                f"KV page pool exhausted ({self.P} pages of {self.ps}; "
                f"feed needs {grow} new + {cow} COW, "
                f"{self.available()} allocatable)")
        while len(t) < need:
            t.append(self._alloc())
        copies = []
        for i in range(start // ps, need):
            p = t[i]
            if self.refcount[p] > 1 and self.writer.get(p) != b:
                new = self._alloc()
                if i * ps < start:           # partial overlap: keep head
                    copies.append((p, new))
                    self.cow_copies += 1
                self._decref(p)
                t[i] = new
        return copies

    def note_fill(self, b: int, frontier: int) -> None:
        """Slot b has written every position < frontier. Pages it is the
        designated writer of become full (and shareable) once the
        frontier crosses their end."""
        ps = self.ps
        for i, p in enumerate(self.tables[b]):
            if (i + 1) * ps > frontier:
                break
            if self.writer.get(p) == b:
                self.full[p] = True
                del self.writer[p]

    # ------------------------------ fork / free --------------------------

    def fork(self, src: int, dst: int) -> None:
        """Clone slot src's table into empty slot dst by reference:
        zero device copies now; later writes COW via prepare_write.
        The fork carries NO wait/claim semantics (n_attached = 0):
        ready(dst) must never claim writer rights over src's pages,
        or prepare_write would skip the COW and let dst clobber them."""
        assert not self.tables[dst], f"slot {dst} already in use"
        for p in self.tables[src]:
            self._attach(dst, p)
        m = self.meta.get(src)
        if m is not None:
            self.meta[dst] = _SlotMeta(
                plen=m.plen, n_attached=0,
                feed_from=m.feed_from, write_from=m.write_from)

    def release(self, b: int) -> None:
        for p in self.tables[b]:
            if self.writer.get(p) == b and not self.full[p]:
                del self.writer[p]          # orphan: waiters may claim
            self._decref(p)
        self.tables[b] = []
        self.meta.pop(b, None)

    # ------------------------------ views --------------------------------

    def table_rows(self, np_mod):
        """[slots, max_pages] int32 page-table matrix (-1 = unmapped),
        ready to ship to device next to the span call."""
        out = np_mod.full((self.slots, self.max_pages), -1, np_mod.int32)
        for b, t in enumerate(self.tables):
            if t:
                out[b, :len(t)] = t
        return out

    def check_invariants(self) -> None:
        """Debug/fuzz hook: refcounts must equal observed references,
        free pages must be unreferenced, cold pages cache-only."""
        refs = [0] * self.P
        for t in self.tables:
            for p in t:
                refs[p] += 1
        for p in self._rev:
            refs[p] += 1
        assert refs == self.refcount, (refs, self.refcount)
        free_set = set(self.free)
        assert len(free_set) == len(self.free), "free list duplicates"
        for p in free_set:
            assert self.refcount[p] == 0
        for p in self._cold:
            assert self.refcount[p] == 1 and p in self._rev and self.full[p]
