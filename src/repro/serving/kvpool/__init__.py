"""Paged KV-cache subsystem: host-side page-table/refcount/prefix-cache
bookkeeping for the global device page pools (docs/kv_paging.md)."""
from .allocator import AdmitPlan, PagedAllocator, PoolExhausted

__all__ = ["AdmitPlan", "PagedAllocator", "PoolExhausted"]
