"""One persistent step-loop core for every serving-engine mode.

Before this module, the engine carried three nearly-identical ~300-line
slot loops (`generate`, `_generate_paged`, `generate_speculative`), each
re-implementing admission, slot bookkeeping, finish handling and stats.
`StepLoop` owns all of that exactly once; the mode objects below plug in
the per-step body (plan → device step → select → commit):

  * `DenseMode`  — one [B, V] decode + fused mask/sample per step, with
    optional host/device OVERLAP: after the fused mask+sample of step k
    is dispatched, step k+1's unmasked forward is dispatched immediately
    with the on-device sampled ids (the token never leaves the device);
    the host then validates step k against the exact oracle and builds
    step k+1's mask rows while the device is already busy. When the host
    changes the outcome (oracle demotion, exact fallback, a finished
    slot, an admission), the speculative forward is discarded and the
    corrected step re-dispatched — position-addressed KV caches make the
    rewrite idempotent (`kv_pos <= q_pos` masking hides the stale
    write), so the result is token-for-token identical to the
    non-overlapped engine.
  * `PagedMode`  — the paged feed loop (chunked prefill through bucketed
    [B, S] spans, prefix-share waking, COW prepare) feeding the same
    selection machinery.
  * `SpecMode`   — grammar-aware speculation (jump-forward + draft
    spans), dense or paged.

The loop is also where every request-lifecycle feature lives once for
all modes: per-token emit callbacks (streaming), per-request
cancellation (frees the slot and its KV pages immediately), deadlines
(a distinct `deadline` finish reason) and graceful drain. `AsyncEngine`
(serving/async_engine.py) runs one persistent StepLoop on a background
thread against a live `QueueSource`; the synchronous `Engine.generate*`
entry points run the same loop to completion over a `ListSource`, which
is what keeps the two token-for-token identical by construction.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.constrain import MAX_ACCEPT
from repro.core.decoding import DecodeConfig
from repro.obs import Telemetry
from repro.serving.devbridge import attach as _attach_devbridge
from repro.serving.kvpool import PoolExhausted
from repro.spec.scheduler import SlotPhase, SpecConfig, SpecScheduler


# --------------------------- request sources ---------------------------

class ListSource:
    """Fixed batch of requests (the synchronous generate() path)."""

    def __init__(self, requests):
        self._q = deque(requests)

    def __len__(self):
        return len(self._q)

    def try_pop(self):
        return self._q.popleft() if self._q else None

    def push_front(self, req) -> None:
        self._q.appendleft(req)

    @property
    def closed(self) -> bool:
        return True                     # nothing more is ever coming

    def wait_for_work(self, timeout: float) -> bool:
        return False


class QueueSource:
    """Thread-safe live admission queue for the persistent async loop.

    submit() may be called from any thread; the step-loop thread pops.
    close() stops admission (drain): the loop exits once the queue and
    the slot pool empty out.
    """

    def __init__(self):
        self._q: deque = deque()
        self._cv = threading.Condition()
        self._closed = False

    def __len__(self):
        with self._cv:
            return len(self._q)

    def submit(self, req) -> None:
        with self._cv:
            if self._closed:
                raise RuntimeError("source closed (engine draining)")
            self._q.append(req)
            self._cv.notify_all()

    def try_pop(self):
        """Pop the head or None — the loop thread's only read primitive.
        (A compound len()/peek()/pop() would race with `remove()` from
        the asyncio thread: cancel-withdraw can empty the queue between
        the check and the pop.)"""
        with self._cv:
            return self._q.popleft() if self._q else None

    def push_front(self, req) -> None:
        """Return a popped-but-not-admitted request to the head (the
        paged admission gate rejected it; it stays next in line)."""
        with self._cv:
            self._q.appendleft(req)

    def remove(self, req) -> bool:
        """Withdraw a queued request (cancel before admission)."""
        with self._cv:
            try:
                self._q.remove(req)
                return True
            except ValueError:
                return False

    def close(self):
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    def wait_for_work(self, timeout: float) -> bool:
        """Block until work arrives or the source closes. True = work."""
        with self._cv:
            if self._q:
                return True
            if self._closed:
                return False
            self._cv.wait(timeout)
            return bool(self._q)


# ------------------------------ the loop -------------------------------

class StepLoop:
    """Shared slot-pool loop: admission, cancellation/deadline sweep,
    per-mode step body, finish bookkeeping, stats. One instance per
    synchronous generate() call; ONE persistent instance per AsyncEngine.
    """

    def __init__(self, engine, mode, source, verbose: bool = False,
                 on_token: Optional[Callable] = None,
                 on_admit: Optional[Callable] = None,
                 on_finish: Optional[Callable] = None,
                 keep_states: bool = True,
                 telemetry: Optional[Telemetry] = None):
        self.eng = engine
        self.mode = mode
        self.source = source
        self.verbose = verbose
        self.on_token = on_token
        self.on_admit = on_admit
        self.on_finish = on_finish
        self.keep_states = keep_states
        # one Telemetry per loop: the sync generate() paths get a fresh
        # per-run instance (EngineStats derives from it); AsyncEngine
        # passes its persistent one so /metrics is cumulative
        self.tele = telemetry if telemetry is not None else \
            Telemetry(enabled=getattr(engine, "telemetry_enabled", True))
        # bind the jax sync/profiler capabilities (devbridge is the one
        # sanctioned jax touchpoint for obs); device timing itself stays
        # OFF unless the engine was built for bench/profile mode
        _attach_devbridge(self.tele)
        if getattr(engine, "devtime_enabled", False):
            self.tele.devtime.enabled = True

        B = engine.slots
        self.B = B
        self.slot_state = [None] * B
        self.feed_pos = np.zeros(B, np.int32)
        self.waiting = np.zeros(B, bool)
        self.seeds = np.zeros(B, np.uint32)
        self.greedy = np.ones(B, bool)
        self.temp = np.ones(B, np.float32)
        self.top_k = np.zeros(B, np.int32)
        self.top_p = np.ones(B, np.float32)
        self.ids_cache: dict[int, list] = {}
        self.stall = 0

        # control queue: closures posted from other threads, executed on
        # the loop thread between steps (hot grammar registration — the
        # engine's concatenated device store must never change while a
        # step that read it is in flight)
        self._controls: deque = deque()
        self._ctl_lock = threading.Lock()

        # cumulative counters now live in the telemetry registry —
        # stats() derives EngineStats from them (one accounting, two
        # views). Count-style instruments are live even with telemetry
        # disabled (plain float adds; the exact token/count invariants
        # must hold either way); only spans/histograms/lifecycle/trace
        # ride the disabled no-op path.
        self.t0 = time.perf_counter()
        self.all_states: list = []
        reg = self.tele.registry
        self.c_requests = reg.counter(
            "repro_requests_total", "requests admitted (incl. failed)")
        self.c_tokens = reg.counter(
            "repro_tokens_total", "tokens committed")
        self.c_steps = reg.counter(
            "repro_slot_steps_total",
            "per-slot step increments (sum of st.steps)")
        self.c_decode_steps = reg.counter(
            "repro_decode_steps_total", "device decode/span calls")
        self.c_mask_comp = reg.counter(
            "repro_mask_computations_total", "grammar mask rows computed")
        self.c_opp_hits = reg.counter(
            "repro_opportunistic_hits_total",
            "unconstrained proposals accepted by the oracle")
        self.c_jump = reg.counter(
            "repro_jump_tokens_total",
            "grammar-forced tokens committed with no model call")
        self.c_draft_prop = reg.counter(
            "repro_draft_tokens_total", "speculative draft tokens",
            {"kind": "proposed"})
        self.c_draft_acc = reg.counter(
            "repro_draft_tokens_total", "speculative draft tokens",
            {"kind": "accepted"})
        # overlap gate outcomes: dispatched = speculative forwards
        # issued, hit = consumed next step (miss = dispatched - hit),
        # probe = dispatches issued only to re-measure a gated-off
        # regime. Registered eagerly so the series exist at zero.
        self.c_overlap_disp = reg.counter(
            "repro_overlap_forwards_total", "overlap gate outcomes",
            {"outcome": "dispatched"})
        self.c_overlap_hit = reg.counter(
            "repro_overlap_forwards_total", "overlap gate outcomes",
            {"outcome": "hit"})
        self.c_overlap_probe = reg.counter(
            "repro_overlap_forwards_total", "overlap gate outcomes",
            {"outcome": "probe"})
        if self.tele.enabled:
            reg.gauge("repro_queue_depth", "requests waiting for a slot",
                      fn=lambda: float(len(self.source)))
            reg.gauge("repro_slots_active", "slots currently serving",
                      fn=lambda: float(len(self.active())))
            reg.gauge("repro_slots_total", "decode pool width",
                      fn=lambda: float(self.B))

        mode.setup(self)

    # ------------------------- slot lifecycle -------------------------

    def active(self) -> list[int]:
        return [b for b in range(self.B) if self.slot_state[b] is not None]

    def admit(self, b: int, req) -> None:
        with self.tele.span("admit") as sp:
            st = self.mode.admit(self, b, req)
            self.slot_state[b] = st
            self.seeds[b] = np.uint32(req.seed & 0xFFFFFFFF)
            g, t, k, p = DecodeConfig.batch_arrays([req.decode])
            self.greedy[b], self.temp[b] = g[0], t[0]
            self.top_k[b], self.top_p[b] = k[0], p[0]
            if req.deadline is not None:
                st.deadline_at = time.perf_counter() + req.deadline
        self.c_requests.inc()
        st.admit_t = sp.t0 if self.tele.enabled else time.perf_counter()
        self.tele.lifecycle.on_admit(req.rid)
        if self.keep_states:
            self.all_states.append(st)
        if self.on_admit:
            self.on_admit(st)

    def finish(self, b: int) -> None:
        st = self.slot_state[b]
        self.mode.release(self, b, st)
        self.slot_state[b] = None
        self.waiting[b] = False
        self.feed_pos[b] = 0
        if self.verbose:
            print(f"[req {st.req.rid}] {st.finish_reason}: "
                  f"{st.generated[:70]!r}")
        self.tele.lifecycle.on_finish(st.req.rid, st.finish_reason)
        tr = self.tele.tracer
        if tr.active:
            now = time.perf_counter()
            t0 = getattr(st, "admit_t", None) or now
            tr.add(f"slot {b}", f"req {st.req.rid}", t0, now - t0,
                   {"reason": st.finish_reason, "tokens": st.steps})
        if self.on_finish:
            self.on_finish(st)

    def commit(self, st, token: int) -> None:
        """THE commit point for every mode (incl. jump-forward commits):
        engine bookkeeping + the streaming emit callback."""
        self.eng._commit(st, token)
        self.c_tokens.inc()
        self.tele.lifecycle.on_token(st.req.rid)
        tr = self.tele.tracer
        if tr.active:
            tr.instant(f"slot {st.slot}", "token", time.perf_counter(),
                       {"id": int(token)})
        if self.on_token:
            self.on_token(st, token)

    def note_steps(self, n: int) -> None:
        """Mirror per-slot st.steps increments into a loop-level total,
        so async stats (keep_states=False) report the same steps-based
        token count as the sync path's sum(st.steps)."""
        self.c_steps.inc(n)

    def fail_request(self, req, reason: str) -> None:
        """Finish a request that never got a slot (e.g. a prompt the KV
        pool can never fit, on the persistent path)."""
        from repro.serving.engine import RequestState
        self.ids_cache.pop(req.rid, None)
        st = RequestState(req=req)
        st.done = True
        st.finish_reason = reason
        self.c_requests.inc()
        self.tele.lifecycle.on_finish(req.rid, reason)
        if self.keep_states:
            self.all_states.append(st)
        if self.on_admit:
            self.on_admit(st)
        if self.on_finish:
            self.on_finish(st)

    # --------------------------- control queue ------------------------

    def post_control(self, fn: Callable[[], None]) -> None:
        """Run fn() on the loop thread before the next step (thread-safe,
        FIFO). fn must do its own error handling — an exception escaping
        a control kills the loop like any other step error."""
        with self._ctl_lock:
            self._controls.append(fn)

    def _drain_controls(self) -> None:
        while True:
            with self._ctl_lock:
                fn = self._controls.popleft() if self._controls else None
            if fn is None:
                return
            fn()

    # --------------------- cancellation / deadlines -------------------

    def _sweep(self) -> None:
        now = None
        for b in self.active():
            st = self.slot_state[b]
            if st.cancelled:
                st.done = True
                st.finish_reason = "cancelled"
                self.finish(b)
                continue
            if st.deadline_at is not None:
                now = time.perf_counter() if now is None else now
                if now >= st.deadline_at:
                    st.done = True
                    st.finish_reason = "deadline"
                    self.finish(b)

    # ------------------------------ run -------------------------------

    def run(self, idle_wait: float = 0.1):
        """Drive the loop until the source is closed AND drained AND the
        pool is idle. For a ListSource this is the synchronous generate
        path; for a QueueSource it is the persistent serving loop (idles
        between requests, exits on close())."""
        while True:
            self._drain_controls()
            self._sweep()
            for b in range(self.B):
                if self.slot_state[b] is not None:
                    continue
                # pop-then-gate (never len/peek-then-pop): cancel
                # withdrawal runs on another thread, so the queue can
                # empty between a check and a pop
                req = self.source.try_pop()
                if req is None:
                    break
                if not self.mode.can_admit_req(self, req):
                    self.source.push_front(req)
                    break
                self.admit(b, req)
            active = self.active()
            if not active:
                req = self.source.try_pop()
                if req is not None:
                    if self.mode.can_admit_req(self, req):
                        # admittable after all (e.g. submitted after the
                        # admission sweep): next iteration takes it
                        self.source.push_front(req)
                        continue
                    # no slot can ever take this request (paged pool too
                    # small): strict sources raise, live sources fail
                    # the request gracefully and keep serving
                    if self.source.closed:
                        raise PoolExhausted(
                            "KV pool too small for the next request's "
                            "prompt")
                    self.fail_request(req, "kv_oom")
                    continue
                if self.source.closed:
                    break
                # idle: the queue is empty, so any memoized prompt ids
                # belong to withdrawn/failed requests — drop them (rids
                # are never reused, so they could only accumulate)
                self.ids_cache.clear()
                self.mode.on_idle(self)
                self.source.wait_for_work(idle_wait)
                continue
            self.mode.step(self, active)
        return (self.all_states, self.stats()) if self.keep_states \
            else (None, self.stats())

    # ------------------------------ stats ------------------------------

    def stats(self):
        """EngineStats as a view over the telemetry registry: counts
        come from the always-live counters; mask_time/plan_time are the
        phase-span totals (the historical mask_time bracket = rows
        build + mask dispatch + ids sync — the oracle loop was never
        included and reports separately as the host_oracle phase).
        With telemetry disabled the timing fields read 0."""
        from repro.serving.engine import EngineStats
        tele = self.tele
        s = EngineStats(
            requests=int(self.c_requests.value),
            tokens=sum(st.steps for st in self.all_states)
            if self.keep_states else int(self.c_steps.value),
            wall=time.perf_counter() - self.t0,
            mask_time=(tele.phase_seconds("ci_lookup")
                       + tele.phase_seconds("cd_check")
                       + tele.phase_seconds("mask_dispatch")
                       + tele.phase_seconds("select_resolve")),
            mask_computations=int(self.c_mask_comp.value),
            opportunistic_hits=int(self.c_opp_hits.value),
            decode_steps=int(self.c_decode_steps.value),
            batch_slots=self.B,
            mesh_devices=self.eng.mesh.size if self.eng.mesh else 1,
            jump_tokens=int(self.c_jump.value),
            draft_proposed=int(self.c_draft_prop.value),
            draft_accepted=int(self.c_draft_acc.value),
            plan_time=tele.phase_seconds("plan"),
            overlap_dispatched=int(self.c_overlap_disp.value),
            overlap_hits=int(self.c_overlap_hit.value),
            device_forward_s=(tele.devtime.seconds("forward")
                              + tele.devtime.seconds("overlap_forward")),
            device_mask_sample_s=tele.devtime.seconds("mask_sample"),
            overlap_hidden_s=tele.c_overlap_hidden.value,
            attribution=tele.attribution() if tele.enabled else None,
        )
        return self.mode.stats_extra(self, s)

    def add_select_ctr(self, ctr: dict) -> None:
        self.c_mask_comp.inc(ctr["mask_computations"])
        self.c_opp_hits.inc(ctr["opportunistic_hits"])


# ------------------------------- modes ---------------------------------

class _ModeBase:
    def can_admit_req(self, loop, req) -> bool:
        return True

    def on_idle(self, loop) -> None:
        pass

    def release(self, loop, b, st) -> None:
        pass

    def stats_extra(self, loop, stats):
        return stats


class DenseMode(_ModeBase):
    """Plain continuous batching over dense per-slot decode caches, with
    optional host/device overlap (see module docstring).

    Overlap is ADAPTIVE: a speculative forward only pays off when the
    host usually validates the whole batch unchanged (greedy and
    low-temperature serving — the masked argmax almost always passes the
    exact oracle). High-temperature sampling over an over-approximate
    mask rejects some slot most steps, so every speculative forward
    would be discarded; the mode tracks a windowed hit rate and stops
    speculating below `OVERLAP_MIN_RATE`, re-probing every
    `OVERLAP_PROBE` steps in case the workload shifts. Token streams are
    identical either way — gating only decides where device time goes."""

    # Break-even: speculation pays when rate*min(host, fwd) exceeds
    # (1-rate)*fwd — at fwd <= host that is rate > 0.5, and for
    # fwd > host the threshold only rises, so 0.5 is the permissive
    # edge of profitability.
    OVERLAP_MIN_RATE = 0.5      # windowed hits/dispatches to keep going
    OVERLAP_WINDOW = 64         # halve counters at this many dispatches
    OVERLAP_PROBE = 16          # gated-off steps between re-probes
    OVERLAP_WARMUP = 8          # unconditional dispatches before gating

    def __init__(self, engine, overlap: Optional[bool] = None):
        self.eng = engine
        self.overlap = engine.overlap if overlap is None else overlap
        if not engine.model.supports_span_decode:
            # recurrent/side-input state cannot absorb a discarded
            # speculative forward (no position-addressed rewrite)
            self.overlap = False
        self.caches = None
        self.cur_tok = None
        self.pending_logits = None      # speculative forward for the
                                        # NEXT step, still on device
        self._spec_disp_t = None        # host clock when that dispatch
                                        # returned (overlap-hidden attr)
        self._disp_w = 0                # windowed dispatch count
        self._hit_w = 0                 # windowed hit count
        self._gated_steps = 0           # steps since last probe

    def setup(self, loop):
        eng = self.eng
        self.caches = eng._place_caches(
            eng.model.init_decode_caches(eng.slots, eng.max_len))
        self.cur_tok = np.zeros(eng.slots, np.int32)

    def admit(self, loop, b, req):
        st, self.caches = self.eng._admit_common(req, b, self.caches)
        st.slot = b
        self.cur_tok[b] = st.token_ids[-1]
        loop.feed_pos[b] = st.pos - 1
        # the inserted prefill caches invalidate any in-flight
        # speculative forward for this slot
        self.pending_logits = None
        self._spec_disp_t = None
        return st

    def step(self, loop, active):
        eng = self.eng
        tele = loop.tele
        if self.pending_logits is not None:
            logits = self.pending_logits       # dispatched last step
            self.pending_logits = None
            loop.c_overlap_hit.inc()
            self._hit_w += 1    # counted at CONSUMPTION, so a forward
                                # invalidated by admit() is a miss in
                                # the gate's window too
            # overlap-hidden attribution: the host-work window between
            # the speculative dispatch finishing and this consumption is
            # device time the overlap hid. Clamp to the latest measured
            # forward interval when devtime has one (bench/profile);
            # otherwise the window itself is the documented upper bound.
            # Never sync the speculative forward — that would serialize
            # the very overlap being measured.
            if self._spec_disp_t is not None:
                window = time.perf_counter() - self._spec_disp_t
                dev = tele.devtime.last_dur.get("forward", 0.0)
                tele.add_overlap_hidden(min(window, dev) if dev > 0.0
                                        else window)
                self._spec_disp_t = None
        else:
            # cur_tok/feed_pos are mutated in place after the resolve
            # sync; the sync does guarantee this dispatch completed
            # first, but copy anyway — same aliasing hazard class as
            # the paged feed (see PagedMode.step)
            with tele.device_span("forward") as dv:
                with tele.span("forward"):
                    tok_dev = jnp.asarray(self.cur_tok.copy())
                    pos_dev = jnp.asarray(loop.feed_pos.copy())
                    logits, self.caches = eng._decode(
                        eng.params, self.caches, tok_dev, pos_dev)
                dv.done(logits)     # host span stays dispatch-only; the
                # device bracket blocks here in bench/profile mode
            eng._note_jit_cost(tele, "forward", eng._decode, eng.params,
                               self.caches, tok_dev, pos_dev)
        loop.c_decode_steps.inc()
        for b in active:
            loop.slot_state[b].steps += 1
        loop.note_steps(len(active))

        ctx = eng._select_dispatch(
            logits, loop.slot_state, set(active), loop.seeds,
            loop.greedy, loop.temp, loop.top_k, loop.top_p, obs=tele)

        # ---- overlap: dispatch step k+1's forward with the on-device
        # sampled ids BEFORE syncing step k back to the host ----------
        spec_logits = None
        if self.overlap and not eng.opportunistic and \
                ctx.ids is not None and self._speculate_now(loop):
            with tele.span("overlap_forward"):
                spec_logits, self.caches = eng._decode(
                    eng.params, self.caches, ctx.ids,
                    jnp.asarray(loop.feed_pos + 1))
            self._spec_disp_t = time.perf_counter()
            loop.c_overlap_disp.inc()
            self._disp_w += 1
            if self._disp_w >= self.OVERLAP_WINDOW:
                self._disp_w //= 2      # exponential decay: old hit
                self._hit_w //= 2       # rates age out

        committed, ctr = eng._select_resolve(
            ctx, loop.slot_state, loop.seeds, loop.greedy, loop.temp,
            loop.top_k, loop.top_p, obs=tele)
        loop.add_select_ctr(ctr)

        for b, t in committed.items():
            st = loop.slot_state[b]
            loop.commit(st, t)
            self.cur_tok[b] = t
            loop.feed_pos[b] = st.pos - 1
        for b in active:
            st = loop.slot_state[b]
            if st is not None and st.done:
                loop.finish(b)

        # speculation valid iff the host changed NOTHING the device
        # didn't already know: every active slot committed its first-
        # round device id. A slot that finished (eos/length) committed
        # that same id — its speculative row is simply ignored from now
        # on, and `admit()` drops the pending forward if the freed slot
        # is refilled. Discarded forwards are harmless: position-
        # addressed caches rewrite idempotently.
        if spec_logits is not None and ctx.clean and \
                set(committed) == set(active):
            self.pending_logits = spec_logits
        else:
            self._spec_disp_t = None    # discarded forward hides nothing

    def _speculate_now(self, loop) -> bool:
        if self._disp_w < self.OVERLAP_WARMUP:      # warm-up: always try
            return True
        if self._hit_w / self._disp_w >= self.OVERLAP_MIN_RATE:
            return True
        self._gated_steps += 1          # hostile regime: probe rarely
        if self._gated_steps >= self.OVERLAP_PROBE:
            self._gated_steps = 0
            loop.c_overlap_probe.inc()  # dispatch issued only to
            return True                 # re-measure a gated-off regime
        return False


class PagedMode(_ModeBase):
    """Paged-KV continuous batching: chunked prefill drained through
    bucketed [B, S] span feeds, prefix-share waking, COW page prepare —
    then the IDENTICAL selection machinery as DenseMode."""

    def __init__(self, engine):
        self.eng = engine
        self.alloc = None
        self.caches = None

    def setup(self, loop):
        self.alloc, self.caches = self.eng._paged_setup(self.eng.slots)
        if loop.tele.enabled:
            loop.tele.register_kv(self.alloc)

    def can_admit_req(self, loop, req) -> bool:
        return self.eng._paged_can_admit(self.alloc, req, loop.ids_cache)

    def admit(self, loop, b, req):
        st, plan = self.eng._admit_paged(
            req, b, self.alloc, loop.ids_cache.pop(req.rid, None))
        st.slot = b
        loop.feed_pos[b] = plan.feed_from
        loop.waiting[b] = True      # shared pages may still be filling
        if not self.eng._paged_wake(self.alloc, b, st, loop.feed_pos,
                                    loop.waiting):
            st.phase = SlotPhase.PREFILLING.value
        return st

    def release(self, loop, b, st) -> None:
        st.kv_pages = len(self.alloc.tables[b])
        self.alloc.release(b)

    def stats_extra(self, loop, stats):
        return self.eng._kv_stats(stats, self.alloc)

    def step(self, loop, active):
        eng = self.eng
        alloc, B = self.alloc, loop.B

        # ---- wake waiters whose shared prefix finished filling ------
        live = [b for b in active
                if eng._paged_wake(alloc, b, loop.slot_state[b],
                                   loop.feed_pos, loop.waiting)]
        if not live:
            loop.stall += 1
            if loop.stall > 4 * B + 16:
                raise RuntimeError("paged scheduler stalled")
            return
        loop.stall = 0

        # ---- ONE [B, S] paged span feed for the whole pool ----------
        with loop.tele.span("feed_build"):
            pend = {b: loop.slot_state[b].pos - int(loop.feed_pos[b])
                    for b in live}
            S = eng._feed_width(list(pend.values()))
            tokens = np.zeros((B, S), np.int32)
            fmask = np.zeros((B, S), bool)
            sel = np.full(B, -1, np.int32)
            feed_n: dict[int, int] = {}
            for b in live:
                st = loop.slot_state[b]
                fs = int(loop.feed_pos[b])
                k = min(pend[b], S)
                new_caches = eng._prepare_feed(alloc, self.caches, b, st,
                                               fs, k)
                if new_caches is None:
                    continue                 # kv_oom: no feed
                self.caches = new_caches
                if pend[b] <= S:
                    sel[b] = k - 1           # selection this step
                tokens[b, :k] = st.token_ids[fs:fs + k]
                for i in range(k):
                    fmask[b, i] = (fs + i) >= st.write_from
                feed_n[b] = k
        live = [b for b in live if b in feed_n]
        if live:
            page_tab = alloc.table_rows(np)
            # feed_pos is a long-lived array mutated IN PLACE right
            # after this dispatch (prefill-drain steps never sync), and
            # jnp.asarray may zero-copy alias host memory on CPU — the
            # async computation would read the NEXT step's cursors.
            # Ship a private copy (jax keeps it alive; nobody mutates
            # it). Root-caused from a 5.47-magnitude logits drift in
            # chunked-prefill runs; see CHANGES.md PR 5 addendum.
            with loop.tele.device_span("forward") as dv:
                with loop.tele.span("forward"):
                    pos_dev = jnp.asarray(loop.feed_pos.copy())
                    logits, self.caches = eng._span_feed_paged(
                        eng.params, self.caches, jnp.asarray(tokens),
                        pos_dev, jnp.asarray(fmask),
                        jnp.asarray(page_tab), jnp.asarray(sel))
                dv.done(logits)
            eng._note_jit_cost(
                loop.tele, "forward", eng._span_feed_paged, eng.params,
                self.caches, jnp.asarray(tokens), pos_dev,
                jnp.asarray(fmask), jnp.asarray(page_tab),
                jnp.asarray(sel))
            loop.c_decode_steps.inc()
            for b in live:
                st = loop.slot_state[b]
                alloc.note_fill(b, min(int(loop.feed_pos[b]) + feed_n[b],
                                       st.prompt_len))
                if sel[b] < 0:               # chunked prefill drain
                    loop.feed_pos[b] += feed_n[b]
                    st.phase = SlotPhase.PREFILLING.value
            selecting = [b for b in live if sel[b] >= 0]
            for b in selecting:
                loop.slot_state[b].steps += 1
                loop.slot_state[b].phase = SlotPhase.DECODING.value
            loop.note_steps(len(selecting))
            if selecting:
                committed, ctr = eng._select_tokens(
                    logits, loop.slot_state, set(selecting), loop.seeds,
                    loop.greedy, loop.temp, loop.top_k, loop.top_p,
                    obs=loop.tele)
                loop.add_select_ctr(ctr)
                for b, t in committed.items():
                    st = loop.slot_state[b]
                    loop.commit(st, t)
                    loop.feed_pos[b] = st.pos - 1
        for b in active:
            st = loop.slot_state[b]
            if st is not None and st.done:
                loop.finish(b)


class SpecMode(_ModeBase):
    """Grammar-aware speculation (jump-forward + draft-verify spans)
    over dense or paged caches — generate_speculative's step body on the
    shared loop."""

    def __init__(self, engine, spec: Optional[SpecConfig] = None):
        self.eng = engine
        self.spec = spec or SpecConfig()
        self.paged = engine.paged
        self.sched = None
        self.alloc = None
        self.caches = None

    def setup(self, loop):
        eng = self.eng
        if not eng.model.supports_span_decode:
            raise ValueError(
                "speculative decoding needs position-addressed decode "
                "caches (attn/moe layer kinds); this arch has recurrent "
                "or side-input state")
        self.sched = SpecScheduler(
            self.spec, eng.tok,
            telemetry=loop.tele if loop.tele.enabled else None)
        if self.paged:
            self.alloc, self.caches = eng._paged_setup(eng.slots)
            if loop.tele.enabled:
                loop.tele.register_kv(self.alloc)
        else:
            self.caches = eng._place_caches(
                eng.model.init_decode_caches(eng.slots, eng.max_len))

    def can_admit_req(self, loop, req) -> bool:
        if not self.paged:
            return True
        return self.eng._paged_can_admit(self.alloc, req, loop.ids_cache)

    def admit(self, loop, b, req):
        eng = self.eng
        if self.paged:
            st, plan = eng._admit_paged(
                req, b, self.alloc, loop.ids_cache.pop(req.rid, None))
            st.slot = b
            loop.feed_pos[b] = plan.feed_from
            loop.waiting[b] = True
            if not eng._paged_wake(self.alloc, b, st, loop.feed_pos,
                                   loop.waiting):
                st.phase = SlotPhase.PREFILLING.value
        else:
            st, self.caches = eng._admit_common(req, b, self.caches)
            st.slot = b
            loop.feed_pos[b] = st.pos - 1
        self.sched.on_admit(st)
        return st

    def release(self, loop, b, st) -> None:
        if self.paged:
            st.kv_pages = len(self.alloc.tables[b])
            self.alloc.release(b)
        self.sched.on_finish(st)

    def stats_extra(self, loop, stats):
        if self.paged:
            return self.eng._kv_stats(stats, self.alloc)
        return stats

    def step(self, loop, active):
        eng = self.eng
        B = loop.B
        slot_state = loop.slot_state
        feed_pos = loop.feed_pos
        # reprolint: mutated-inflight=loop.greedy,loop.temp,loop.top_k,loop.top_p admit() rewrites the decode configs while the span dispatch is in flight

        def commit_one(st, token):
            st.steps += 1
            loop.note_steps(1)
            loop.commit(st, token)

        # ---- wake waiters whose shared prefix finished filling ------
        if self.paged:
            for b in active:
                eng._paged_wake(self.alloc, b, slot_state[b], feed_pos,
                                loop.waiting)

        # ---- host planning: jump-forward commits + drafting ---------
        plans = {}
        with loop.tele.span("plan"):
            for b in active:
                st = slot_state[b]
                if loop.waiting[b]:
                    from repro.spec.scheduler import SlotPlan
                    plans[b] = SlotPlan()
                    continue
                backlog = (st.pos - 1) - int(feed_pos[b])
                pre = st.jump_tokens
                plans[b] = self.sched.plan_slot(st, commit_one,
                                                eng.max_len,
                                                backlog=backlog)
                loop.c_jump.inc(st.jump_tokens - pre)
                st.phase = plans[b].phase.value
        for b in active:
            st = slot_state[b]
            if st.done:      # finished mid-jump: nothing left to feed
                self.sched.on_commit(st, plans[b].jumped)
                loop.finish(b)
        live = [b for b in active
                if slot_state[b] is not None and not loop.waiting[b]]
        if not live:
            loop.stall += 1
            if loop.stall > 4 * B + 16:
                raise RuntimeError("paged scheduler stalled")
            return
        loop.stall = 0

        # ---- span width: maximize commits per unit of compute -------
        pend_n = {b: slot_state[b].pos - int(feed_pos[b]) for b in live}
        S = eng._choose_span(
            [pend_n[b] + len(plans[b].drafts) for b in live])
        tokens = np.zeros((B, S), np.int32)
        fmask = np.zeros((B, S), bool)
        sel0 = {}        # b -> span index of first selection (-1 none)
        fed = {}         # b -> tokens fed this span
        for b in list(live):
            st = slot_state[b]
            fs = int(feed_pos[b])
            pend = st.token_ids[fs: st.pos]
            if len(pend) > S:          # backlog drain: feed only
                feed = pend[:S]
                sel0[b] = -1
                plans[b].drafts = []
            else:
                plans[b].drafts = plans[b].drafts[: S - len(pend)]
                feed = pend + plans[b].drafts
                sel0[b] = len(pend) - 1
            if self.paged:
                new_caches = eng._prepare_feed(self.alloc, self.caches,
                                               b, st, fs, len(feed))
                if new_caches is None:
                    loop.finish(b)     # kv_oom under true pressure
                    live.remove(b)
                    continue
                self.caches = new_caches
                for i in range(len(feed)):
                    fmask[b, i] = (fs + i) >= st.write_from
            else:
                fmask[b, : len(feed)] = True
            tokens[b, : len(feed)] = feed
            fed[b] = len(feed)
            if plans[b].drafts:
                st.phase = SlotPhase.VERIFYING.value
        if not live:
            return
        # feed_pos is mutated in place after dispatch — ship a private
        # copy (zero-copy aliasing hazard; see PagedMode.step)
        with loop.tele.device_span("forward") as dv:
            with loop.tele.span("forward"):
                if self.paged:
                    page_tab = self.alloc.table_rows(np)
                    logits, self.caches = eng._span_decode_paged(
                        eng.params, self.caches, jnp.asarray(tokens),
                        jnp.asarray(feed_pos.copy()), jnp.asarray(fmask),
                        jnp.asarray(page_tab))
                else:
                    logits, self.caches = eng._span_decode(
                        eng.params, self.caches, jnp.asarray(tokens),
                        jnp.asarray(feed_pos.copy()), jnp.asarray(fmask))
            dv.done(logits)
        loop.c_decode_steps.inc()
        if self.paged:
            for b in live:
                st = slot_state[b]
                self.alloc.note_fill(b, min(int(feed_pos[b]) + fed[b],
                                            st.prompt_len))

        # ---- mask rows for every selection position -----------------
        # four spans partitioning the historical mask_time bracket:
        # host row building (ci_lookup), residue overlay (cd_check),
        # fused mask+select dispatch, ids sync
        with loop.tele.span("ci_lookup"):
            span_sms: dict[tuple, tuple] = {}  # (b, f) -> (StepMask, off)
            eosm = np.zeros((B, S), bool)
            consm = np.zeros((B, S), bool)
            for b in live:
                st = slot_state[b]
                pl = plans[b]
                if st.constraint is None or sel0[b] < 0:
                    continue
                off = eng._row_offset[st.req.grammar]
                text = st.generated
                for i in range(len(pl.drafts) + 1):
                    if i > 0:
                        text = text + eng.tok.id_to_bytes[pl.drafts[i - 1]]
                    if i == 0 and pl.stop_mask is not None:
                        sm = pl.stop_mask  # reuse jump analyzer's mask
                    else:
                        sm = st.constraint.step_rows(text)
                    f = sel0[b] + i
                    span_sms[(b, f)] = (sm, off)
                    eosm[b, f] = sm.eos_allowed
                    consm[b, f] = True
                    st.mask_computations += 1
                    loop.c_mask_comp.inc()
            # row width grows in accept_width buckets on overflow
            # (soundness)
            A = max([MAX_ACCEPT] + [sm.rows.shape[0]
                                    for sm, _ in span_sms.values()])
            rows = np.full((B, S, A), -1, np.int32)
            for (b, f), (sm, off) in span_sms.items():
                r = np.where(sm.rows >= 0, sm.rows + off, sm.rows)
                rows[b, f, :r.shape[0]] = r
        with loop.tele.span("cd_check"):
            W = int(eng._store_cat.shape[1])
            cdm = np.zeros((B, S, W), np.uint32)
            for (b, f), (sm, _) in span_sms.items():
                if sm.cd_words is not None:
                    cdm[b, f] = sm.cd_words
        with loop.tele.device_span("mask_sample") as dv:
            with loop.tele.span("mask_dispatch"):
                salts = np.array([slot_state[b].steps if slot_state[b]
                                  else 0 for b in range(B)], np.uint32)
                keys = eng._span_keys(loop.seeds, salts, S)
                # per-step arrays go in as numpy (fresh allocations);
                # the admit()-mutated decode configs ship copies
                masked, ids, ok = eng._span_mask_select(  # reprolint: dispatch
                    logits, eng._store_cat, rows, cdm, eosm, consm,
                    loop.greedy.copy(), loop.temp.copy(),
                    loop.top_k.copy(), loop.top_p.copy(), keys)
            dv.done((ids, ok))
        with loop.tele.span("select_resolve"):
            ids_h, ok_h = np.asarray(ids), np.asarray(ok)

        # ---- accept: longest valid draft prefix + bonus token -------
        with loop.tele.span("host_oracle"):
            for b in live:
                st = slot_state[b]
                pl = plans[b]
                if sel0[b] < 0:
                    # pure backlog drain (jump replay or chunked
                    # prefill): advance the feed cursor; the step's jump
                    # commits must still reach the proposer history
                    self.sched.on_commit(st, pl.jumped)
                    feed_pos[b] += fed[b]
                    if self.paged and feed_pos[b] < st.prompt_len:
                        st.phase = SlotPhase.PREFILLING.value
                    continue
                idx = sel0[b]
                committed = []
                for d in pl.drafts:
                    if st.done or int(ids_h[b, idx]) != d:
                        break
                    commit_one(st, d)
                    committed.append(d)
                    idx += 1
                st.draft_proposed += len(pl.drafts)
                st.draft_accepted += len(committed)
                loop.c_draft_prop.inc(len(pl.drafts))
                loop.c_draft_acc.inc(len(committed))
                self.sched.on_verify(st, len(pl.drafts), len(committed))
                if not st.done:
                    nxt = eng._resolve_span_selection(
                        st, masked, b, idx, int(ids_h[b, idx]),
                        bool(ok_h[b, idx]), st.steps)
                    if nxt is None:
                        st.done = True
                        st.finish_reason = "mask_exhausted"
                    else:
                        commit_one(st, nxt)
                        committed.append(nxt)
                self.sched.on_commit(st, pl.jumped + committed)
                if st.done:
                    loop.finish(b)
                else:
                    feed_pos[b] = st.pos - 1
                    st.phase = SlotPhase.DECODING.value


def make_mode(engine, spec: Optional[SpecConfig] = None,
              speculative: bool = False, overlap: Optional[bool] = None):
    """Mode factory mirroring the Engine entry points."""
    if speculative or spec is not None:
        return SpecMode(engine, spec)
    if engine.paged:
        return PagedMode(engine)
    return DenseMode(engine, overlap=overlap)
