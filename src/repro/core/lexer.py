"""Maximal-munch lexer over the grammar's combined lexer DFA (paper §4.2).

`lex_partial` implements the paper's partial-output lexing with the two
remainder cases:

* Case 1 — the input ends exactly at a complete lexical token: the token
  list includes the final token; the caller treats the final token as the
  remainder `r` (its type may still change as the LLM extends the text).
* Case 2 — the input ends with a suffix that is not (yet) a complete
  token but is a live prefix of some terminal: that suffix is returned as
  the unlexed remainder `u`.

A dead suffix (no terminal can ever match) raises LexError — such a
string is not in L_p(G) for any grammar over these terminals.

For layout-sensitive grammars (`%indent NEWLINE INDENT DEDENT`),
`postlex_indent` runs after `lex_partial` and synthesizes INDENT/DEDENT
tokens around committed NEWLINE tokens, Python-tokenizer style:

* the NEWLINE terminal's lexeme carries the following line's leading
  spaces (and any comments / blank lines it absorbed); its indentation
  column is compared against an indent stack;
* a trailing NEWLINE whose lexeme may still grow (mid-generation the
  text often ends inside `"\n    "`) is returned as *pending* — its
  indent effect is deliberately uncommitted so partial inputs never
  commit to an indent level the next token could still change;
* NEWLINE tokens inside unclosed brackets are dropped (implicit line
  joining);
* leading blank/comment lines emit no NEWLINE.

The indent stack counts leading spaces only; the column of a committed
line that matches no enclosing level raises IndentationError (a
LexError — such text is not in L_p(G)).
"""
from __future__ import annotations

from dataclasses import dataclass

from .grammar import Grammar


class LexError(ValueError):
    def __init__(self, msg, pos=None):
        super().__init__(msg)
        self.pos = pos


class IndentationError_(LexError):
    """Committed line indentation matches no enclosing level."""


@dataclass
class LexToken:
    type: str
    value: bytes
    pos: int


def lex_partial_state(grammar: Grammar, data: bytes, start: int = 0,
                      state: "tuple | None" = None):
    """Stateful maximal-munch lex. Returns (tokens, unlexed_suffix,
    walk_state). unlexed_suffix == b'' means Case 1 (or empty input);
    non-empty means Case 2.

    `walk_state` is (pos, q, j, last_acc, last_tag) — the DFA walk of
    the final, still-extendable unit at the end of `data` (None when the
    input ends exactly at a dead-stopped token boundary). Passing it
    back as `state` on a later call whose data extends the original
    continues that walk over only the appended bytes, reproducing the
    fresh walk's outcome exactly; the caller must drop its previously
    returned final token when one was emitted at `state[0]` (that token
    is re-emitted, possibly extended). See
    IncrementalParser._lex_partial_cached.

    `start` resumes lexing at a byte offset (token positions stay
    absolute): every committed token except the final one is decided by
    bytes the DFA already consumed, so an incremental caller without a
    walk state may keep `tokens[:-1]` and relex from `tokens[-1].pos`."""
    dfa = grammar.lexer_dfa
    tags = grammar.lexer_tags
    trans = dfa.trans
    live = dfa.live
    finals = dfa.finals
    tokens: list[LexToken] = []
    pos = start
    n = len(data)
    resume = state
    while pos < n or resume is not None:
        if resume is not None:
            pos, q, j, last_acc, last_tag = resume
            resume = None
        else:
            q = dfa.start
            j = pos
            last_acc = -1
            last_tag = None
        while j < n:
            nq = trans[q, data[j]]
            if not live[nq]:
                break
            q = nq
            j += 1
            if finals[q]:
                last_acc = j
                last_tag = tags[q]
        if j == n and live[q] and q != dfa.start:
            # reached end of input while a token is still in progress
            st = (pos, q, j, last_acc, last_tag)
            if finals[q]:
                tokens.append(LexToken(last_tag, data[pos:j], pos))
                return tokens, b"", st
            return tokens, data[pos:], st
        if last_acc < 0:
            raise LexError(
                f"no terminal matches at byte {pos} ({data[pos:pos+12]!r})",
                pos=pos)
        tokens.append(LexToken(last_tag, data[pos:last_acc], pos))
        pos = last_acc
    return tokens, b"", None


def lex_partial(grammar: Grammar, data: bytes, start: int = 0):
    """Returns (tokens, unlexed_suffix) — `lex_partial_state` without the
    resumable walk state."""
    tokens, unlexed, _st = lex_partial_state(grammar, data, start)
    return tokens, unlexed


# --------------------------------------------------------------------------
# Indentation post-lex pass (%indent grammars)
# --------------------------------------------------------------------------

_OPENERS = (b"(", b"[", b"{")
_CLOSERS = (b")", b"]", b"}")


@dataclass
class IndentResult:
    """Output of `postlex_indent`.

    tokens:  committed token stream with INDENT/DEDENT synthesized and
             bracket-joined NEWLINEs dropped — safe to feed the parser.
    pending: the trailing NEWLINE token whose lexeme may still grow
             (partial input, bracket depth 0), indent effect NOT yet
             applied; None when the tail is committed or at_eof.
    levels:  the committed indent stack (always starts with 0).
    paren:   unclosed-bracket depth over the committed tokens.
    has_content: a committed non-ignored, non-synthetic token exists
             (controls leading-NEWLINE suppression and the EOF closure).
    """
    tokens: list
    pending: "LexToken | None"
    levels: tuple
    paren: int
    has_content: bool
    # fold state immediately before the final token was processed:
    # (k, tokens-out tuple, levels tuple, paren, has_content). Passing it
    # back as `resume` (with toks[:k] unchanged) re-folds only the tail.
    prefix_state: "tuple | None" = None


def _indent_col(value: bytes) -> "int | None":
    """Column opened by a committed NEWLINE lexeme: spaces after its last
    newline byte. None when the lexeme holds no newline (a pure trailing
    comment — only possible at the very end of the input)."""
    i = value.rfind(b"\n")
    if i < 0:
        return None
    col = 0
    j = i + 1
    while j < len(value) and value[j] == 0x20:
        col += 1
        j += 1
    return col


def postlex_indent(grammar: Grammar, toks: list, unlexed: bytes = b"",
                   at_eof: bool = False,
                   resume: "tuple | None" = None) -> IndentResult:
    """Synthesize INDENT/DEDENT for an `%indent` grammar.

    Partial-input safety: a trailing NEWLINE token that could still be
    extended by future bytes (more spaces deepen the line, a fresh
    newline resets it entirely) is returned as `pending` instead of
    committing an indent decision. Every non-trailing NEWLINE is
    committed — its lexeme was terminated by a real token, so its column
    can never change again.

    With `at_eof=True` (whole-input recognition) the Python-tokenizer EOF
    closure is applied instead: a final NEWLINE (the last logical line
    needs no trailing newline byte) followed by one DEDENT per open
    level.

    `resume` is a `prefix_state` from a previous call whose first k
    tokens are unchanged (the caller must verify this — object identity
    over `toks[:k]` suffices, see IncrementalParser): the fold restarts
    after token k-1 instead of from the top, so a decode step that only
    appends bytes re-folds O(1) tokens. Only the final token's handling
    differs between calls (pending vs committed), and `prefix_state` is
    snapshotted strictly before it, so resumed and from-scratch folds
    agree exactly.
    """
    nl_t, ind_t, ded_t = grammar.indent_spec
    ignores = set(grammar.ignores)
    out: list[LexToken] = []
    levels = [0]
    paren = 0
    has_content = False
    pending = None
    n = len(toks)
    start = 0
    if resume is not None and resume[0] < n:
        start, r_out, r_levels, paren, has_content = resume
        out = list(r_out)
        levels = list(r_levels)
    snapshot = None
    for i in range(start, n):
        t = toks[i]
        if i == n - 1 and not at_eof:
            snapshot = (i, tuple(out), tuple(levels), paren, has_content)
        if t.type == nl_t:
            if paren > 0:
                continue                    # implicit line joining
            if i == n - 1 and not unlexed:
                pending = t                 # open tail: defer the decision
                break
            end = t.pos + len(t.value)
            if has_content:
                out.append(t)
            col = _indent_col(t.value)
            if col is None:
                continue
            if col > levels[-1]:
                levels.append(col)
                out.append(LexToken(ind_t, b"", end))
            else:
                while col < levels[-1]:
                    levels.pop()
                    out.append(LexToken(ded_t, b"", end))
                if col != levels[-1]:
                    raise IndentationError_(
                        f"unindent to column {col} at byte {t.pos} matches "
                        f"no enclosing indentation level", pos=t.pos)
            continue
        out.append(t)
        if t.type not in ignores:
            has_content = True
            if len(t.value) == 1:
                if t.value in _OPENERS:
                    paren += 1
                elif t.value in _CLOSERS and paren > 0:
                    paren -= 1
    if at_eof:
        end = (toks[-1].pos + len(toks[-1].value)) if toks else 0
        if has_content and paren == 0:
            out.append(LexToken(nl_t, b"", end))
            while len(levels) > 1:
                levels.pop()
                out.append(LexToken(ded_t, b"", end))
        pending = None
        snapshot = None
    return IndentResult(out, pending, tuple(levels), paren, has_content,
                        prefix_state=snapshot)
