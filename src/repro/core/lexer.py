"""Maximal-munch lexer over the grammar's combined lexer DFA (paper §4.2).

`lex_partial` implements the paper's partial-output lexing with the two
remainder cases:

* Case 1 — the input ends exactly at a complete lexical token: the token
  list includes the final token; the caller treats the final token as the
  remainder `r` (its type may still change as the LLM extends the text).
* Case 2 — the input ends with a suffix that is not (yet) a complete
  token but is a live prefix of some terminal: that suffix is returned as
  the unlexed remainder `u`.

A dead suffix (no terminal can ever match) raises LexError — such a
string is not in L_p(G) for any grammar over these terminals.
"""
from __future__ import annotations

from dataclasses import dataclass

from .grammar import Grammar


class LexError(ValueError):
    def __init__(self, msg, pos=None):
        super().__init__(msg)
        self.pos = pos


@dataclass
class LexToken:
    type: str
    value: bytes
    pos: int


def lex_partial(grammar: Grammar, data: bytes):
    """Returns (tokens, unlexed_suffix). unlexed_suffix == b'' means Case 1
    (or empty input); non-empty means Case 2."""
    dfa = grammar.lexer_dfa
    tags = grammar.lexer_tags
    trans = dfa.trans
    live = dfa.live
    finals = dfa.finals
    tokens: list[LexToken] = []
    pos = 0
    n = len(data)
    while pos < n:
        q = dfa.start
        j = pos
        last_acc = -1
        last_tag = None
        while j < n:
            nq = trans[q, data[j]]
            if not live[nq]:
                break
            q = nq
            j += 1
            if finals[q]:
                last_acc = j
                last_tag = tags[q]
        if j == n and live[q] and q != dfa.start:
            # reached end of input while a token is still in progress
            if finals[q]:
                tokens.append(LexToken(last_tag, data[pos:j], pos))
                pos = j
                continue
            return tokens, data[pos:]
        if last_acc < 0:
            raise LexError(
                f"no terminal matches at byte {pos} ({data[pos:pos+12]!r})",
                pos=pos)
        tokens.append(LexToken(last_tag, data[pos:last_acc], pos))
        pos = last_acc
    return tokens, b""
