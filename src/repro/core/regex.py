"""Regex engine: parse -> NFA (Thompson) -> DFA (subset construction).

Byte-alphabet (0..255). Supports the subset needed for grammar terminals:
literals, escapes (\\d \\w \\s \\n \\t \\r \\f \\. etc.), char classes
[a-z0-9_] and negations [^...], '.', alternation '|', grouping '(...)',
quantifiers * + ? {m} {m,} {m,n}, and a case-insensitive flag (for "SELECT"i
style literal terminals).

DFAs carry numpy transition tables [num_states, 256] for vectorized walks
(used heavily by the mask-store construction).
"""
from __future__ import annotations

import numpy as np
from dataclasses import dataclass, field
from typing import Optional

ALPHABET = 256
DOT_EXCLUDES = frozenset(b"\n")  # '.' matches everything except newline


# --------------------------------------------------------------------------
# Regex AST
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class RNode:
    pass


@dataclass(frozen=True)
class RChars(RNode):
    """A set of byte values (char class / literal char)."""
    chars: frozenset


@dataclass(frozen=True)
class RConcat(RNode):
    parts: tuple


@dataclass(frozen=True)
class RAlt(RNode):
    options: tuple


@dataclass(frozen=True)
class RStar(RNode):
    inner: RNode


@dataclass(frozen=True)
class RPlus(RNode):
    inner: RNode


@dataclass(frozen=True)
class ROpt(RNode):
    inner: RNode


@dataclass(frozen=True)
class REpsilon(RNode):
    pass


_CLASS_SHORTCUTS = {
    ord("d"): frozenset(range(ord("0"), ord("9") + 1)),
    ord("w"): frozenset(
        list(range(ord("a"), ord("z") + 1))
        + list(range(ord("A"), ord("Z") + 1))
        + list(range(ord("0"), ord("9") + 1))
        + [ord("_")]
    ),
    ord("s"): frozenset(b" \t\n\r\f\v"),
}
_ESCAPES = {
    ord("n"): ord("\n"),
    ord("t"): ord("\t"),
    ord("r"): ord("\r"),
    ord("f"): ord("\f"),
    ord("v"): ord("\v"),
    ord("0"): 0,
    ord("a"): 7,
    ord("b"): 8,
}


class RegexSyntaxError(ValueError):
    pass


class _Parser:
    def __init__(self, pattern: bytes, ignore_case: bool = False):
        self.p = pattern
        self.i = 0
        self.ignore_case = ignore_case

    def peek(self) -> Optional[int]:
        return self.p[self.i] if self.i < len(self.p) else None

    def next(self) -> int:
        c = self.p[self.i]
        self.i += 1
        return c

    def parse(self) -> RNode:
        node = self.parse_alt()
        if self.i != len(self.p):
            raise RegexSyntaxError(f"trailing input at {self.i} in {self.p!r}")
        return node

    def parse_alt(self) -> RNode:
        opts = [self.parse_concat()]
        while self.peek() == ord("|"):
            self.next()
            opts.append(self.parse_concat())
        if len(opts) == 1:
            return opts[0]
        return RAlt(tuple(opts))

    def parse_concat(self) -> RNode:
        parts = []
        while True:
            c = self.peek()
            if c is None or c in (ord("|"), ord(")")):
                break
            parts.append(self.parse_quant())
        if not parts:
            return REpsilon()
        if len(parts) == 1:
            return parts[0]
        return RConcat(tuple(parts))

    def parse_quant(self) -> RNode:
        atom = self.parse_atom()
        while True:
            c = self.peek()
            if c == ord("*"):
                self.next()
                atom = RStar(atom)
            elif c == ord("+"):
                self.next()
                atom = RPlus(atom)
            elif c == ord("?"):
                self.next()
                atom = ROpt(atom)
            elif c == ord("{"):
                save = self.i
                rep = self._try_repeat()
                if rep is None:
                    self.i = save
                    break
                lo, hi = rep
                atom = self._expand_repeat(atom, lo, hi)
            else:
                break
        return atom

    def _try_repeat(self):
        # at '{'
        self.next()
        num1 = b""
        while self.peek() is not None and ord("0") <= self.peek() <= ord("9"):
            num1 += bytes([self.next()])
        if not num1:
            return None
        if self.peek() == ord("}"):
            self.next()
            n = int(num1)
            return (n, n)
        if self.peek() != ord(","):
            return None
        self.next()
        num2 = b""
        while self.peek() is not None and ord("0") <= self.peek() <= ord("9"):
            num2 += bytes([self.next()])
        if self.peek() != ord("}"):
            return None
        self.next()
        return (int(num1), int(num2) if num2 else None)

    @staticmethod
    def _expand_repeat(atom: RNode, lo: int, hi: Optional[int]) -> RNode:
        parts = [atom] * lo
        if hi is None:
            parts.append(RStar(atom))
        else:
            parts.extend([ROpt(atom)] * (hi - lo))
        if not parts:
            return REpsilon()
        if len(parts) == 1:
            return parts[0]
        return RConcat(tuple(parts))

    def _maybe_fold_case(self, chars: frozenset) -> frozenset:
        if not self.ignore_case:
            return chars
        out = set(chars)
        for c in chars:
            if ord("a") <= c <= ord("z"):
                out.add(c - 32)
            elif ord("A") <= c <= ord("Z"):
                out.add(c + 32)
        return frozenset(out)

    def parse_atom(self) -> RNode:
        c = self.peek()
        if c is None:
            return REpsilon()
        if c == ord("("):
            self.next()
            # swallow non-capturing / flags prefix (?: (?i: etc. -- treat as group
            if self.peek() == ord("?"):
                self.next()
                while self.peek() is not None and self.peek() != ord(")") and self.peek() != ord(":"):
                    self.next()
                if self.peek() == ord(":"):
                    self.next()
            node = self.parse_alt()
            if self.peek() != ord(")"):
                raise RegexSyntaxError(f"unbalanced paren in {self.p!r}")
            self.next()
            return node
        if c == ord("["):
            return self.parse_class()
        if c == ord("."):
            self.next()
            return RChars(frozenset(set(range(ALPHABET)) - set(DOT_EXCLUDES)))
        if c == ord("\\"):
            self.next()
            e = self.next()
            if e in _CLASS_SHORTCUTS:
                return RChars(self._maybe_fold_case(_CLASS_SHORTCUTS[e]))
            if e in (ord("D"), ord("W"), ord("S")):
                base = _CLASS_SHORTCUTS[e + 32]
                return RChars(frozenset(set(range(ALPHABET)) - set(base)))
            if e == ord("x"):
                lit = int(bytes([self.next(), self.next()]).decode(), 16)
                return RChars(frozenset([lit]))
            lit = _ESCAPES.get(e, e)
            return RChars(self._maybe_fold_case(frozenset([lit])))
        if c in (ord("*"), ord("+"), ord("?"), ord(")")):
            raise RegexSyntaxError(f"unexpected {chr(c)!r} at {self.i} in {self.p!r}")
        self.next()
        return RChars(self._maybe_fold_case(frozenset([c])))

    def parse_class(self) -> RNode:
        self.next()  # '['
        negate = False
        if self.peek() == ord("^"):
            negate = True
            self.next()
        chars: set = set()
        first = True
        while True:
            c = self.peek()
            if c is None:
                raise RegexSyntaxError(f"unterminated class in {self.p!r}")
            if c == ord("]") and not first:
                self.next()
                break
            first = False
            if c == ord("\\"):
                self.next()
                e = self.next()
                if e in _CLASS_SHORTCUTS:
                    chars |= set(_CLASS_SHORTCUTS[e])
                    continue
                if e == ord("x"):
                    lo = int(bytes([self.next(), self.next()]).decode(), 16)
                else:
                    lo = _ESCAPES.get(e, e)
            else:
                self.next()
                lo = c
            if self.peek() == ord("-") and self.i + 1 < len(self.p) and self.p[self.i + 1] != ord("]"):
                self.next()
                c2 = self.peek()
                if c2 == ord("\\"):
                    self.next()
                    e2 = self.next()
                    if e2 == ord("x"):
                        hi = int(bytes([self.next(), self.next()]).decode(), 16)
                    else:
                        hi = _ESCAPES.get(e2, e2)
                else:
                    self.next()
                    hi = c2
                chars |= set(range(lo, hi + 1))
            else:
                chars.add(lo)
        if negate:
            chars = set(range(ALPHABET)) - chars
        return RChars(self._maybe_fold_case(frozenset(chars)))


def parse_regex(pattern: str | bytes, ignore_case: bool = False) -> RNode:
    if isinstance(pattern, str):
        pattern = pattern.encode("utf-8")
    return _Parser(pattern, ignore_case=ignore_case).parse()


def literal_regex(text: str | bytes, ignore_case: bool = False) -> RNode:
    """AST matching exactly `text` (optionally case-insensitively)."""
    if isinstance(text, str):
        text = text.encode("utf-8")
    parts = []
    for c in text:
        chars = frozenset([c])
        if ignore_case:
            if ord("a") <= c <= ord("z"):
                chars = frozenset([c, c - 32])
            elif ord("A") <= c <= ord("Z"):
                chars = frozenset([c, c + 32])
        parts.append(RChars(chars))
    if not parts:
        return REpsilon()
    if len(parts) == 1:
        return parts[0]
    return RConcat(tuple(parts))


# --------------------------------------------------------------------------
# NFA (Thompson construction)
# --------------------------------------------------------------------------

class NFA:
    def __init__(self):
        self.eps: list[list[int]] = []          # state -> eps successors
        self.trans: list[list[tuple[frozenset, int]]] = []  # state -> [(chars, succ)]
        self.start = self.new_state()
        self.accept: int = -1

    def new_state(self) -> int:
        self.eps.append([])
        self.trans.append([])
        return len(self.eps) - 1

    def add_eps(self, a: int, b: int):
        self.eps[a].append(b)

    def add_trans(self, a: int, chars: frozenset, b: int):
        self.trans[a].append((chars, b))


def _build(nfa: NFA, node: RNode, entry: int) -> int:
    """Wire `node` starting at state `entry`; return exit state."""
    if isinstance(node, REpsilon):
        return entry
    if isinstance(node, RChars):
        out = nfa.new_state()
        nfa.add_trans(entry, node.chars, out)
        return out
    if isinstance(node, RConcat):
        cur = entry
        for part in node.parts:
            cur = _build(nfa, part, cur)
        return cur
    if isinstance(node, RAlt):
        out = nfa.new_state()
        for opt in node.options:
            s = nfa.new_state()
            nfa.add_eps(entry, s)
            e = _build(nfa, opt, s)
            nfa.add_eps(e, out)
        return out
    if isinstance(node, RStar):
        hub = nfa.new_state()
        nfa.add_eps(entry, hub)
        e = _build(nfa, node.inner, hub)
        nfa.add_eps(e, hub)
        return hub
    if isinstance(node, RPlus):
        e = _build(nfa, node.inner, entry)
        # loop: from e back via inner again
        hub = nfa.new_state()
        nfa.add_eps(e, hub)
        e2 = _build(nfa, node.inner, hub)
        nfa.add_eps(e2, hub)
        return hub
    if isinstance(node, ROpt):
        out = nfa.new_state()
        nfa.add_eps(entry, out)
        e = _build(nfa, node.inner, entry)
        nfa.add_eps(e, out)
        return out
    raise TypeError(node)


def nfa_from_ast(node: RNode) -> NFA:
    nfa = NFA()
    nfa.accept = _build(nfa, node, nfa.start)
    return nfa


# --------------------------------------------------------------------------
# DFA (subset construction over byte equivalence classes)
# --------------------------------------------------------------------------

class DFA:
    """Deterministic finite automaton over bytes.

    trans: np.ndarray [num_states, 256] int32 (DEAD = num_states-th implicit? no:
           dead state is an explicit state with all-self transitions and not live)
    """

    def __init__(self, trans: np.ndarray, start: int, finals: np.ndarray):
        self.trans = trans                  # [Q, 256] int32
        self.start = int(start)
        self.finals = finals.astype(bool)   # [Q]
        self.live = self._compute_live()    # [Q] bool

    @property
    def num_states(self) -> int:
        return self.trans.shape[0]

    def _compute_live(self) -> np.ndarray:
        Q = self.num_states
        live = self.finals.copy()
        # reverse reachability from finals
        # build reverse adjacency once
        radj: list[set] = [set() for _ in range(Q)]
        for q in range(Q):
            for s in set(self.trans[q].tolist()):
                radj[s].add(q)
        frontier = [q for q in range(Q) if live[q]]
        while frontier:
            nxt = []
            for q in frontier:
                for p in radj[q]:
                    if not live[p]:
                        live[p] = True
                        nxt.append(p)
            frontier = nxt
        return live

    def step(self, q: int, byte: int) -> int:
        return int(self.trans[q, byte])

    def walk(self, q: int, data: bytes) -> int:
        for b in data:
            q = int(self.trans[q, b])
        return q

    def accepts(self, data: bytes) -> bool:
        return bool(self.finals[self.walk(self.start, data)])

    def is_live(self, q: int) -> bool:
        return bool(self.live[q])

    def walk_live(self, q: int, data: bytes) -> int:
        """Walk, stopping early in the dead sink if we fall out of live states."""
        for b in data:
            q = int(self.trans[q, b])
            if not self.live[q]:
                return q
        return q


def dfa_from_nfa(nfa: NFA) -> DFA:
    """Subset construction. Returns DFA whose state 0 is the start; the last
    state index may be a dead sink (all transitions self, non-final)."""
    n = len(nfa.eps)

    # epsilon closures
    import collections
    eclo: list[frozenset] = []
    for s in range(n):
        seen = {s}
        stack = [s]
        while stack:
            x = stack.pop()
            for y in nfa.eps[x]:
                if y not in seen:
                    seen.add(y)
                    stack.append(y)
        eclo.append(frozenset(seen))

    start_set = eclo[nfa.start]
    state_ids: dict[frozenset, int] = {start_set: 0}
    order = [start_set]
    rows: list[np.ndarray] = []
    queue = collections.deque([start_set])

    # Precompute per-NFA-state char transition as (mask over 256, succ)
    while queue:
        cur = queue.popleft()
        # For each byte, target set
        row = np.full(ALPHABET, -1, dtype=np.int64)
        # gather moves: char -> set of targets. Use numpy mask accumulation.
        move: dict[int, set] = {}
        for s in cur:
            for chars, succ in nfa.trans[s]:
                for c in chars:
                    move.setdefault(c, set()).update(eclo[succ])
        # canonicalize target sets
        cache: dict[frozenset, int] = {}
        for c, tgt in move.items():
            ftgt = frozenset(tgt)
            if ftgt in cache:
                row[c] = cache[ftgt]
                continue
            if ftgt not in state_ids:
                state_ids[ftgt] = len(order)
                order.append(ftgt)
                queue.append(ftgt)
            row[c] = state_ids[ftgt]
            cache[ftgt] = row[c]
        rows.append(row)

    Q = len(order)
    dead = Q  # dead sink
    trans = np.full((Q + 1, ALPHABET), dead, dtype=np.int32)
    for q, row in enumerate(rows):
        valid = row >= 0
        trans[q, valid] = row[valid]
    finals = np.zeros(Q + 1, dtype=bool)
    for q, st in enumerate(order):
        if nfa.accept in st:
            finals[q] = True
    return DFA(trans, 0, finals)


def minimize(dfa: DFA) -> DFA:
    """Moore partition refinement (fine for our state counts)."""
    Q = dfa.num_states
    # initial partition: final vs non-final (and keep dead separate implicitly)
    part = dfa.finals.astype(np.int64).copy()
    nparts = 2
    while True:
        # signature: (part, parts of successors for each byte) -- hash rows
        succ_parts = part[dfa.trans]  # [Q, 256]
        sig = np.concatenate([part[:, None], succ_parts], axis=1)
        _, new_part = np.unique(sig, axis=0, return_inverse=True)
        new_n = int(new_part.max()) + 1
        if new_n == nparts:
            # Moore refinement only splits blocks, so equal counts => stable.
            part = new_part
            break
        part = new_part
        nparts = new_n
    # rebuild
    new_trans = np.zeros((nparts, ALPHABET), dtype=np.int32)
    new_finals = np.zeros(nparts, dtype=bool)
    for q in range(Q):
        new_trans[part[q]] = part[dfa.trans[q]]
        if dfa.finals[q]:
            new_finals[part[q]] = True
    return DFA(new_trans, int(part[dfa.start]), new_finals)


def compile_regex(pattern: str | bytes, ignore_case: bool = False,
                  do_minimize: bool = True) -> DFA:
    ast = parse_regex(pattern, ignore_case=ignore_case)
    dfa = dfa_from_nfa(nfa_from_ast(ast))
    return minimize(dfa) if do_minimize else dfa


def compile_literal(text: str | bytes, ignore_case: bool = False) -> DFA:
    dfa = dfa_from_nfa(nfa_from_ast(literal_regex(text, ignore_case=ignore_case)))
    return minimize(dfa)
