"""Random sentence sampling from a CFG (used by the synthetic data
pipeline and by property tests as a source of guaranteed-valid strings).

Derivation is depth-bounded: below the budget, expansion prefers the
shortest-derivation production for each nonterminal so sampling always
terminates.
"""
from __future__ import annotations

import random

from .grammar import Grammar
from .regex import DFA


def _min_depths(grammar: Grammar) -> dict[str, int]:
    """Min derivation depth per nonterminal (terminals = 0)."""
    INF = 10 ** 9
    depth = {nt: INF for nt in grammar.nonterminals}
    changed = True
    while changed:
        changed = False
        for p in grammar.productions:
            d = 0
            for sym in p.rhs:
                d = max(d, depth.get(sym, 0) if sym in grammar.nonterminals
                        else 0)
            d += 1
            if d < depth[p.lhs]:
                depth[p.lhs] = d
                changed = True
    return depth


_DIST_CACHE: dict[int, list] = {}


def _dist_to_accept(dfa: DFA) -> list:
    key = id(dfa)
    if key in _DIST_CACHE:
        return _DIST_CACHE[key]
    import collections
    Q = dfa.num_states
    dist = [None] * Q
    radj = [[] for _ in range(Q)]
    for q in range(Q):
        for c in range(256):
            radj[int(dfa.trans[q, c])].append((q, c))
    dq = collections.deque()
    for q in range(Q):
        if dfa.finals[q]:
            dist[q] = 0
            dq.append(q)
    while dq:
        q = dq.popleft()
        for (p, c) in radj[q]:
            if dist[p] is None:
                dist[p] = dist[q] + 1
                dq.append(p)
    _DIST_CACHE[key] = dist
    return dist


def sample_terminal_string(dfa: DFA, rng: random.Random,
                           max_len: int = 12) -> bytes:
    """Random shortest-biased string accepted by a DFA."""
    dist = _dist_to_accept(dfa)
    out = bytearray()
    q = dfa.start
    while True:
        if dfa.finals[q] and (len(out) >= 1 or dist[q] == 0):
            # stochastically stop; always stop at max_len
            if len(out) >= max_len or rng.random() < 0.45:
                return bytes(out)
        # choose a char that keeps (or brings) us near acceptance
        choices = []
        for c in range(256):
            nq = int(dfa.trans[q, c])
            if dist[nq] is not None:
                budget_ok = dist[nq] + len(out) < max_len + 2
                if budget_ok:
                    choices.append((c, nq))
        if not choices:
            # must already be final (dist[q]==0), else walk greedily
            if dfa.finals[q]:
                return bytes(out)
            choices = [(c, int(dfa.trans[q, c])) for c in range(256)
                       if dist[int(dfa.trans[q, c])] is not None]
        # bias toward printable ascii
        printable = [(c, nq) for (c, nq) in choices if 32 <= c < 127]
        c, q = rng.choice(printable or choices)
        out.append(c)


class GrammarSampler:
    def __init__(self, grammar: Grammar, seed: int = 0,
                 max_terminal_len: int = 10):
        self.grammar = grammar
        self.rng = random.Random(seed)
        self.by_lhs = grammar.prods_by_lhs()
        self.min_depth = _min_depths(grammar)
        self.max_terminal_len = max_terminal_len
        self._needs_space_cache: dict[tuple, bool] = {}
        # layout-sensitive (%indent) grammars: INDENT/DEDENT are synthetic
        # (no lexeme of their own) and NEWLINE lexemes must carry the
        # following line's indentation, so the sampler renders them
        # canonically instead of sampling their DFAs.
        self._indent = grammar.indent_spec
        self._level = 0
        self._nl_buf = b""

    def _expand(self, sym: str, budget: int, out: list[bytes]):
        g = self.grammar
        if sym not in g.nonterminals:
            if self._indent is not None:
                nl_t, ind_t, ded_t = self._indent
                if sym == ind_t:
                    self._level += 4
                    return
                if sym == ded_t:
                    self._level = max(0, self._level - 4)
                    return
                if sym == nl_t:
                    # buffered: the newline and the next line's indent must
                    # reach the glue step as ONE piece, so no separator can
                    # be inserted inside the NEWLINE lexeme
                    self._nl_buf = b"\n"
                    return
            dfa = g.terminals[sym].dfa
            from .lexer import LexError, lex_partial
            for _ in range(50):
                s = sample_terminal_string(dfa, self.rng,
                                           self.max_terminal_len)
                # the sampled string must actually *lex* as this terminal
                # (e.g. a random NAME must not collide with a keyword)
                try:
                    toks, rem = lex_partial(g, s)
                except LexError:
                    continue
                if not rem and len(toks) == 1 and toks[0].type == sym:
                    if self._indent is not None and self._nl_buf:
                        s = self._nl_buf + b" " * self._level + s
                        self._nl_buf = b""
                    out.append(s)
                    return
            raise RuntimeError(f"cannot sample terminal {sym}")
        prods = self.by_lhs[sym]
        if budget <= self.min_depth[sym]:
            # forced: pick a minimal production
            best = min(prods, key=lambda p: max(
                [self.min_depth.get(s, 0) for s in p.rhs] or [0]))
            choices = [best]
        else:
            choices = [p for p in prods
                       if max([self.min_depth.get(s, 0)
                               for s in p.rhs] or [0]) < budget]
            if not choices:
                choices = [min(prods, key=lambda p: max(
                    [self.min_depth.get(s, 0) for s in p.rhs] or [0]))]
        p = self.rng.choice(choices)
        for s in p.rhs:
            self._expand(s, budget - 1, out)

    def sample_batch(self, n: int, budget: int = 24,
                     max_bytes: int | None = None) -> list[bytes]:
        """n syntactically valid strings (benchmark corpora / property
        tests / synthetic-data batches for the training pipeline)."""
        return [self.sample(budget, max_bytes) for _ in range(n)]

    def sample(self, budget: int = 24, max_bytes: int | None = None) -> bytes:
        """One syntactically valid string; pieces are separated by a space
        whenever gluing them would merge two lexical tokens. `max_bytes`
        retries with shrinking budget (derivations can blow up)."""
        b = budget
        for _ in range(16):
            pieces: list[bytes] = []
            self._level = 0
            self._nl_buf = b""
            self._expand(self.grammar.start, b, pieces)
            s = self._glue(pieces)
            if max_bytes is None or len(s) <= max_bytes:
                return s
            b = max(3, b - 3)
        return s

    def _lex_sig(self, data: bytes):
        from .lexer import LexError, lex_partial
        try:
            toks, rem = lex_partial(self.grammar, data)
        except LexError:
            return None
        return ([(t.type, t.value) for t in toks
                 if t.type not in self.grammar.ignores], rem)

    def _glue(self, pieces: list[bytes]) -> bytes:
        """Linear-time glue: only the boundary window is re-lexed."""
        out = bytearray()
        for piece in pieces:
            if not piece:
                continue
            if not out:
                out += piece
                continue
            w = 16
            while True:
                tail = bytes(out[-w:])
                sig_glued = self._lex_sig(tail + piece)
                sig_spaced = self._lex_sig(tail + b" " + piece)
                if sig_glued is not None or sig_spaced is not None:
                    break
                if w >= len(out):
                    break
                # the window started mid-token (e.g. inside a string
                # literal with bytes that are dead outside strings) and
                # nothing lexes: widen until the boundary re-lex is honest
                w *= 2
            if sig_glued is not None and sig_glued == sig_spaced:
                out += piece
            elif sig_spaced is None:
                # whitespace is not lexable in this grammar (compact
                # formats like jsonmsg): direct glue is the only option —
                # such grammars must delimit adjacent terminals
                # punctuationally, which the boundary re-lex confirms
                out += piece
            else:
                out += b" " + piece
        return bytes(out)
