"""Incremental LR parser producing accept sequences + remainder (paper §4.2,
§4.5, Appendix A.3).

`IncrementalParser.partial_parse(C_k)` returns a `ParseResult` with:
  * accept_sequences: list of 1- or 2-length terminal-name tuples (the set A)
  * remainder r (bytes) — suffix of C_k whose lexical type may still change
  * eos_allowed — whether C_k ∈ L(G) (the EOS token may be emitted)

Incrementality (App. A.3): parser stacks are cached per prefix of the
non-ignored lexical token list; re-parsing after the LLM appends a token
restores the longest cached prefix and parses only the new tail.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .grammar import END, Grammar
from .lexer import (LexError, LexToken, lex_partial, lex_partial_state,
                    postlex_indent)
from .lr import LRTable, build_lr_table


class ParseError(ValueError):
    pass


@dataclass(slots=True)
class ParseResult:
    accept_sequences: list        # list[tuple[str, ...]]
    remainder: bytes
    eos_allowed: bool
    tokens: list = field(default_factory=list)
    case: int = 1                 # 1 or 2 (paper's remainder cases)


class IncrementalParser:
    def __init__(self, grammar: Grammar, table: LRTable | None = None,
                 lalr: bool = True, max_accept: int | None = None):
        self.grammar = grammar
        self.table = table or build_lr_table(grammar, lalr=lalr)
        self.ignores = set(grammar.ignores)
        self.parse_terminal_list = list(grammar.parse_terminals)
        self.max_accept = max_accept
        # incremental cache: token keys + stack snapshots (tuples).
        # _cache_toks holds the LexToken objects themselves: the lex and
        # postlex caches reuse prefix token objects verbatim, so an `is`
        # scan resolves the common prefix without tuple compares.
        self._cache_keys: list[tuple] = []
        self._cache_toks: list = []
        self._cache_stacks: list[tuple] = [(self.table.start_state,)]
        # persistent accept-set memo (paper App. A.3's "parser residue"
        # carried across steps): A(stack) and END-acceptability are pure
        # functions of the hashable stack tuple, and generation re-visits
        # the same stacks for many consecutive steps (the committed token
        # list only changes when a lexeme closes, while the remainder
        # grows byte by byte). LALR accept sets cost one simulated
        # reduce-loop per terminal, so the memo turns the dominant
        # per-step parser cost into a dict hit. Bounded; never stale
        # (the LR table is fixed per parser), so reset_cache() keeps it.
        self._accept_memo: dict[tuple, list] = {}
        self._end_memo: dict[tuple, bool] = {}
        # accept-SEQUENCE memo: the full accept_sequences list of a step
        # is a pure function of (branch, parser stack, final-token type,
        # indent context) — everything except the remainder bytes — so
        # consecutive decode steps that only grow the current lexeme
        # rebuild nothing. Values are shared read-only lists (callers
        # never mutate accept_sequences); the PARSE_DEAD sentinel caches
        # the no-acceptable-terminals ParseError so oracle probes that
        # keep hitting the same dead configuration stay cheap.
        self._seq_memo: dict[tuple, tuple] = {}
        self._eof_memo: dict[tuple, bool] = {}
        # incremental lexing: (data, tokens, filtered-tokens) snapshots.
        # `tip` tracks the
        # most recent text, `base` the prefix it extended — together they
        # serve both the engine's committed text (base) and the oracle's
        # one-token probes (tip) with O(delta) relexing.
        self._lex_tip: tuple | None = None
        self._lex_base: tuple | None = None
        # whole-step result cache: partial_parse(data) repeated with the
        # SAME bytes returns the previous (never-mutated) ParseResult —
        # the engine re-parses the committed text right after the oracle
        # probed that exact extension, and saturated slots repeat texts.
        self._pp_cache: tuple | None = None
        # case-1 memo fast path keyed by the identity of the (cached,
        # identity-stable) head stack — skips re-hashing the stack tuple
        self._c1_fast: dict[tuple, tuple] = {}
        # filtered (non-ignored) view of the tip's token list, maintained
        # incrementally alongside it; read-only for consumers.
        self._lex_ffilt: list = []
        # postlex fold resume slots (%indent grammars): 2-entry LRU of
        # (toks, prefix_state); validated by object identity before use.
        self._postlex_tip: tuple | None = None
        self._postlex_base: tuple | None = None

    _PARSE_DEAD = ("dead",)

    # ---------------- incremental lexing ----------------

    def _lex_partial_cached(self, data: bytes):
        """lex_partial with O(delta) resume. Every committed token except
        the final one is immutable under appended bytes (its DFA walk
        died strictly before the old end of input); the final unit's walk
        state is carried forward, so appended bytes continue that walk
        instead of relexing the token. Returns (tokens, unlexed) exactly
        like lex_partial; the returned token list is freshly built and
        safe to slice."""
        src = self._lex_tip
        if src is None or len(data) < len(src[0]) \
                or not data.startswith(src[0]):
            src = self._lex_base
            if src is not None and (len(data) < len(src[0])
                                    or not data.startswith(src[0])):
                src = None
        ignores = self.ignores
        lps = lex_partial_state
        if src is not None and (src[3] is not None or src[1]):
            stoks = src[1]
            sf = src[2]
            st = src[3]
            # drop the old final token when the resumed walk re-emits it
            # (a walk state at its pos), or when no walk state survived
            # and it must be relexed from its own start.
            if st is None or (stoks and stoks[-1].pos == st[0]):
                keep = stoks[:-1]
                kf = sf[:-1] if sf and sf[-1] is stoks[-1] else sf
            else:
                keep = stoks
                kf = sf
            if st is not None:
                tail, unlexed, nst = lps(self.grammar, data, 0, st)
            else:
                tail, unlexed, nst = lps(self.grammar, data,
                                         stoks[-1].pos)
            toks = keep + tail
            # filter(keep) == src ffilt minus the dropped token (iff the
            # filter kept it) — O(1) + O(|tail|), not O(n).
            ffilt = kf + [t for t in tail if t.type not in ignores]
        else:
            toks, unlexed, nst = lps(self.grammar, data)
            ffilt = [t for t in toks if t.type not in ignores]
        self._lex_tip = (data, toks, ffilt, nst)
        self._lex_ffilt = ffilt
        if src is not None and len(src[0]) < len(data):
            self._lex_base = src
        return toks, unlexed

    # ---------------- LR machinery ----------------

    def _shift(self, stack: list, term: str) -> bool:
        """Perform reduces until `term` can be shifted; mutate stack.
        Returns False (stack possibly dirty) if `term` is not acceptable."""
        action = self.table.action
        goto = self.table.goto
        prods = self.table.productions
        while True:
            ent = action[stack[-1]].get(term)
            if ent is None:
                return False
            op = ent[0]
            if op == "s":
                stack.append(ent[1])
                return True
            if op == "acc":
                return True
            # reduce
            prod = prods[ent[1]]
            if len(prod.rhs):
                del stack[-len(prod.rhs):]
            nxt = goto[stack[-1]].get(prod.lhs)
            if nxt is None:
                return False
            stack.append(nxt)

    def _can_shift(self, stack: tuple, term: str) -> bool:
        if not self.table.lalr:
            # canonical LR(1): immediate error detection — table presence
            # is exact.
            return term in self.table.action[stack[-1]]
        s = list(stack)
        return self._shift(s, term)

    _MEMO_CAP = 1 << 13   # entries; cleared wholesale on overflow

    def accept_terminals(self, stack: tuple) -> list[str]:
        """A(stack): acceptable next terminals (paper's immediate-error-
        detection accept set), excluding END. Memoized per stack tuple;
        callers treat the returned list as read-only."""
        memo = self._accept_memo
        out = memo.get(stack)
        if out is None:
            if not self.table.lalr:
                out = [t for t in self.table.action[stack[-1]]
                       if t != END]
            else:
                out = [t for t in self.parse_terminal_list
                       if self._can_shift(stack, t)]
            if len(memo) >= self._MEMO_CAP:
                memo.clear()
            memo[stack] = out
        return out

    def _end_acceptable(self, stack: tuple) -> bool:
        memo = self._end_memo
        ok = memo.get(stack)
        if ok is None:
            ok = self._can_shift(stack, END)
            if len(memo) >= self._MEMO_CAP:
                memo.clear()
            memo[stack] = ok
        return ok

    # ---------------- incremental prefix parsing ----------------

    def _parse_tokens(self, toks: list[LexToken]) -> tuple:
        """Parse non-ignored tokens, using/updating the prefix cache.
        Returns the final stack (tuple)."""
        ck = self._cache_keys
        ct = self._cache_toks
        cp = 0
        nt = len(toks)
        maxcp = min(nt, len(ck))
        # fast path: shared token objects (the lex/postlex caches reuse
        # prefix objects) — then fall back to (type, value) compares for
        # any relexed-but-identical region.
        while cp < maxcp and toks[cp] is ct[cp]:
            cp += 1
        if cp == nt and nt == len(ck):
            return self._cache_stacks[nt]
        while cp < maxcp:
            k = ck[cp]
            t = toks[cp]
            if k[0] != t.type or k[1] != t.value:
                break
            cp += 1
        # truncate stale cache
        del ck[cp:]
        del ct[cp:]
        del self._cache_stacks[cp + 1:]
        stack = list(self._cache_stacks[cp])
        for i in range(cp, len(toks)):
            t = toks[i]
            if not self._shift(stack, t.type):
                raise ParseError(
                    f"unexpected {t.type} ({t.value!r}) at byte {t.pos}")
            ck.append((t.type, t.value))
            ct.append(t)
            self._cache_stacks.append(tuple(stack))
        # return the cached snapshot: identity-stable across steps whose
        # committed tokens are unchanged (memo keys hash it every step)
        return self._cache_stacks[len(toks)]

    def parse_from_scratch_stack(self, toks: list[LexToken]) -> tuple:
        stack = [self.table.start_state]
        for t in toks:
            if not self._shift(stack, t.type):
                raise ParseError(
                    f"unexpected {t.type} ({t.value!r}) at byte {t.pos}")
        return tuple(stack)

    def reset_cache(self):
        self._cache_keys = []
        self._cache_toks = []
        self._cache_stacks = [(self.table.start_state,)]
        # the accept-set/sequence memos are pure functions of the LR
        # table and survive resets; only the per-text state is dropped
        self._lex_tip = None
        self._lex_base = None
        self._lex_ffilt = []
        self._pp_cache = None
        self._postlex_tip = None
        self._postlex_base = None

    # ---------------- the paper's partial parse ----------------

    def partial_parse(self, data: bytes, incremental: bool = True) -> ParseResult:
        if incremental:
            pp = self._pp_cache
            if pp is not None and pp[0] == data:
                return pp[1]
            toks, unlexed = self._lex_partial_cached(data)
            res = self._parse_step(toks, unlexed, True)
            self._pp_cache = (data, res)
            return res
        toks, unlexed = lex_partial(self.grammar, data)
        return self._parse_step(toks, unlexed, False)

    def _parse_step(self, toks: list, unlexed: bytes,
                    incremental: bool) -> ParseResult:
        if self.grammar.indent_spec is not None:
            return self._partial_parse_indent(toks, unlexed, incremental)
        ignores = self.ignores
        memo = self._seq_memo

        if unlexed:
            # Case 2: unlexed suffix u — parse ALL lexed tokens, 1-length
            # sequences from the accept set.
            parse_toks = (self._lex_ffilt if incremental
                          else [t for t in toks if t.type not in ignores])
            stack = (self._parse_tokens(parse_toks) if incremental
                     else self.parse_from_scratch_stack(parse_toks))
            hit = memo.get(("c2", stack))
            if hit is None:
                a1 = self.accept_terminals(stack)
                seqs = [(t,) for t in a1]
                seqs += [(ig,) for ig in self.grammar.ignores]
                hit = (self._cap(seqs), False)
                self._memo_put(("c2", stack), hit)
            return ParseResult(hit[0], unlexed, eos_allowed=False,
                               tokens=toks, case=2)

        # Case 1: input ends at a complete lexical token l_f (possibly none)
        if not toks:
            stack = (self._parse_tokens([]) if incremental
                     else self.parse_from_scratch_stack([]))
            hit = memo.get(("c0", stack))
            if hit is None:
                a0 = self.accept_terminals(stack)
                seqs = [(t,) for t in a0]
                seqs += [(ig,) for ig in self.grammar.ignores]
                hit = (self._cap(seqs), self._end_acceptable(stack))
                self._memo_put(("c0", stack), hit)
            return ParseResult(hit[0], b"", eos_allowed=hit[1],
                               tokens=toks, case=1)

        lf = toks[-1]
        if incremental:
            ff = self._lex_ffilt
            parse_head = ff[:-1] if ff and ff[-1] is lf else ff
            stack0 = self._parse_tokens(parse_head)
        else:
            parse_head = [t for t in toks[:-1] if t.type not in ignores]
            stack0 = self.parse_from_scratch_stack(parse_head)
        fkey = (id(stack0), lf.type)
        fhit = self._c1_fast.get(fkey)
        if fhit is not None and fhit[0] is stack0:
            hit = fhit[1]
        else:
            hit = memo.get((stack0, lf.type))
            if hit is None:
                hit = self._build_case1(stack0, lf.type)
                self._memo_put((stack0, lf.type), hit)
            if len(self._c1_fast) >= self._MEMO_CAP:
                self._c1_fast.clear()
            self._c1_fast[fkey] = (stack0, hit)
        if hit is self._PARSE_DEAD:
            raise ParseError(
                f"unexpected {lf.type} ({lf.value!r}) at byte "
                f"{lf.pos}: no acceptable terminals")
        return ParseResult(hit[0], lf.value, eos_allowed=hit[1],
                           tokens=toks, case=1)

    def _build_case1(self, stack0: tuple, lf_type: str):
        """(accept_sequences, eos) for a flat-grammar Case-1 step — a
        pure function of (stack0, lf_type). Returns the _PARSE_DEAD
        sentinel when no terminal is acceptable."""
        a0 = self.accept_terminals(stack0)
        shifted = True
        if lf_type in self.ignores:
            eos = self._end_acceptable(stack0)
            a1 = a0
        else:
            s = list(stack0)
            if self._shift(s, lf_type):
                stack1 = tuple(s)
                eos = self._end_acceptable(stack1)
                a1 = self.accept_terminals(stack1)
            else:
                # l_f's current type is not acceptable here — but the token
                # may still grow into an acceptable terminal (e.g. "!" ->
                # "!=", identifier prefix -> keyword). Only the 1-length
                # A0 sequences apply (paper §4.5 Case 1).
                shifted = False
                eos = False
                a1 = []
                if not a0:
                    return self._PARSE_DEAD
        seqs = []
        if shifted:
            seqs += [(lf_type, t1) for t1 in a1]
            seqs += [(lf_type, ig) for ig in self.grammar.ignores]
        seqs += [(t0,) for t0 in a0 if t0 != lf_type]
        return (self._cap(seqs), eos)

    def _memo_put(self, key, val):
        memo = self._seq_memo
        if len(memo) >= self._MEMO_CAP:
            memo.clear()
        memo[key] = val

    # ---------------- indent-aware partial parse (%indent grammars) -------

    def _indent_eof_ok(self, stack: tuple, levels: tuple, paren: int,
                       has_content: bool) -> bool:
        """EOF closure: the last logical line needs no trailing newline
        byte — emit an implicit NEWLINE (when any content exists), then
        one DEDENT per open level, then END must be shiftable. Memoized:
        a pure function of (stack, open-level count, has_content) once
        the bracket-depth gate passes."""
        if paren > 0:
            return False
        key = (stack, len(levels), has_content)
        memo = self._eof_memo
        ok = memo.get(key)
        if ok is None:
            nl_t, _ind_t, ded_t = self.grammar.indent_spec
            s = list(stack)
            if has_content and not self._shift(s, nl_t):
                ok = False
            else:
                ok = True
                for _ in range(len(levels) - 1):
                    if not self._shift(s, ded_t):
                        ok = False
                        break
                if ok:
                    ok = self._can_shift(tuple(s), END)
            if len(memo) >= self._MEMO_CAP:
                memo.clear()
            memo[key] = ok
        return ok

    def _postlex_cached(self, toks: list, unlexed: bytes):
        """postlex_indent with fold resume: reuse a prefix_state from a
        recent call whose token prefix is unchanged. Validation is an
        object-identity scan — the lex cache shares prefix LexToken
        objects across steps, so a hit costs O(k) pointer compares and
        the fold itself re-processes only the final token."""
        resume = None
        n = len(toks)
        for ent in (self._postlex_tip, self._postlex_base):
            if ent is None:
                continue
            ptoks, state = ent
            k = state[0]
            if k >= n or k > len(ptoks):
                continue
            ok = True
            for i in range(k):
                if toks[i] is not ptoks[i]:
                    ok = False
                    break
            if ok:
                resume = state
                break
        res = postlex_indent(self.grammar, toks, unlexed, resume=resume)
        if res.prefix_state is not None:
            old = self._postlex_tip
            self._postlex_tip = (toks, res.prefix_state)
            if old is not None and old[1][0] < res.prefix_state[0]:
                self._postlex_base = old
        return res

    def _partial_parse_indent(self, toks: list, unlexed: bytes,
                              incremental: bool) -> ParseResult:
        g = self.grammar
        nl_t, ind_t, ded_t = g.indent_spec
        synth = g.synthetic_terminals
        res = (self._postlex_cached(toks, unlexed) if incremental
               else postlex_indent(g, toks, unlexed))
        parse_all = [t for t in res.tokens if t.type not in self.ignores]

        def accepts(stack: tuple) -> list:
            # INDENT/DEDENT are zero-width — they never head an accept
            # sequence (no byte can lex into them); the pending-NEWLINE
            # branch expansion below accounts for them instead.
            return [t for t in self.accept_terminals(stack)
                    if t not in synth]

        def parse(ts):
            return (self._parse_tokens(ts) if incremental
                    else self.parse_from_scratch_stack(ts))

        memo = self._seq_memo

        if unlexed:
            # Case 2: everything lexed is committed (new bytes extend the
            # unlexed suffix, never a committed token).
            stack = parse(parse_all)
            hit = memo.get(("i2", stack))
            if hit is None:
                seqs = [(t,) for t in accepts(stack)]
                seqs += [(ig,) for ig in g.ignores]
                hit = (self._cap(seqs), False)
                self._memo_put(("i2", stack), hit)
            return ParseResult(hit[0], unlexed, eos_allowed=False,
                               tokens=toks, case=2)

        if res.pending is not None:
            # Trailing NEWLINE with its indent level still open: the next
            # line may land on the current level, one deeper (INDENT), or
            # any enclosing one (DEDENT+) — and more newline/comment bytes
            # may extend the lexeme first. Union the accept sets over all
            # reachable branches; the exact oracle re-checks on commit.
            stack0 = parse(parse_all)
            has = any(t.type not in synth for t in parse_all)
            key = ("ip", stack0, len(res.levels), has)
            hit = memo.get(key)
            if hit is None:
                hit = self._build_pending(stack0, len(res.levels), has)
                self._memo_put(key, hit)
            if hit is self._PARSE_DEAD:
                raise ParseError(
                    f"unexpected {nl_t} at byte {res.pending.pos}")
            eos = self._indent_eof_ok(stack0, res.levels, res.paren, has)
            return ParseResult(hit[0], res.pending.value,
                               eos_allowed=eos, tokens=toks, case=1)

        if toks and toks[-1].type == nl_t and res.paren > 0:
            # Trailing NEWLINE inside brackets: dropped from the parse
            # (implicit line joining) but still the lexical remainder.
            stack0 = parse(parse_all)
            hit = memo.get(("ib", stack0))
            if hit is None:
                seqs = [(nl_t, t1) for t1 in accepts(stack0)]
                seqs += [(nl_t, ig) for ig in g.ignores]
                hit = (self._cap(seqs), False)
                self._memo_put(("ib", stack0), hit)
            return ParseResult(hit[0], toks[-1].value,
                               eos_allowed=False, tokens=toks, case=1)

        if not toks:
            stack = parse([])
            hit = memo.get(("i0", stack))
            if hit is None:
                a0 = accepts(stack)
                seqs = [(t,) for t in a0] + [(ig,) for ig in g.ignores]
                hit = (self._cap(seqs), self._can_shift(stack, END))
                self._memo_put(("i0", stack), hit)
            return ParseResult(hit[0], b"", eos_allowed=hit[1],
                               tokens=toks, case=1)

        # Case 1 with a real (or ignored) final token: identical to the
        # flat-grammar path, except the head went through the post-lexer
        # and EOS uses the EOF closure. The seqs are a pure function of
        # (stack0, lf.type); EOS also needs the indent context, so the
        # memo records WHICH stack the EOF closure starts from.
        lf = toks[-1]
        head_parse = [t for t in res.tokens[:-1] if t.type not in self.ignores]
        stack0 = parse(head_parse)
        has_head = any(t.type not in synth for t in head_parse)
        key = ("i1", stack0, lf.type)
        hit = memo.get(key)
        if hit is None:
            hit = self._build_indent_case1(stack0, lf.type)
            self._memo_put(key, hit)
        if hit is self._PARSE_DEAD:
            raise ParseError(
                f"unexpected {lf.type} ({lf.value!r}) at byte "
                f"{lf.pos}: no acceptable terminals")
        seqs, eos_mode, stack1 = hit
        if eos_mode == 0:                         # ignored l_f: no shift
            eos = self._indent_eof_ok(stack0, res.levels, res.paren,
                                      has_head)
        elif eos_mode == 1:                       # shifted l_f
            eos = self._indent_eof_ok(stack1, res.levels, res.paren,
                                      True)
        else:
            eos = False                           # unshiftable, growing l_f
        return ParseResult(seqs, lf.value, eos_allowed=eos,
                           tokens=toks, case=1)

    def _build_pending(self, stack0: tuple, nlevels: int, has: bool):
        """Accept sequences for the open-NEWLINE branch union — a pure
        function of (stack0, open-level count, has-content)."""
        g = self.grammar
        nl_t, ind_t, ded_t = g.indent_spec
        synth = g.synthetic_terminals

        def accepts(stack):
            return [t for t in self.accept_terminals(stack)
                    if t not in synth]

        if has:
            s = list(stack0)
            if not self._shift(s, nl_t):
                return self._PARSE_DEAD
            s1 = tuple(s)
        else:
            s1 = stack0         # leading blank/comment lines: no NEWLINE
        branch = list(accepts(s1))
        s = list(s1)
        if self._shift(s, ind_t):
            branch += accepts(tuple(s))
        s = list(s1)
        for _ in range(nlevels - 1):
            if not self._shift(s, ded_t):
                break
            branch += accepts(tuple(s))
        seqs = [(nl_t, t1) for t1 in dict.fromkeys(branch)]
        seqs += [(nl_t, ig) for ig in g.ignores]
        return (self._cap(seqs),)

    def _build_indent_case1(self, stack0: tuple, lf_type: str):
        """(accept_sequences, eos_mode, stack1) for an indent Case-1
        step. eos_mode selects the EOF-closure start: 0 = stack0 with
        the head's has_content (ignored l_f), 1 = the post-shift stack1
        with content (shifted l_f), 2 = EOS impossible (unshiftable,
        still-growing l_f)."""
        g = self.grammar
        synth = g.synthetic_terminals

        def accepts(stack):
            return [t for t in self.accept_terminals(stack)
                    if t not in synth]

        a0 = accepts(stack0)
        shifted = True
        eos_mode, stack1 = 0, None
        if lf_type in self.ignores:
            a1 = a0
        else:
            s = list(stack0)
            if self._shift(s, lf_type):
                stack1 = tuple(s)
                eos_mode = 1
                a1 = accepts(stack1)
            else:
                shifted = False
                eos_mode = 2
                a1 = []
                if not a0:
                    return self._PARSE_DEAD
        seqs = []
        if shifted:
            seqs += [(lf_type, t1) for t1 in a1]
            seqs += [(lf_type, ig) for ig in g.ignores]
        seqs += [(t0,) for t0 in a0 if t0 != lf_type]
        return (self._cap(seqs), eos_mode, stack1)

    def _cap(self, seqs):
        # dedupe, keep order
        seen = set()
        out = []
        for s in seqs:
            if s not in seen:
                seen.add(s)
                out.append(s)
        if self.max_accept is not None:
            out = out[: self.max_accept]
        return out

    # ---------------- whole-string recognition (for tests/benchmarks) ----

    def recognize(self, data: bytes) -> bool:
        """C ∈ L(G)?"""
        try:
            toks, unlexed = lex_partial(self.grammar, data)
        except LexError:
            return False
        if unlexed:
            return False
        if self.grammar.indent_spec is not None:
            try:
                res = postlex_indent(self.grammar, toks, b"", at_eof=True)
            except LexError:
                return False
            if res.paren > 0:
                return False
            toks = res.tokens
        parse_toks = [t for t in toks if t.type not in self.ignores]
        stack = [self.table.start_state]
        for t in parse_toks:
            if not self._shift(stack, t.type):
                return False
        return self._can_shift(tuple(stack), END)
