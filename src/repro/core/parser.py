"""Incremental LR parser producing accept sequences + remainder (paper §4.2,
§4.5, Appendix A.3).

`IncrementalParser.partial_parse(C_k)` returns a `ParseResult` with:
  * accept_sequences: list of 1- or 2-length terminal-name tuples (the set A)
  * remainder r (bytes) — suffix of C_k whose lexical type may still change
  * eos_allowed — whether C_k ∈ L(G) (the EOS token may be emitted)

Incrementality (App. A.3): parser stacks are cached per prefix of the
non-ignored lexical token list; re-parsing after the LLM appends a token
restores the longest cached prefix and parses only the new tail.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .grammar import END, Grammar
from .lexer import LexError, LexToken, lex_partial, postlex_indent
from .lr import LRTable, build_lr_table


class ParseError(ValueError):
    pass


@dataclass
class ParseResult:
    accept_sequences: list        # list[tuple[str, ...]]
    remainder: bytes
    eos_allowed: bool
    tokens: list = field(default_factory=list)
    case: int = 1                 # 1 or 2 (paper's remainder cases)


class IncrementalParser:
    def __init__(self, grammar: Grammar, table: LRTable | None = None,
                 lalr: bool = True, max_accept: int | None = None):
        self.grammar = grammar
        self.table = table or build_lr_table(grammar, lalr=lalr)
        self.ignores = set(grammar.ignores)
        self.parse_terminal_list = list(grammar.parse_terminals)
        self.max_accept = max_accept
        # incremental cache: token keys + stack snapshots (tuples)
        self._cache_keys: list[tuple] = []
        self._cache_stacks: list[tuple] = [(self.table.start_state,)]

    # ---------------- LR machinery ----------------

    def _shift(self, stack: list, term: str) -> bool:
        """Perform reduces until `term` can be shifted; mutate stack.
        Returns False (stack possibly dirty) if `term` is not acceptable."""
        action = self.table.action
        goto = self.table.goto
        prods = self.table.productions
        while True:
            ent = action[stack[-1]].get(term)
            if ent is None:
                return False
            op = ent[0]
            if op == "s":
                stack.append(ent[1])
                return True
            if op == "acc":
                return True
            # reduce
            prod = prods[ent[1]]
            if len(prod.rhs):
                del stack[-len(prod.rhs):]
            nxt = goto[stack[-1]].get(prod.lhs)
            if nxt is None:
                return False
            stack.append(nxt)

    def _can_shift(self, stack: tuple, term: str) -> bool:
        if not self.table.lalr:
            # canonical LR(1): immediate error detection — table presence
            # is exact.
            return term in self.table.action[stack[-1]]
        s = list(stack)
        return self._shift(s, term)

    def accept_terminals(self, stack: tuple) -> list[str]:
        """A(stack): acceptable next terminals (paper's immediate-error-
        detection accept set), excluding END."""
        if not self.table.lalr:
            return [t for t in self.table.action[stack[-1]]
                    if t != END]
        return [t for t in self.parse_terminal_list
                if self._can_shift(stack, t)]

    def _end_acceptable(self, stack: tuple) -> bool:
        return self._can_shift(stack, END)

    # ---------------- incremental prefix parsing ----------------

    def _parse_tokens(self, toks: list[LexToken]) -> tuple:
        """Parse non-ignored tokens, using/updating the prefix cache.
        Returns the final stack (tuple)."""
        keys = [(t.type, t.value) for t in toks]
        cp = 0
        maxcp = min(len(keys), len(self._cache_keys))
        while cp < maxcp and self._cache_keys[cp] == keys[cp]:
            cp += 1
        # truncate stale cache
        del self._cache_keys[cp:]
        del self._cache_stacks[cp + 1:]
        stack = list(self._cache_stacks[cp])
        for i in range(cp, len(keys)):
            t = toks[i]
            if not self._shift(stack, t.type):
                raise ParseError(
                    f"unexpected {t.type} ({t.value!r}) at byte {t.pos}")
            self._cache_keys.append(keys[i])
            self._cache_stacks.append(tuple(stack))
        return tuple(stack)

    def parse_from_scratch_stack(self, toks: list[LexToken]) -> tuple:
        stack = [self.table.start_state]
        for t in toks:
            if not self._shift(stack, t.type):
                raise ParseError(
                    f"unexpected {t.type} ({t.value!r}) at byte {t.pos}")
        return tuple(stack)

    def reset_cache(self):
        self._cache_keys = []
        self._cache_stacks = [(self.table.start_state,)]

    # ---------------- the paper's partial parse ----------------

    def partial_parse(self, data: bytes, incremental: bool = True) -> ParseResult:
        toks, unlexed = lex_partial(self.grammar, data)
        if self.grammar.indent_spec is not None:
            return self._partial_parse_indent(toks, unlexed, incremental)
        ignores = self.ignores

        if unlexed:
            # Case 2: unlexed suffix u — parse ALL lexed tokens, 1-length
            # sequences from the accept set.
            parse_toks = [t for t in toks if t.type not in ignores]
            stack = (self._parse_tokens(parse_toks) if incremental
                     else self.parse_from_scratch_stack(parse_toks))
            a1 = self.accept_terminals(stack)
            seqs = [(t,) for t in a1]
            seqs += [(ig,) for ig in self.grammar.ignores]
            return ParseResult(self._cap(seqs), unlexed, eos_allowed=False,
                               tokens=toks, case=2)

        # Case 1: input ends at a complete lexical token l_f (possibly none)
        if not toks:
            stack = (self._parse_tokens([]) if incremental
                     else self.parse_from_scratch_stack([]))
            a0 = self.accept_terminals(stack)
            seqs = [(t,) for t in a0] + [(ig,) for ig in self.grammar.ignores]
            return ParseResult(self._cap(seqs), b"",
                               eos_allowed=self._end_acceptable(stack),
                               tokens=toks, case=1)

        lf = toks[-1]
        head = toks[:-1]
        parse_head = [t for t in head if t.type not in ignores]
        stack0 = (self._parse_tokens(parse_head) if incremental
                  else self.parse_from_scratch_stack(parse_head))
        a0 = self.accept_terminals(stack0)

        shifted = True
        if lf.type in ignores:
            eos = self._end_acceptable(stack0)
            a1 = a0
        else:
            s = list(stack0)
            if self._shift(s, lf.type):
                stack1 = tuple(s)
                eos = self._end_acceptable(stack1)
                a1 = self.accept_terminals(stack1)
            else:
                # l_f's current type is not acceptable here — but the token
                # may still grow into an acceptable terminal (e.g. "!" ->
                # "!=", identifier prefix -> keyword). Only the 1-length
                # A0 sequences apply (paper §4.5 Case 1).
                shifted = False
                eos = False
                a1 = []
                if not a0:
                    raise ParseError(
                        f"unexpected {lf.type} ({lf.value!r}) at byte "
                        f"{lf.pos}: no acceptable terminals")

        seqs = []
        if shifted:
            seqs += [(lf.type, t1) for t1 in a1]
            seqs += [(lf.type, ig) for ig in self.grammar.ignores]
        seqs += [(t0,) for t0 in a0 if t0 != lf.type]
        return ParseResult(self._cap(seqs), lf.value, eos_allowed=eos,
                           tokens=toks, case=1)

    # ---------------- indent-aware partial parse (%indent grammars) -------

    def _indent_eof_ok(self, stack: tuple, levels: tuple, paren: int,
                       has_content: bool) -> bool:
        """EOF closure: the last logical line needs no trailing newline
        byte — emit an implicit NEWLINE (when any content exists), then
        one DEDENT per open level, then END must be shiftable."""
        if paren > 0:
            return False
        nl_t, _ind_t, ded_t = self.grammar.indent_spec
        s = list(stack)
        if has_content and not self._shift(s, nl_t):
            return False
        for _ in range(len(levels) - 1):
            if not self._shift(s, ded_t):
                return False
        return self._can_shift(tuple(s), END)

    def _partial_parse_indent(self, toks: list, unlexed: bytes,
                              incremental: bool) -> ParseResult:
        g = self.grammar
        nl_t, ind_t, ded_t = g.indent_spec
        synth = g.synthetic_terminals
        res = postlex_indent(g, toks, unlexed)
        parse_all = [t for t in res.tokens if t.type not in self.ignores]

        def accepts(stack: tuple) -> list:
            # INDENT/DEDENT are zero-width — they never head an accept
            # sequence (no byte can lex into them); the pending-NEWLINE
            # branch expansion below accounts for them instead.
            return [t for t in self.accept_terminals(stack)
                    if t not in synth]

        def parse(ts):
            return (self._parse_tokens(ts) if incremental
                    else self.parse_from_scratch_stack(ts))

        if unlexed:
            # Case 2: everything lexed is committed (new bytes extend the
            # unlexed suffix, never a committed token).
            stack = parse(parse_all)
            seqs = [(t,) for t in accepts(stack)]
            seqs += [(ig,) for ig in g.ignores]
            return ParseResult(self._cap(seqs), unlexed, eos_allowed=False,
                               tokens=toks, case=2)

        if res.pending is not None:
            # Trailing NEWLINE with its indent level still open: the next
            # line may land on the current level, one deeper (INDENT), or
            # any enclosing one (DEDENT+) — and more newline/comment bytes
            # may extend the lexeme first. Union the accept sets over all
            # reachable branches; the exact oracle re-checks on commit.
            stack0 = parse(parse_all)
            has = any(t.type not in synth for t in parse_all)
            if has:
                s = list(stack0)
                if not self._shift(s, nl_t):
                    raise ParseError(
                        f"unexpected {nl_t} at byte {res.pending.pos}")
                s1 = tuple(s)
            else:
                s1 = stack0     # leading blank/comment lines: no NEWLINE
            branch = list(accepts(s1))
            s = list(s1)
            if self._shift(s, ind_t):
                branch += accepts(tuple(s))
            s = list(s1)
            for _ in range(len(res.levels) - 1):
                if not self._shift(s, ded_t):
                    break
                branch += accepts(tuple(s))
            seqs = [(nl_t, t1) for t1 in dict.fromkeys(branch)]
            seqs += [(nl_t, ig) for ig in g.ignores]
            eos = self._indent_eof_ok(stack0, res.levels, res.paren, has)
            return ParseResult(self._cap(seqs), res.pending.value,
                               eos_allowed=eos, tokens=toks, case=1)

        if toks and toks[-1].type == nl_t and res.paren > 0:
            # Trailing NEWLINE inside brackets: dropped from the parse
            # (implicit line joining) but still the lexical remainder.
            stack0 = parse(parse_all)
            seqs = [(nl_t, t1) for t1 in accepts(stack0)]
            seqs += [(nl_t, ig) for ig in g.ignores]
            return ParseResult(self._cap(seqs), toks[-1].value,
                               eos_allowed=False, tokens=toks, case=1)

        if not toks:
            stack = parse([])
            a0 = accepts(stack)
            seqs = [(t,) for t in a0] + [(ig,) for ig in g.ignores]
            return ParseResult(self._cap(seqs), b"",
                               eos_allowed=self._can_shift(stack, END),
                               tokens=toks, case=1)

        # Case 1 with a real (or ignored) final token: identical to the
        # flat-grammar path, except the head went through the post-lexer
        # and EOS uses the EOF closure.
        lf = toks[-1]
        head_parse = [t for t in res.tokens[:-1] if t.type not in self.ignores]
        stack0 = parse(head_parse)
        a0 = accepts(stack0)
        has_head = any(t.type not in synth for t in head_parse)

        shifted = True
        if lf.type in self.ignores:
            eos = self._indent_eof_ok(stack0, res.levels, res.paren, has_head)
            a1 = a0
        else:
            s = list(stack0)
            if self._shift(s, lf.type):
                stack1 = tuple(s)
                eos = self._indent_eof_ok(stack1, res.levels, res.paren, True)
                a1 = accepts(stack1)
            else:
                shifted = False
                eos = False
                a1 = []
                if not a0:
                    raise ParseError(
                        f"unexpected {lf.type} ({lf.value!r}) at byte "
                        f"{lf.pos}: no acceptable terminals")

        seqs = []
        if shifted:
            seqs += [(lf.type, t1) for t1 in a1]
            seqs += [(lf.type, ig) for ig in g.ignores]
        seqs += [(t0,) for t0 in a0 if t0 != lf.type]
        return ParseResult(self._cap(seqs), lf.value, eos_allowed=eos,
                           tokens=toks, case=1)

    def _cap(self, seqs):
        # dedupe, keep order
        seen = set()
        out = []
        for s in seqs:
            if s not in seen:
                seen.add(s)
                out.append(s)
        if self.max_accept is not None:
            out = out[: self.max_accept]
        return out

    # ---------------- whole-string recognition (for tests/benchmarks) ----

    def recognize(self, data: bytes) -> bool:
        """C ∈ L(G)?"""
        try:
            toks, unlexed = lex_partial(self.grammar, data)
        except LexError:
            return False
        if unlexed:
            return False
        if self.grammar.indent_spec is not None:
            try:
                res = postlex_indent(self.grammar, toks, b"", at_eof=True)
            except LexError:
                return False
            if res.paren > 0:
                return False
            toks = res.tokens
        parse_toks = [t for t in toks if t.type not in self.ignores]
        stack = [self.table.start_state]
        for t in parse_toks:
            if not self._shift(stack, t.type):
                return False
        return self._can_shift(tuple(stack), END)
