"""Canonical LR(1) / LALR(1) parser-table generator (paper §4.5).

The paper uses LR(1) tables because of the immediate-error-detection
property: `action[state, τ]` being present iff τ is an acceptable next
terminal, which gives O(|Γ|) accept-set computation. We build canonical
LR(1) item sets and optionally merge same-core states (LALR). With LALR
merging, reduce entries may exist for unacceptable terminals, so the
accept-set computation falls back to shift-simulation (also implemented,
in parser.py).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass

from .grammar import END, Grammar, Production

ACCEPT_PROD = "$accept"


@dataclass
class LRTable:
    grammar: Grammar
    productions: list            # augmented (prod 0 = $accept -> start $END-implicit)
    action: list                 # state -> dict[term] -> ('s', st)|('r', prodidx)|('acc',)
    goto: list                   # state -> dict[nt] -> state
    start_state: int = 0
    lalr: bool = True

    @property
    def num_states(self):
        return len(self.action)


class LRConflict(ValueError):
    pass


def _compute_first(prods, nonterminals):
    first = {nt: set() for nt in nonterminals}
    nullable = set()
    changed = True
    while changed:
        changed = False
        for p in prods:
            if p.lhs == ACCEPT_PROD:
                tgt = first.setdefault(p.lhs, set())
            else:
                tgt = first[p.lhs]
            n = len(tgt)
            was_nullable = p.lhs in nullable
            all_null = True
            for sym in p.rhs:
                if sym in nonterminals or sym == ACCEPT_PROD:
                    tgt |= first.get(sym, set())
                    if sym not in nullable:
                        all_null = False
                        break
                else:
                    tgt.add(sym)
                    all_null = False
                    break
            if all_null and not was_nullable:
                nullable.add(p.lhs)
                changed = True
            if len(tgt) != n:
                changed = True
    return first, nullable


def build_lr_table(grammar: Grammar, lalr: bool = True) -> LRTable:
    prods = [Production(ACCEPT_PROD, (grammar.start,), 0)]
    for p in grammar.productions:
        prods.append(Production(p.lhs, p.rhs, len(prods)))
    nonterminals = set(grammar.nonterminals) | {ACCEPT_PROD}
    by_lhs = collections.defaultdict(list)
    for p in prods:
        by_lhs[p.lhs].append(p.idx)
    first, nullable = _compute_first(prods, nonterminals)

    def first_of_seq(seq, la):
        out = set()
        for sym in seq:
            if sym in nonterminals:
                out |= first.get(sym, set())
                if sym not in nullable:
                    return out
            else:
                out.add(sym)
                return out
        out.add(la)
        return out

    # item = (prod_idx, dot, lookahead)
    def closure(items: frozenset) -> frozenset:
        out = set(items)
        stack = list(items)
        while stack:
            (pi, d, la) = stack.pop()
            rhs = prods[pi].rhs
            if d < len(rhs) and rhs[d] in nonterminals:
                B = rhs[d]
                las = first_of_seq(rhs[d + 1:], la)
                for qi in by_lhs[B]:
                    for b in las:
                        it = (qi, 0, b)
                        if it not in out:
                            out.add(it)
                            stack.append(it)
        return frozenset(out)

    def goto_set(items: frozenset, X: str) -> frozenset:
        nxt = set()
        for (pi, d, la) in items:
            rhs = prods[pi].rhs
            if d < len(rhs) and rhs[d] == X:
                nxt.add((pi, d + 1, la))
        return closure(frozenset(nxt)) if nxt else frozenset()

    start = closure(frozenset({(0, 0, END)}))
    states = {start: 0}
    order = [start]
    trans: list[dict] = [dict()]
    queue = collections.deque([start])
    while queue:
        st = queue.popleft()
        sid = states[st]
        symbols = set()
        for (pi, d, la) in st:
            rhs = prods[pi].rhs
            if d < len(rhs):
                symbols.add(rhs[d])
        for X in symbols:
            tgt = goto_set(st, X)
            if tgt not in states:
                states[tgt] = len(order)
                order.append(tgt)
                trans.append(dict())
                queue.append(tgt)
            trans[sid][X] = states[tgt]

    if lalr:
        # merge states with identical cores
        core_of = {}
        merged_id = {}
        merged_items: list[set] = []
        for i, st in enumerate(order):
            core = frozenset((pi, d) for (pi, d, la) in st)
            if core not in core_of:
                core_of[core] = len(merged_items)
                merged_items.append(set(st))
            else:
                merged_items[core_of[core]].update(st)
            merged_id[i] = core_of[core]
        new_trans = [dict() for _ in merged_items]
        for i, tr in enumerate(trans):
            for X, j in tr.items():
                new_trans[merged_id[i]][X] = merged_id[j]
        order = [frozenset(s) for s in merged_items]
        trans = new_trans
        start_state = merged_id[0]
    else:
        start_state = 0

    action: list[dict] = [dict() for _ in order]
    goto: list[dict] = [dict() for _ in order]
    conflicts = []
    for sid, st in enumerate(order):
        for X, j in trans[sid].items():
            if X in nonterminals:
                goto[sid][X] = j
            else:
                action[sid][X] = ("s", j)
        for (pi, d, la) in st:
            rhs = prods[pi].rhs
            if d == len(rhs):
                if pi == 0:
                    action[sid][END] = ("acc",)
                    continue
                prev = action[sid].get(la)
                ent = ("r", pi)
                if prev is None:
                    action[sid][la] = ent
                elif prev != ent:
                    if prev[0] == "s":
                        # shift/reduce: prefer shift (matches Lark/yacc default)
                        conflicts.append((sid, la, prev, ent, "sr"))
                    else:
                        conflicts.append((sid, la, prev, ent, "rr"))
                        # deterministic: keep lowest production index
                        if ent[1] < prev[1]:
                            action[sid][la] = ent
    rr = [c for c in conflicts if c[4] == "rr"]
    if rr:
        msgs = []
        for sid, la, prev, ent, _ in rr[:5]:
            msgs.append(f"state {sid} on {la}: {prev} vs {ent} "
                        f"({prods[prev[1]]}) vs ({prods[ent[1]]})")
        raise LRConflict(f"{len(rr)} reduce/reduce conflicts:\n" + "\n".join(msgs))

    return LRTable(grammar=grammar, productions=prods, action=action,
                   goto=goto, start_state=start_state, lalr=lalr)
