"""Decoding algorithms, composable with grammar masks (paper §2.1 / §3.2:
"any algorithm that could be applied to V can instead be applied to V_k").

All selectors operate on a (possibly masked) logits vector. Masking is
`logits + log(mask)` i.e. -inf outside the mask — applied *before* the
selector, so greedy / temperature / top-k / top-p / beam all compose
unchanged (the paper's generality claim).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def apply_bool_mask(logits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """logits [..., V], mask [..., V] bool -> masked logits."""
    return jnp.where(mask, logits, NEG_INF)


def unpack_mask_words(packed: jnp.ndarray, vocab_size: int) -> jnp.ndarray:
    """packed [..., W] uint32 -> bool [..., W*32][:vocab] (little-endian)."""
    bits = jnp.arange(32, dtype=jnp.uint32)
    unpacked = (packed[..., :, None] >> bits) & jnp.uint32(1)
    out = unpacked.reshape(*packed.shape[:-1], -1)
    return out[..., :vocab_size].astype(bool)


def union_packed_rows(store: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """store [R, W] uint32, rows [..., A] int32 (-1 pad) -> [..., W] uint32.
    Pure-jnp reference for the Pallas masked_logits kernel."""
    safe = jnp.maximum(rows, 0)
    gathered = store[safe]                                  # [..., A, W]
    valid = (rows >= 0)[..., None]
    gathered = jnp.where(valid, gathered, jnp.uint32(0))
    return jax.lax.reduce(gathered, jnp.uint32(0),
                          jnp.bitwise_or, dimensions=(gathered.ndim - 2,))


# ---------------------------- selectors -----------------------------------

def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1)


def topk_topp_filter(scaled: jnp.ndarray, top_k: jnp.ndarray,
                     top_p: jnp.ndarray) -> jnp.ndarray:
    """Support filter shared by the scalar sampler (`sample`) and the
    batched selector (`select_batch`): ONE implementation, so the two
    paths keep IDENTICAL kept-token sets by construction (parity is
    fuzz-tested in tests/test_decoding.py).

    scaled [..., V] temperature-scaled logits; top_k [...] int32 (<= 0
    disables); top_p [...] f32 (>= 1.0 disables). Boundary semantics:

      * top-k keeps ties with the k-th largest logit (strictly-below
        demotion), so the kept set can exceed k;
      * top-p keeps tokens while the sorted cumulative probability is
        < top_p, PLUS the first token at/over the boundary
        (inclusive-first-over), plus any tie with that cutoff logit;
      * top_p >= 1.0 disables the nucleus filter EXACTLY. (The scalar
        sampler used to apply `cum < 1.0` literally, where float
        round-off in the cumsum could truncate low-probability tail
        tokens the batched selector kept — the boundary-semantics
        mismatch this shared filter removes.)
    """
    V = scaled.shape[-1]
    # top-k: demote everything strictly below the k-th largest
    kidx = jnp.clip(jnp.where(top_k > 0, top_k, V), 1, V) - 1
    sorted_desc = -jnp.sort(-scaled, axis=-1)
    kth = jnp.take_along_axis(sorted_desc, kidx[..., None], axis=-1)
    scaled = jnp.where(scaled < kth, NEG_INF, scaled)
    # top-p (nucleus) over the top-k-filtered rows
    sorted_desc = -jnp.sort(-scaled, axis=-1)
    cum = jnp.cumsum(jax.nn.softmax(sorted_desc, axis=-1), axis=-1)
    p = jnp.where(top_p < 1.0, top_p, 2.0)[..., None]
    cutoff_idx = jnp.minimum(jnp.sum(cum < p, axis=-1, keepdims=True), V - 1)
    cutoff = jnp.take_along_axis(sorted_desc, cutoff_idx, axis=-1)
    return jnp.where(scaled < cutoff, NEG_INF, scaled)


def sample(logits: jnp.ndarray, key: jax.Array, temperature: float = 1.0,
           top_k: Optional[int] = None, top_p: Optional[float] = None
           ) -> jnp.ndarray:
    """Temperature / top-k / top-p sampling over the last axis.

    The support set is `topk_topp_filter` — the same filter the batched
    `select_batch` applies — so scalar and batched sampling draw from
    identical candidate sets for identical configs."""
    scaled = logits / jnp.maximum(temperature, 1e-6)
    if top_k is not None or top_p is not None:
        # both None is the common plain-temperature case: the filter is
        # a mathematical no-op there, and the Optionals are static at
        # trace time, so skip its two O(V log V) sorts entirely
        lead = logits.shape[:-1]
        scaled = topk_topp_filter(
            scaled,
            jnp.full(lead, 0 if top_k is None else top_k, jnp.int32),
            jnp.full(lead, 1.0 if top_p is None else top_p, jnp.float32))
    return jax.random.categorical(key, scaled, axis=-1)


def select_batch(logits: jnp.ndarray, keys: jnp.ndarray,
                 greedy_flags: jnp.ndarray, temperature: jnp.ndarray,
                 top_k: jnp.ndarray, top_p: jnp.ndarray) -> jnp.ndarray:
    """Per-row token selection over a whole decode batch in one fused call.

    The batched engine's device-side selector: each slot carries its own
    decode config, vectorized as arrays (the per-request generality of
    `DecodeConfig.select`, without B separate device calls):

      logits       [B, V]  (already grammar-masked where applicable)
      keys         [B, 2]  uint32 PRNG keys (one stream per slot)
      greedy_flags [B]     bool — row ignores sampling params, takes argmax
      temperature  [B]     f32
      top_k        [B]     int32, <= 0 disables
      top_p        [B]     f32, >= 1.0 disables

    Returns [B] int32 sampled ids.

    Sharded serving: under `use_sharding` with the serving rules the
    incoming logits are vocab-sharded; the "sample_logits" hint below
    is the hot path's single combine — one all-gather of the masked
    [B, V] back to replicated right before the sort/cumsum/categorical
    machinery, whose partitioned forms are not bit-exact. (The greedy
    argmax alone would partition exactly, but sampled rows force the
    gather anyway and only [B] ids ever reach the host.)
    """
    from repro.distributed.api import shard_hint
    logits = shard_hint(logits, "sample_logits")
    B, V = logits.shape
    scaled = logits / jnp.maximum(temperature, 1e-6)[:, None]
    scaled = topk_topp_filter(scaled, top_k, top_p)
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(greedy_flags, jnp.argmax(logits, axis=-1),
                     sampled).astype(jnp.int32)


def select_span(logits: jnp.ndarray, keys: jnp.ndarray,
                greedy_flags: jnp.ndarray, temperature: jnp.ndarray,
                top_k: jnp.ndarray, top_p: jnp.ndarray) -> jnp.ndarray:
    """Span form of `select_batch` for speculative verification.

    logits [B, S, V] (already grammar-masked per position), keys
    [B, S, 2] — one PRNG stream per (slot, span position); the per-slot
    decode configs broadcast across the span. Returns [B, S] int32: a
    selection at EVERY span position, so the draft-accept test is a
    single host-side comparison against the proposed tokens.
    """
    B, S, V = logits.shape
    rep = lambda a: jnp.repeat(a, S, axis=0)
    ids = select_batch(logits.reshape(B * S, V), keys.reshape(B * S, 2),
                       rep(greedy_flags), rep(temperature), rep(top_k),
                       rep(top_p))
    return ids.reshape(B, S)


@dataclass
class DecodeConfig:
    method: str = "greedy"            # greedy | sample
    temperature: float = 1.0
    top_k: Optional[int] = None
    top_p: Optional[float] = None

    def select(self, logits: jnp.ndarray, key: Optional[jax.Array] = None
               ) -> jnp.ndarray:
        if self.method == "greedy":
            return greedy(logits)
        if self.method == "sample":
            assert key is not None
            return sample(logits, key, self.temperature, self.top_k,
                          self.top_p)
        raise ValueError(self.method)

    @staticmethod
    def batch_arrays(configs: list["DecodeConfig"]):
        """Stack per-slot configs into `select_batch` parameter arrays
        (greedy [B] bool, temperature [B] f32, top_k [B] i32, top_p [B] f32)."""
        for c in configs:
            if c.method not in ("greedy", "sample"):
                raise ValueError(c.method)
        return (np.array([c.method == "greedy" for c in configs], bool),
                np.array([c.temperature for c in configs], np.float32),
                np.array([c.top_k or 0 for c in configs], np.int32),
                np.array([1.0 if c.top_p is None else c.top_p
                          for c in configs], np.float32))


# ------------------------- host-level beam search --------------------------

def beam_search(step_fn: Callable, init_state, beam_width: int,
                max_steps: int, eos_id: int):
    """Generic host-driven beam search.

    step_fn(state, token_history) -> (log_probs over V [np], new_state).
    The grammar mask composes by step_fn masking its log_probs — beam is
    just another selector over V_k (paper generality).
    Returns list of (tokens, score) best-first.
    """
    beams = [([], 0.0, init_state, False)]
    for _ in range(max_steps):
        if all(done for (_, _, _, done) in beams):
            break
        cand = []
        for toks, score, state, done in beams:
            if done:
                cand.append((toks, score, state, True))
                continue
            logp, new_state = step_fn(state, toks)
            top = np.argsort(logp)[::-1][:beam_width]
            for t in top:
                if not np.isfinite(logp[t]):
                    continue
                cand.append((toks + [int(t)], score + float(logp[t]),
                             new_state, int(t) == eos_id))
        if not cand:
            break
        cand.sort(key=lambda c: c[1], reverse=True)
        beams = cand[:beam_width]
    return [(toks, score) for toks, score, _, done in beams]
