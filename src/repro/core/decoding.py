"""Decoding algorithms, composable with grammar masks (paper §2.1 / §3.2:
"any algorithm that could be applied to V can instead be applied to V_k").

All selectors operate on a (possibly masked) logits vector. Masking is
`logits + log(mask)` i.e. -inf outside the mask — applied *before* the
selector, so greedy / temperature / top-k / top-p / beam all compose
unchanged (the paper's generality claim).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def apply_bool_mask(logits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """logits [..., V], mask [..., V] bool -> masked logits."""
    return jnp.where(mask, logits, NEG_INF)


def unpack_mask_words(packed: jnp.ndarray, vocab_size: int) -> jnp.ndarray:
    """packed [..., W] uint32 -> bool [..., W*32][:vocab] (little-endian)."""
    bits = jnp.arange(32, dtype=jnp.uint32)
    unpacked = (packed[..., :, None] >> bits) & jnp.uint32(1)
    out = unpacked.reshape(*packed.shape[:-1], -1)
    return out[..., :vocab_size].astype(bool)


def union_packed_rows(store: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """store [R, W] uint32, rows [..., A] int32 (-1 pad) -> [..., W] uint32.
    Pure-jnp reference for the Pallas masked_logits kernel."""
    safe = jnp.maximum(rows, 0)
    gathered = store[safe]                                  # [..., A, W]
    valid = (rows >= 0)[..., None]
    gathered = jnp.where(valid, gathered, jnp.uint32(0))
    return jax.lax.reduce(gathered, jnp.uint32(0),
                          jnp.bitwise_or, dimensions=(gathered.ndim - 2,))


# ---------------------------- selectors -----------------------------------

def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1)


def sample(logits: jnp.ndarray, key: jax.Array, temperature: float = 1.0,
           top_k: Optional[int] = None, top_p: Optional[float] = None
           ) -> jnp.ndarray:
    """Temperature / top-k / top-p sampling over the last axis."""
    logits = logits / jnp.maximum(temperature, 1e-6)
    if top_k is not None:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, NEG_INF, logits)
    if top_p is not None:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep tokens until cumulative prob exceeds top_p (incl. first over)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, NEG_INF, logits)
    return jax.random.categorical(key, logits, axis=-1)


@dataclass
class DecodeConfig:
    method: str = "greedy"            # greedy | sample
    temperature: float = 1.0
    top_k: Optional[int] = None
    top_p: Optional[float] = None

    def select(self, logits: jnp.ndarray, key: Optional[jax.Array] = None
               ) -> jnp.ndarray:
        if self.method == "greedy":
            return greedy(logits)
        if self.method == "sample":
            assert key is not None
            return sample(logits, key, self.temperature, self.top_k,
                          self.top_p)
        raise ValueError(self.method)


# ------------------------- host-level beam search --------------------------

def beam_search(step_fn: Callable, init_state, beam_width: int,
                max_steps: int, eos_id: int):
    """Generic host-driven beam search.

    step_fn(state, token_history) -> (log_probs over V [np], new_state).
    The grammar mask composes by step_fn masking its log_probs — beam is
    just another selector over V_k (paper generality).
    Returns list of (tokens, score) best-first.
    """
    beams = [([], 0.0, init_state, False)]
    for _ in range(max_steps):
        if all(done for (_, _, _, done) in beams):
            break
        cand = []
        for toks, score, state, done in beams:
            if done:
                cand.append((toks, score, state, True))
                continue
            logp, new_state = step_fn(state, toks)
            top = np.argsort(logp)[::-1][:beam_width]
            for t in top:
                if not np.isfinite(logp[t]):
                    continue
                cand.append((toks + [int(t)], score + float(logp[t]),
                             new_state, int(t) == eos_id))
        if not cand:
            break
        cand.sort(key=lambda c: c[1], reverse=True)
        beams = cand[:beam_width]
    return [(toks, score) for toks, score, _, done in beams]
