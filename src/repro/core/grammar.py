"""Lark-flavoured EBNF grammar frontend.

Parses grammar text like the paper's Figure 3 / Appendix A.8 into:
  * a set of named terminals, each compiled to a byte-level DFA,
  * BNF productions (EBNF sugar ``[]``, ``()``, ``*``, ``+``, ``?`` expanded
    into helper nonterminals),
  * an ``%ignore`` list (whitespace/comments),
  * a combined lexer DFA with tagged finals for maximal-munch lexing.

Supported surface syntax (subset of Lark):
  rule_name: item* ("|" item*)* ("->" alias)?
  TERMINAL(.prio)?: <terminal expression over strings/regexes/terminal refs>
  "literal"  "literal"i  /regex/  [optional]  (group)  x* x+ x?
  %ignore TERMINAL | "lit" | /re/
  %declare NAME (accepted, declared terminals get an impossible-match DFA
                 unless defined elsewhere)
  %indent NEWLINE_TERM INDENT_TERM DEDENT_TERM (opt into the layout-
                 sensitive post-lex pass in core/lexer.py: the named
                 NEWLINE terminal must have a lexer definition; the
                 INDENT/DEDENT terminals are auto-%declare'd and are
                 synthesized, never lexed)
  // comments
"""
from __future__ import annotations

import re as _pyre
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .regex import (
    DFA, RAlt, RChars, RConcat, REpsilon, RNode, ROpt, RPlus, RStar,
    compile_regex, dfa_from_nfa, literal_regex, minimize, nfa_from_ast,
    parse_regex, NFA, _build,
)

END = "$END"  # end-of-input terminal for the LR parser


@dataclass
class Terminal:
    name: str
    ast: RNode
    priority: int = 0
    from_literal: bool = False     # literal terminals win lexer ties
    dfa: Optional[DFA] = None

    def compile(self):
        if self.dfa is None:
            self.dfa = minimize(dfa_from_nfa(nfa_from_ast(self.ast)))
        return self.dfa


@dataclass(frozen=True)
class Production:
    lhs: str
    rhs: tuple  # tuple[str] symbol names; terminals are uppercase/__ANON
    idx: int = -1


class GrammarError(ValueError):
    pass


# --------------------------------------------------------------------------
# Meta-tokenizer for grammar text
# --------------------------------------------------------------------------

_TOKEN_RE = _pyre.compile(
    r"""
      (?P<WS>[ \t]+)
    | (?P<COMMENT>//[^\n]*)
    | (?P<STRING>"(?:\\.|[^"\\])*"i?)
    | (?P<REGEX>/(?:\\.|[^/\\\n])+/[imslux]*)
    | (?P<ARROW>->)
    | (?P<NAME>[?!]?[A-Za-z_][A-Za-z0-9_]*(\.\d+)?)
    | (?P<OP>[:|()\[\]*+?~])
    | (?P<NL>\n)
    | (?P<PCT>%[a-z]+)
    """,
    _pyre.VERBOSE,
)


def _tokenize_meta(text: str):
    text = text.replace("\\\n", " ")  # line continuation
    toks = []
    i = 0
    while i < len(text):
        m = _TOKEN_RE.match(text, i)
        if not m:
            raise GrammarError(f"bad grammar char {text[i]!r} at offset {i}")
        i = m.end()
        kind = m.lastgroup
        if kind in ("WS", "COMMENT"):
            continue
        toks.append((kind, m.group()))
    toks.append(("EOF", ""))
    return toks


def _unescape_string(tok: str) -> tuple[bytes, bool]:
    """'"abc"i?' -> (b'abc', ignore_case)"""
    icase = tok.endswith("i")
    if icase:
        tok = tok[:-1]
    body = tok[1:-1]
    out = []
    i = 0
    while i < len(body):
        c = body[i]
        if c == "\\" and i + 1 < len(body):
            n = body[i + 1]
            mapping = {"n": "\n", "t": "\t", "r": "\r", "f": "\f", "\\": "\\",
                       '"': '"', "'": "'", "/": "/", "0": "\0"}
            out.append(mapping.get(n, n))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out).encode(), icase


def _regex_body(tok: str) -> tuple[str, bool]:
    """'/re/flags' -> (pattern, ignore_case)."""
    end = tok.rfind("/")
    flags = tok[end + 1:]
    return tok[1:end], "i" in flags


# --------------------------------------------------------------------------
# Grammar parser (recursive descent over meta tokens)
# --------------------------------------------------------------------------

class _Expansion:
    """One alternative of a rule body: a list of items."""
    def __init__(self, items):
        self.items = items  # list of _Item


class _Item:
    def __init__(self, atom, quant=None):
        self.atom = atom    # ('str', bytes, icase)|('re', pat, icase)|('name', n)|('group', [_Expansion])|('opt', [_Expansion])
        self.quant = quant  # None | '*' | '+' | '?'


class _DefParser:
    def __init__(self, toks, pos):
        self.toks = toks
        self.pos = pos

    def peek(self):
        return self.toks[self.pos]

    def next(self):
        t = self.toks[self.pos]
        self.pos += 1
        return t

    def skip_nl(self):
        while self.peek()[0] == "NL":
            self.next()

    def at_def_boundary(self) -> bool:
        """True if current position starts a new definition (NAME ':' or %...)."""
        k, v = self.peek()
        if k == "EOF":
            return True
        if k == "PCT":
            return True
        if k == "NAME":
            j = self.pos + 1
            if j < len(self.toks) and self.toks[j] == ("OP", ":"):
                return True
        return False

    def parse_alts(self, stop_at_newline_boundary=True):
        alts = [self.parse_seq()]
        while True:
            # skip newlines, but stop if a new definition begins
            save = self.pos
            self.skip_nl()
            if self.peek() == ("OP", "|"):
                self.next()
                alts.append(self.parse_seq())
            else:
                self.pos = save
                break
        return alts

    def parse_seq(self) -> _Expansion:
        items = []
        while True:
            k, v = self.peek()
            if k in ("EOF", "NL") or (k == "OP" and v in ("|", ")", "]")):
                break
            if k == "ARROW":
                self.next()
                self.next()  # alias name, discarded (tree shaping irrelevant)
                break
            items.append(self.parse_item())
        return _Expansion(items)

    def parse_item(self) -> _Item:
        atom = self.parse_atom()
        quant = None
        k, v = self.peek()
        if k == "OP" and v in ("*", "+", "?"):
            self.next()
            quant = v
        return _Item(atom, quant)

    def parse_atom(self):
        k, v = self.next()
        if k == "STRING":
            s, icase = _unescape_string(v)
            return ("str", s, icase)
        if k == "REGEX":
            pat, icase = _regex_body(v)
            return ("re", pat, icase)
        if k == "NAME":
            name = v.lstrip("?!")
            if "." in name:
                name = name.split(".")[0]
            return ("name", name)
        if k == "OP" and v == "(":
            self.skip_nl()
            alts = self.parse_alts()
            self.skip_nl()
            nk, nv = self.next()
            if (nk, nv) != ("OP", ")"):
                raise GrammarError(f"expected ')', got {nv!r}")
            return ("group", alts)
        if k == "OP" and v == "[":
            self.skip_nl()
            alts = self.parse_alts()
            self.skip_nl()
            nk, nv = self.next()
            if (nk, nv) != ("OP", "]"):
                raise GrammarError(f"expected ']', got {nv!r}")
            return ("opt", alts)
        if k == "OP" and v == "~":
            # Lark's "up to N" — not needed; treat as error
            raise GrammarError("~ repetition not supported")
        raise GrammarError(f"unexpected token {v!r} in rule body")


# --------------------------------------------------------------------------
# Grammar
# --------------------------------------------------------------------------

_PUNCT_NAMES = {
    "+": "PLUS", "-": "MINUS", "*": "STAR", "/": "SLASH", "(": "LPAR",
    ")": "RPAR", "[": "LSQB", "]": "RSQB", "{": "LBRACE", "}": "RBRACE",
    ",": "COMMA", ":": "COLON", ";": "SEMICOLON", ".": "DOT", "=": "EQUAL",
    "<": "LESSTHAN", ">": "MORETHAN", "!": "BANG", "|": "VBAR", "&": "AMP",
    "%": "PERCENT", "^": "CIRCUMFLEX", "~": "TILDE", "@": "AT", "?": "QMARK",
    '"': "DQUOTE", "'": "QUOTE", "#": "HASH", "$": "DOLLAR", "\\": "BACKSLASH",
}


def _anon_name_for(text: bytes, icase: bool) -> str:
    s = text.decode("utf-8", "replace")
    if _pyre.fullmatch(r"[A-Za-z_][A-Za-z0-9_]*", s):
        base = s.upper()
    else:
        parts = [_PUNCT_NAMES.get(ch, f"C{ord(ch)}") for ch in s]
        base = "_".join(parts) or "EMPTY"
    if icase:
        base += "_I"
    return "__" + base


class Grammar:
    """A compiled grammar: terminals (with DFAs), BNF productions, lexer DFA."""

    def __init__(self, text: str, start: str = "start", name: str = "grammar"):
        self.name = name
        self.start = start
        self.terminals: dict[str, Terminal] = {}
        self.ignores: list[str] = []
        # (newline_term, indent_term, dedent_term) when the grammar is
        # layout-sensitive (%indent); None otherwise.
        self.indent_spec: Optional[tuple[str, str, str]] = None
        self.productions: list[Production] = []
        self.nonterminals: set[str] = set()
        self._helper_counter = 0
        self._term_defs: dict[str, tuple[list, int]] = {}  # name -> (alts, prio)
        self._literal_names: dict[tuple[bytes, bool], str] = {}
        self._parse_text(text)
        self._compile_terminals()
        self._build_lexer_dfa()
        self._index()

    # ---------------- parsing the grammar text ----------------

    def _parse_text(self, text: str):
        toks = _tokenize_meta(text)
        p = _DefParser(toks, 0)
        rule_defs: list[tuple[str, list]] = []
        while True:
            p.skip_nl()
            k, v = p.peek()
            if k == "EOF":
                break
            if k == "PCT":
                p.next()
                if v == "%ignore":
                    atom = p.parse_atom()
                    self.ignores.append(self._atom_terminal_name(atom))
                elif v == "%declare":
                    while p.peek()[0] == "NAME":
                        name = p.next()[1]
                        self._term_defs.setdefault(name, ([], 0))
                elif v == "%indent":
                    names = []
                    while p.peek()[0] == "NAME":
                        names.append(p.next()[1])
                    if len(names) != 3:
                        raise GrammarError(
                            "%indent takes exactly three terminal names: "
                            "NEWLINE INDENT DEDENT")
                    self.indent_spec = tuple(names)
                    # INDENT/DEDENT are synthesized by the post-lex pass;
                    # they participate in parsing but never in lexing.
                    for synth in names[1:]:
                        self._term_defs.setdefault(synth, ([], 0))
                elif v == "%import":
                    # consume rest of line
                    while p.peek()[0] not in ("NL", "EOF"):
                        p.next()
                else:
                    raise GrammarError(f"unknown directive {v}")
                continue
            if k != "NAME":
                raise GrammarError(f"expected definition, got {v!r}")
            name_tok = p.next()[1]
            prio = 0
            name = name_tok.lstrip("?!")
            if "." in name:
                name, ps = name.split(".", 1)
                prio = int(ps)
            colon = p.next()
            if colon != ("OP", ":"):
                raise GrammarError(f"expected ':' after {name}")
            p.skip_nl()
            alts = p.parse_alts()
            if name.isupper():
                self._term_defs[name] = (alts, prio)
            else:
                rule_defs.append((name, alts))

        for name, alts in rule_defs:
            self.nonterminals.add(name)
        for name, alts in rule_defs:
            for exp in alts:
                rhs = []
                for item in exp.items:
                    rhs.append(self._lower_item(item))
                self._add_production(name, tuple(rhs))

        if self.start not in self.nonterminals:
            raise GrammarError(f"no start rule {self.start!r}")
        if self.indent_spec is not None:
            nl_alts, _ = self._term_defs.get(self.indent_spec[0], ([], 0))
            if not nl_alts:
                raise GrammarError(
                    f"%indent newline terminal {self.indent_spec[0]!r} "
                    "has no lexer definition")

    def _atom_terminal_name(self, atom) -> str:
        kind = atom[0]
        if kind == "name":
            return atom[1]
        if kind == "str":
            return self._literal_terminal(atom[1], atom[2])
        if kind == "re":
            name = f"__ANONRE_{len(self._term_defs)}"
            self._term_defs[name] = ([_Expansion([_Item(atom)])], 0)
            return name
        raise GrammarError(f"cannot use {kind} here")

    def _literal_terminal(self, text: bytes, icase: bool) -> str:
        key = (text, icase)
        if key not in self._literal_names:
            name = _anon_name_for(text, icase)
            while name in self._term_defs and self._literal_names.get(key) != name:
                name += "_"
            self._literal_names[key] = name
            self._term_defs[name] = ([_Expansion([_Item(("str", text, icase))])], 0)
        return self._literal_names[key]

    def _fresh_nt(self, tag: str) -> str:
        self._helper_counter += 1
        name = f"__{tag}_{self._helper_counter}"
        self.nonterminals.add(name)
        return name

    def _lower_item(self, item: _Item) -> str:
        """Lower one EBNF item to a single symbol name, creating helper rules."""
        sym = self._lower_atom(item.atom)
        if item.quant is None:
            return sym
        if item.quant == "?":
            nt = self._fresh_nt("opt")
            self._add_production(nt, ())
            self._add_production(nt, (sym,))
            return nt
        if item.quant == "*":
            nt = self._fresh_nt("star")
            self._add_production(nt, ())
            self._add_production(nt, (nt, sym))
            return nt
        if item.quant == "+":
            nt = self._fresh_nt("plus")
            self._add_production(nt, (sym,))
            self._add_production(nt, (nt, sym))
            return nt
        raise GrammarError(item.quant)

    def _lower_atom(self, atom) -> str:
        kind = atom[0]
        if kind == "str":
            return self._literal_terminal(atom[1], atom[2])
        if kind == "re":
            return self._atom_terminal_name(atom)
        if kind == "name":
            return atom[1]
        if kind == "group":
            nt = self._fresh_nt("grp")
            for exp in atom[1]:
                rhs = tuple(self._lower_item(it) for it in exp.items)
                self._add_production(nt, rhs)
            return nt
        if kind == "opt":
            nt = self._fresh_nt("opt")
            self._add_production(nt, ())
            for exp in atom[1]:
                rhs = tuple(self._lower_item(it) for it in exp.items)
                self._add_production(nt, rhs)
            return nt
        raise GrammarError(kind)

    def _add_production(self, lhs: str, rhs: tuple):
        self.nonterminals.add(lhs)
        self.productions.append(Production(lhs, rhs, len(self.productions)))

    # ---------------- terminal compilation ----------------

    def _term_ast(self, name: str, visiting=None) -> RNode:
        visiting = visiting or set()
        if name in visiting:
            raise GrammarError(f"recursive terminal {name}")
        if name not in self._term_defs:
            raise GrammarError(f"undefined terminal {name}")
        alts, _ = self._term_defs[name]
        if not alts:
            # %declare'd with no def: never matches (empty alternation over
            # an impossible char class)
            return RChars(frozenset())
        visiting = visiting | {name}
        opts = []
        for exp in alts:
            parts = [self._item_ast(it, visiting) for it in exp.items]
            if not parts:
                opts.append(REpsilon())
            elif len(parts) == 1:
                opts.append(parts[0])
            else:
                opts.append(RConcat(tuple(parts)))
        return opts[0] if len(opts) == 1 else RAlt(tuple(opts))

    def _item_ast(self, item: _Item, visiting) -> RNode:
        node = self._atom_ast(item.atom, visiting)
        if item.quant == "*":
            node = RStar(node)
        elif item.quant == "+":
            node = RPlus(node)
        elif item.quant == "?":
            node = ROpt(node)
        return node

    def _atom_ast(self, atom, visiting) -> RNode:
        kind = atom[0]
        if kind == "str":
            return literal_regex(atom[1], ignore_case=atom[2])
        if kind == "re":
            return parse_regex(atom[1], ignore_case=atom[2])
        if kind == "name":
            return self._term_ast(atom[1], visiting)
        if kind in ("group",):
            opts = []
            for exp in atom[1]:
                parts = [self._item_ast(it, visiting) for it in exp.items]
                opts.append(parts[0] if len(parts) == 1 else
                            (RConcat(tuple(parts)) if parts else REpsilon()))
            return opts[0] if len(opts) == 1 else RAlt(tuple(opts))
        if kind == "opt":
            return ROpt(self._atom_ast(("group", atom[1]), visiting))
        raise GrammarError(kind)

    def _compile_terminals(self):
        used: set[str] = set()
        for prod in self.productions:
            for sym in prod.rhs:
                if sym not in self.nonterminals:
                    used.add(sym)
        used.update(self.ignores)
        for name in used:
            if name not in self._term_defs:
                raise GrammarError(f"undefined symbol {name}")
        # also compile defined-but-unused named terminals that other terminals
        # reference only indirectly -- they don't need DFAs.
        for name in sorted(used):
            alts, prio = self._term_defs[name]
            is_lit = False
            if len(alts) == 1 and len(alts[0].items) == 1:
                it = alts[0].items[0]
                if it.quant is None and it.atom[0] == "str":
                    is_lit = True
            ast = self._term_ast(name)
            term = Terminal(name, ast, priority=prio, from_literal=is_lit)
            term.compile()
            if not term.dfa.live[term.dfa.start] and alts:
                raise GrammarError(f"terminal {name} matches nothing")
            self.terminals[name] = term

    # ---------------- combined lexer DFA ----------------

    def _build_lexer_dfa(self):
        """Union NFA over all terminals, tagged finals by winning terminal."""
        order = sorted(self.terminals)
        nfa = NFA()
        accept_of: dict[int, str] = {}
        for name in order:
            ast = self.terminals[name].ast
            s = nfa.new_state()
            nfa.add_eps(nfa.start, s)
            e = _build(nfa, ast, s)
            accept_of[e] = name

        # subset construction with tags
        import collections
        n = len(nfa.eps)
        eclo = []
        for s in range(n):
            seen = {s}
            stack = [s]
            while stack:
                x = stack.pop()
                for y in nfa.eps[x]:
                    if y not in seen:
                        seen.add(y)
                        stack.append(y)
            eclo.append(frozenset(seen))

        def winner(states: frozenset) -> Optional[str]:
            cands = [accept_of[s] for s in states if s in accept_of]
            if not cands:
                return None
            # priority, then literal-over-regex, then name for determinism
            return max(
                cands,
                key=lambda nm: (self.terminals[nm].priority,
                                self.terminals[nm].from_literal,
                                # longer literals not needed: longest-match is
                                # positional; tie at same length/prio -> stable
                                -order.index(nm)),
            )

        start_set = eclo[nfa.start]
        ids = {start_set: 0}
        olist = [start_set]
        queue = collections.deque([start_set])
        rows = []
        while queue:
            cur = queue.popleft()
            row = np.full(256, -1, dtype=np.int64)
            move: dict[int, set] = {}
            for s in cur:
                for chars, succ in nfa.trans[s]:
                    for c in chars:
                        move.setdefault(c, set()).update(eclo[succ])
            cache = {}
            for c, tgt in move.items():
                f = frozenset(tgt)
                if f not in cache:
                    if f not in ids:
                        ids[f] = len(olist)
                        olist.append(f)
                        queue.append(f)
                    cache[f] = ids[f]
                row[c] = cache[f]
            rows.append(row)
        Q = len(olist)
        dead = Q
        trans = np.full((Q + 1, 256), dead, dtype=np.int32)
        for q, row in enumerate(rows):
            v = row >= 0
            trans[q, v] = row[v]
        finals = np.zeros(Q + 1, dtype=bool)
        tags = [None] * (Q + 1)
        for q, st in enumerate(olist):
            w = winner(st)
            if w is not None:
                finals[q] = True
                tags[q] = w
        self.lexer_dfa = DFA(trans, 0, finals)
        self.lexer_tags = tags

    # ---------------- indexing ----------------

    def _index(self):
        self.terminal_names = sorted(self.terminals)
        self.term_id = {t: i for i, t in enumerate(self.terminal_names)}
        self.parse_terminals = [t for t in self.terminal_names
                                if t not in self.ignores]
        self.synthetic_terminals = (frozenset(self.indent_spec[1:])
                                    if self.indent_spec else frozenset())
        # global DFA state numbering for the mask store: concatenate all
        # terminal DFAs; states of terminal i are offset by state_offset[i]
        self.state_offset: dict[str, int] = {}
        off = 0
        for t in self.terminal_names:
            self.state_offset[t] = off
            off += self.terminals[t].dfa.num_states
        self.total_dfa_states = off

    def prods_by_lhs(self):
        by = {}
        for p in self.productions:
            by.setdefault(p.lhs, []).append(p)
        return by

    def __repr__(self):
        return (f"Grammar({self.name}: {len(self.productions)} prods, "
                f"{len(self.terminals)} terminals, "
                f"{self.total_dfa_states} DFA states)")
