"""Deterministic synthetic byte-level tokenizer.

The offline container has no pretrained tokenizers, but the paper's core
difficulty — *token misalignment* (LLM tokens spanning / splitting grammar
terminals) — only needs a vocabulary of multi-byte tokens that cross
terminal boundaries. This tokenizer is BPE-shaped: 256 byte tokens plus a
deterministic list of multi-byte merges (keywords with/without leading
space, punctuation bigrams, digit pairs, letter n-grams). `encode` is
greedy longest-match (maximal munch over the vocab trie), mirroring how a
trained BPE behaves on code-like text.

ids: 0=PAD, 1=EOS, 2=BOS, 3..258 = single bytes, 259.. = merges.
"""
from __future__ import annotations

import string

PAD_ID, EOS_ID, BOS_ID = 0, 1, 2
_NUM_SPECIAL = 3

_KEYWORDS = [
    "true", "false", "null", "fn", "let", "if", "else", "while", "for",
    "in", "return", "break", "continue", "struct", "int", "float", "str",
    "bool", "nil", "math_exp", "math_sqrt", "math_sin", "math_cos", "math",
    "select", "from", "where", "group", "by", "order", "having", "limit",
    "join", "on", "as", "and", "or", "not", "count", "sum", "avg", "min",
    "max", "distinct", "between", "like", "exists", "union", "left",
    "right", "inner", "asc", "desc", "offset", "is",
    "SELECT", "FROM", "WHERE", "GROUP", "BY", "ORDER", "HAVING", "LIMIT",
    "JOIN", "ON", "AS", "AND", "OR", "NOT", "COUNT", "SUM", "AVG", "MIN",
    "MAX", "DISTINCT", "BETWEEN", "LIKE", "EXISTS", "UNION",
    "name", "value", "type", "id", "key", "data", "list", "item", "index",
    "result", "args", "len", "total", "self", "this", "print", "range",
    # python keywords (python_mini): make real token/terminal misalignment
    # — "def" is one token but also a NAME prefix ("define"), "None"/"True"
    # straddle the keyword-vs-NAME choice the mask must keep open
    "def", "class", "elif", "pass", "None", "True", "False", "import",
    "lambda", "yield", "def ", "return ", "    ",
]
_PUNCT_MERGES = [
    '":', '",', '" ', ' "', '{"', '"}', '):', ');', ')(', '()', '())',
    '();', '[]', '{}', '))', '((', '],', '};', ', ', ': ', '; ', ' (',
    ' )', ' {', ' }', ' [', ' ]', ' =', '= ', ' == ', ' != ', ' <= ',
    ' >= ', ' < ', ' > ', ' + ', ' - ', ' * ', ' / ', ' && ', ' || ',
    '->', '=>', '//', '/*', '*/', '\n\n', '\n  ', '\n    ', '    ',
    '  ', '."', '".', '...', 'e+', 'e-', 'E+', '0.', '1.', '("', '")',
]


def _merge_strings(vocab_size: int) -> list[bytes]:
    """Deterministic multi-byte token list, most useful first."""
    out: list[bytes] = []
    seen: set[bytes] = set()

    def add(s):
        b = s.encode() if isinstance(s, str) else s
        if len(b) >= 2 and b not in seen:
            seen.add(b)
            out.append(b)

    for kw in _KEYWORDS:
        add(kw)
        add(" " + kw)
    for pm in _PUNCT_MERGES:
        add(pm)
    for a in "0123456789":
        for b in "0123456789":
            add(a + b)
    letters = "etaoinshrdlucmfwypvbgkqjxz"
    for a in letters:
        for b in letters:
            add(a + b)
    for a in letters[:12]:
        add(" " + a)
    for a in letters[:12]:
        for b in letters[:12]:
            for c in letters[:12]:
                add(a + b + c)
                if len(out) > vocab_size:  # enough material
                    return out
    # fallback filler: longer digit strings
    i = 0
    while len(out) <= vocab_size:
        add(f"{i:04d}")
        i += 1
    return out


class ByteTokenizer:
    def __init__(self, vocab_size: int = 2048):
        if vocab_size < _NUM_SPECIAL + 256 + 16:
            raise ValueError("vocab_size too small")
        self.vocab_size = vocab_size
        self.id_to_bytes: list[bytes] = [b"", b"", b""]  # PAD, EOS, BOS
        for b in range(256):
            self.id_to_bytes.append(bytes([b]))
        n_merges = vocab_size - len(self.id_to_bytes)
        merges = _merge_strings(n_merges)[:n_merges]
        self.id_to_bytes.extend(merges)
        assert len(self.id_to_bytes) == vocab_size
        # trie for greedy longest-match encode
        self._trie: dict = {}
        for tid, tb in enumerate(self.id_to_bytes):
            if tid < _NUM_SPECIAL:
                continue
            node = self._trie
            for ch in tb:
                node = node.setdefault(ch, {})
            node[-1] = tid
        self.max_token_len = max(len(b) for b in self.id_to_bytes)

    def encode(self, data: bytes | str, add_bos: bool = False,
               add_eos: bool = False) -> list[int]:
        if isinstance(data, str):
            data = data.encode("utf-8")
        ids = [BOS_ID] if add_bos else []
        i, n = 0, len(data)
        while i < n:
            node = self._trie
            best = None
            j = i
            while j < n and data[j] in node:
                node = node[data[j]]
                j += 1
                if -1 in node:
                    best = (node[-1], j)
            tid, i = best  # single bytes always match, so best is never None
            ids.append(tid)
        if add_eos:
            ids.append(EOS_ID)
        return ids

    def decode(self, ids) -> bytes:
        return b"".join(self.id_to_bytes[int(t)] for t in ids
                        if int(t) >= _NUM_SPECIAL)

    def decode_str(self, ids) -> str:
        return self.decode(ids).decode("utf-8", "replace")

    def token_bytes(self) -> list[bytes]:
        """Per-id byte strings (specials are b'')."""
        return list(self.id_to_bytes)

    @property
    def num_special(self):
        return _NUM_SPECIAL
