"""The DFA mask store (paper §4.3, Def. 12) — precomputed offline.

For every live DFA state `q` (over all terminals' DFAs, globally numbered)
the store holds packed boolean vocabulary masks:

  * M0 row  — tokens t with dmatch(t, q, ())          [α = 0]
  * M1 rows — tokens t with dmatch(t, q, (τ',)) per τ' [α = 1]

dmatch (Def. 10) decomposes, for a token t walked from q on terminal τ's
DFA, into:
  cond 1: the walk ends in a live state of D_τ
  cond 2: some *proper* prefix of t lands in F_τ (α = 0 only)
  cond 3: some prefix (incl. ε and all of t) lands in F_τ and the rest of
          t "pmatches" τ' from its start state (α = 1)

Construction is vectorized with numpy over the whole vocabulary at once:
tokens are a padded [V, L] byte matrix; a DFA walk from any state is L
gather steps over the transition table. Complexity matches the paper's
O(|Q_Ω|·|V|·|Γ|^α) with tiny constants; stores are cached on disk keyed by
(grammar, vocab) fingerprints (paper §6.4 reports one-time costs only).

The store holds TWO row families over the same state addressing:

  * grammar_mask rows (the paper's dmatch, OVERapproximate): a token is
    kept if any tokenization could keep the text in L_p(G) — including
    tokens that overshoot a terminal boundary into arbitrary bytes
    (cond 2 / the "rest of t is arbitrary" allowance of cond 3).
  * grammar_strict rows (UNDERapproximate, terminal-boundary-aligned):
    the overshoot allowances are dropped — a token survives only if its
    bytes walk entirely inside the current terminal (cond 1), or split
    exactly once at a final state of the current terminal with the rest
    walking live inside the single lookahead terminal τ'. Strict masks
    never admit a token the mask family bans (strict ⊆ mask, bitwise),
    at the cost of banning some tokens an exact oracle would allow.

Row layout (used by the serving kernel): row(q, α=0) = q·(|Γ|+1);
row(q, τ') = q·(|Γ|+1) + 1 + tid(τ'). The strict family is the same
layout shifted by `strict_offset` = total_states·(|Γ|+1); the packed
array is [2R, W]. Packed as uint32 little-endian bit-words: word w bit
b ⇔ token id w·32+b.

The per-state build is shardable: `build_rows_shard` computes the rows
for any global-state range (the offline parallel builder
`scripts/build_mask_store.py` farms shards to worker processes) and
`assemble_store` concatenates shard outputs and atomically publishes
the store through the fingerprinted disk cache.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import time

import numpy as np

from .grammar import Grammar
from .tokenizer import ByteTokenizer, EOS_ID, PAD_ID

# On-disk cache layout version, hashed into the cache fingerprint. Bump
# whenever the packed representation changes (word dtype, bit order, row
# addressing, padding) so stale caches written by an older layout MISS
# instead of being loaded as garbage masks.
STORE_LAYOUT_VERSION = 3


class MaskStore:
    def __init__(self, grammar: Grammar, tokenizer: ByteTokenizer,
                 packed: np.ndarray, meta: dict):
        self.grammar = grammar
        self.tokenizer = tokenizer
        self.packed = packed            # [rows, words] uint32
        self.meta = meta
        self.num_terminals = len(grammar.terminal_names)
        self.row_stride = self.num_terminals + 1
        # the strict family occupies the second half of the packed array
        self.strict_offset = packed.shape[0] // 2
        self._row_pc = None             # lazy per-row popcounts (spec path)
        self._fb = None                 # lazy first-byte -> vocab bitmask

    # ---- row addressing ----
    def global_state(self, terminal: str, q: int) -> int:
        return self.grammar.state_offset[terminal] + q

    def row_m0(self, terminal: str, q: int, strict: bool = False) -> int:
        off = self.strict_offset if strict else 0
        return self.global_state(terminal, q) * self.row_stride + off

    def row_m1(self, terminal: str, q: int, next_terminal: str,
               strict: bool = False) -> int:
        tid = self.grammar.term_id[next_terminal]
        off = self.strict_offset if strict else 0
        return (self.global_state(terminal, q) * self.row_stride
                + 1 + tid + off)

    # ---- host-side mask ops (reference; device path is in kernels/) ----
    def union_rows(self, rows) -> np.ndarray:
        """OR of packed rows -> packed [words] uint32."""
        out = np.zeros(self.packed.shape[1], dtype=np.uint32)
        for r in rows:
            if r >= 0:
                out |= self.packed[r]
        return out

    def unpack(self, packed_row: np.ndarray) -> np.ndarray:
        bits = np.unpackbits(packed_row.view(np.uint8), bitorder="little")
        return bits[: self.tokenizer.vocab_size].astype(bool)

    # ---- forced-continuation queries (speculation / jump-forward) ------
    # The spec subsystem (repro.spec.jump) asks, per step, "how many
    # tokens survive this step's mask union, and which one if exactly
    # one?" — popcount + sole-survivor extraction on the packed rows,
    # without ever materializing the [V] boolean mask.

    def row_popcounts(self) -> np.ndarray:
        """[rows] int32 allowed-token count per packed row (computed once,
        lazily). The jump-forward analyzer uses it as a short-circuit:
        the union of a row set can only collapse to <= 1 token if every
        member row already allows <= 1, so per-step forced detection is a
        gather + max instead of a mask union."""
        if self._row_pc is None:
            # 256-entry popcount LUT over the uint8 view: same result as
            # unpackbits().sum() at 1/8 the transient memory (no [R, V]
            # bit expansion next to the resident model)
            lut = np.unpackbits(
                np.arange(256, dtype=np.uint8)[:, None], axis=1
            ).sum(axis=1, dtype=np.int32)
            self._row_pc = lut[self.packed.view(np.uint8)].sum(
                axis=1, dtype=np.int32)
        return self._row_pc

    @staticmethod
    def popcount_packed(packed: np.ndarray) -> int:
        """Allowed-token count of an already-unioned packed row. Padding
        bits past vocab_size are zero by construction, so a plain bit
        count over the packed words is exact."""
        return int(np.unpackbits(packed.view(np.uint8)).sum())

    @staticmethod
    def sole_from_packed(packed: np.ndarray):
        """Single allowed token id of an already-unioned packed row, or
        None when the popcount is not exactly 1."""
        nz = np.nonzero(packed)[0]
        if nz.size != 1:
            return None
        word = int(packed[nz[0]])
        if word & (word - 1):               # more than one bit in the word
            return None
        return int(nz[0]) * 32 + word.bit_length() - 1

    def union_popcount(self, rows) -> int:
        """Number of vocabulary tokens allowed by the OR of `rows`."""
        return self.popcount_packed(self.union_rows(rows))

    def allowed_first_bytes(self, packed_union: np.ndarray) -> np.ndarray:
        """[256] bool: byte c is True iff some token allowed by the packed
        union starts with c. When exactly one byte survives, EVERY valid
        tokenization of the continuation begins with it — the byte is
        grammar-FORCED even though several tokens (prefix-nested merges)
        remain in the mask. The jump-forward analyzer chains this to
        recover forced literal byte-strings that token-level popcount
        misses. Lazy [256, words] first-byte bitmasks, one AND per query."""
        if self._fb is None:
            W = self.packed.shape[1]
            fb = np.zeros((256, W), np.uint32)
            for tid, b in enumerate(self.tokenizer.id_to_bytes):
                if b and tid < self.tokenizer.vocab_size:
                    fb[b[0], tid // 32] |= np.uint32(1 << (tid % 32))
            self._fb = fb
        return (self._fb & packed_union[None, :]).any(axis=1)

    def sole_survivor(self, rows):
        """If exactly one token survives the union of `rows`, return its
        id; else None."""
        return self.sole_from_packed(self.union_rows(rows))

    @property
    def num_rows(self):
        return self.packed.shape[0]

    @property
    def num_words(self):
        return self.packed.shape[1]

    def nbytes(self):
        return self.packed.nbytes


def _fingerprint(grammar: Grammar, tok: ByteTokenizer) -> str:
    h = hashlib.sha256()
    # layout version + packed-word geometry first: a cache produced by an
    # older packed layout must not fingerprint-match (it would load as
    # wrong masks — soundness, not just staleness)
    words = (tok.vocab_size + 31) // 32
    h.update(f"layout{STORE_LAYOUT_VERSION}:uint32le:w{words}".encode())
    h.update(grammar.name.encode())
    for t in grammar.terminal_names:
        h.update(t.encode())
        h.update(grammar.terminals[t].dfa.trans.tobytes())
        h.update(grammar.terminals[t].dfa.finals.tobytes())
    h.update(str(tok.vocab_size).encode())
    # hash EVERY token, length-prefixed: two vocabs sharing a prefix and
    # total byte length must not collide onto the same cached store
    for b in tok.id_to_bytes:
        h.update(len(b).to_bytes(4, "little"))
        h.update(b)
    return h.hexdigest()[:16]


class _Prep:
    """Shared per-(grammar, vocab) precomputation reused by every shard:
    the padded token byte-matrix and the packed suffix-pmatch tables for
    both row families."""
    __slots__ = ("V", "L", "T", "tok_len", "nonempty", "terms", "G",
                 "stride", "lanes", "S_bits", "Ss_bits")


def _prep(grammar: Grammar, tokenizer: ByteTokenizer) -> _Prep:
    p = _Prep()
    V = p.V = tokenizer.vocab_size
    toks = tokenizer.token_bytes()
    L = p.L = max(1, max(len(b) for b in toks))
    if L + 1 > 64:
        raise ValueError("token length > 63 unsupported by packed build")
    T = p.T = np.zeros((V, L), dtype=np.int32)
    tok_len = p.tok_len = np.zeros(V, dtype=np.int32)
    for i, b in enumerate(toks):
        tok_len[i] = len(b)
        if b:
            T[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    # special tokens (len 0) must never be "valid": we make their rows 0
    nonempty = p.nonempty = tok_len > 0

    terms = p.terms = grammar.terminal_names
    G = p.G = len(terms)
    p.stride = G + 1

    # ---- per-terminal suffix pmatch tables S[g, v, i]:
    #   mask family   — dmatch(t[i:], start(τ_g), ()) (end live OR a
    #                   proper prefix of the suffix lands in F)
    #   strict family — the suffix's ENTIRE walk stays live (no
    #                   overshoot past a terminal boundary)
    # for i in 0..L (i > len -> False). Packed over the split index i
    # into uint64 bit-lanes so the per-state M1 computation is a single
    # AND+nonzero over [G, V] (instead of a [G, V, L] reduction) —
    # TPU-thinking applied to the host build.
    S = np.zeros((G, V, L + 1), dtype=bool)
    Ss = np.zeros((G, V, L + 1), dtype=bool)
    for g, name in enumerate(terms):
        dfa = grammar.terminals[name].dfa
        trans, finals, live = dfa.trans, dfa.finals, dfa.live
        # suffix walk: for each start position i, walk from q0 over
        # t[i:]; each walk is <= L steps over [V] vectors.
        for i in range(L + 1):
            ok = tok_len >= i
            st = np.full(V, dfa.start, dtype=np.int32)
            hitF = np.zeros(V, dtype=bool)   # F hit strictly before suffix end
            for j in range(i, L):
                act = j < tok_len
                hitF |= ok & act & finals[st]       # prefix ending at j (proper)
                st_new = trans[st, T[:, j]]
                st = np.where(act, st_new, st)
            end_live = live[st]
            base_ok = ok & nonempty
            # mask: dmatch(suffix, q0, ()) = end live (cond1) or
            # proper-prefix in F (cond2); strict: end live only
            S[g, :, i] = base_ok & (end_live | hitF)
            Ss[g, :, i] = base_ok & end_live
            # note: empty suffix (i == len): cond1 with ε -> q0 live == True
            isempty = (tok_len == i) & live[dfa.start]
            S[g, :, i] |= isempty
            Ss[g, :, i] |= isempty
        # tokens shorter than i already masked by ok

    # bit-pack S over the split axis: S_bits[g, v] bit i <-> S[g, v, i]
    lanes = p.lanes = (np.uint64(1) << np.arange(L + 1, dtype=np.uint64))
    p.S_bits = (S.astype(np.uint64) * lanes[None, None, :]).sum(
        axis=2, dtype=np.uint64)
    p.Ss_bits = (Ss.astype(np.uint64) * lanes[None, None, :]).sum(
        axis=2, dtype=np.uint64)
    return p


def _pack_rows(rows: np.ndarray, V: int) -> np.ndarray:
    """[rows, V] bool -> [rows, W] uint32, little-endian bit-words."""
    Wbits = ((V + 31) // 32) * 32
    padded = np.zeros((rows.shape[0], Wbits), dtype=bool)
    padded[:, :V] = rows
    packed = np.packbits(padded, axis=1, bitorder="little")
    return np.ascontiguousarray(packed).view(np.uint32)


def build_rows_shard(grammar: Grammar, tokenizer: ByteTokenizer,
                     lo: int, hi: int, prep: _Prep | None = None):
    """Packed rows for the global DFA states [lo, hi).

    Returns (mask_packed, strict_packed), each uint32 of shape
    [(hi-lo)·stride, W]. Shards concatenated in global-state order
    reproduce the full store bit-for-bit regardless of how the range
    [0, total_dfa_states) was split — the parallel offline builder
    relies on this.
    """
    p = prep if prep is not None else _prep(grammar, tokenizer)
    V, L, T, tok_len = p.V, p.L, p.T, p.tok_len
    nonempty, G, stride, lanes = p.nonempty, p.G, p.stride, p.lanes
    n = hi - lo
    mask_rows = np.zeros((n * stride, V), dtype=bool)
    strict_rows = np.zeros((n * stride, V), dtype=bool)
    pos = np.arange(L + 1)[None, :]
    for name in p.terms:
        dfa = grammar.terminals[name].dfa
        trans, finals, live = dfa.trans, dfa.finals, dfa.live
        off = grammar.state_offset[name]
        for q in range(max(0, lo - off), min(dfa.num_states, hi - off)):
            if not live[q]:
                continue  # dead-state rows stay all-zero (never queried)
            st = np.full(V, q, dtype=np.int32)
            # hitF_at[v, i]: state after consuming t[:i] is in F  (i=0..L)
            hitF_at = np.zeros((V, L + 1), dtype=bool)
            hitF_at[:, 0] = finals[q]
            for j in range(L):
                act = j < tok_len
                st_new = trans[st, T[:, j]]
                st = np.where(act, st_new, st)
                hitF_at[:, j + 1] = act & finals[st]
            end_live = live[st] & nonempty
            proper = hitF_at & (pos < tok_len[:, None])   # strict prefix in F
            anyF = hitF_at & (pos <= tok_len[:, None])    # any prefix incl. full
            anyF_bits = (anyF.astype(np.uint64) *
                         lanes[None, :]).sum(axis=1, dtype=np.uint64)
            r0 = (off + q - lo) * stride
            # mask M0: cond1 | cond2; strict M0: cond1 only (the token
            # must stay inside the current terminal)
            mask_rows[r0] = end_live | proper.any(axis=1)
            strict_rows[r0] = end_live
            # M1[τ']: cond1 | (split in F and suffix pmatches τ'), with
            # the family's own suffix table
            m1 = (p.S_bits & anyF_bits[None, :]) != 0
            m1s = (p.Ss_bits & anyF_bits[None, :]) != 0
            mask_rows[r0 + 1: r0 + 1 + G] = m1 | end_live
            strict_rows[r0 + 1: r0 + 1 + G] = m1s | end_live

    # never allow specials through the grammar mask (EOS handled separately)
    mask_rows[:, ~nonempty] = False
    strict_rows[:, ~nonempty] = False
    return _pack_rows(mask_rows, V), _pack_rows(strict_rows, V)


def assemble_store(grammar: Grammar, tokenizer: ByteTokenizer, parts,
                   cache_dir: str | None = None, verbose: bool = False,
                   t0: float | None = None) -> MaskStore:
    """Concatenate shard outputs (in global-state order, covering the
    whole state space) into the [2R, W] packed array and publish it
    atomically through the disk cache."""
    fp = _fingerprint(grammar, tokenizer)
    packed = np.concatenate([part[0] for part in parts] +
                            [part[1] for part in parts], axis=0)
    meta = {
        "build_seconds": time.time() - (t0 if t0 is not None else time.time()),
        "rows": int(packed.shape[0]),
        "bytes": int(packed.nbytes),
        "grammar": grammar.name,
        "vocab": tokenizer.vocab_size,
        "cached": False,
    }
    if verbose:
        print(f"[mask_store] {grammar.name}: {meta['rows']} rows x "
              f"{packed.shape[1]} words, {meta['bytes']/1e6:.1f} MB, "
              f"{meta['build_seconds']:.1f}s")
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        path = os.path.join(cache_dir, f"maskstore_{grammar.name}_{fp}.npz")
        # atomic publish, safe under concurrent multi-process (and
        # multi-thread) builds: mkstemp gives each writer a private
        # temp file in the SAME directory (os.replace must not cross
        # filesystems), the pid in the prefix aids debugging, and
        # os.replace atomically publishes — concurrent builders race
        # benignly (last writer wins, all write identical bytes) and
        # readers never see a torn .npz. The unlink is tolerant: the
        # temp name is private, so ENOENT can only mean our own
        # os.replace already consumed it.
        fd, tmp = tempfile.mkstemp(
            dir=cache_dir,
            prefix=f".maskstore_{grammar.name}_{fp}.{os.getpid()}.")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez_compressed(f, packed=packed)
            os.replace(tmp, path)
        finally:
            try:
                os.remove(tmp)
            except OSError:
                pass
        meta["path"] = path
    return MaskStore(grammar, tokenizer, packed, meta)


def load_cached_store(grammar: Grammar, tokenizer: ByteTokenizer,
                      cache_dir: str | None) -> "MaskStore | None":
    """The cache-hit path, shared by the serial and parallel builders."""
    if not cache_dir:
        return None
    fp = _fingerprint(grammar, tokenizer)
    path = os.path.join(cache_dir, f"maskstore_{grammar.name}_{fp}.npz")
    if not os.path.exists(path):
        return None
    z = np.load(path)
    return MaskStore(grammar, tokenizer, z["packed"],
                     {"cached": True, "path": path})


def build_mask_store(grammar: Grammar, tokenizer: ByteTokenizer,
                     cache_dir: str | None = None,
                     verbose: bool = False) -> MaskStore:
    cached = load_cached_store(grammar, tokenizer, cache_dir)
    if cached is not None:
        return cached
    t0 = time.time()
    prep = _prep(grammar, tokenizer)
    part = build_rows_shard(grammar, tokenizer, 0,
                            grammar.total_dfa_states, prep)
    return assemble_store(grammar, tokenizer, [part],
                          cache_dir=cache_dir, verbose=verbose, t0=t0)
