"""The DFA mask store (paper §4.3, Def. 12) — precomputed offline.

For every live DFA state `q` (over all terminals' DFAs, globally numbered)
the store holds packed boolean vocabulary masks:

  * M0 row  — tokens t with dmatch(t, q, ())          [α = 0]
  * M1 rows — tokens t with dmatch(t, q, (τ',)) per τ' [α = 1]

dmatch (Def. 10) decomposes, for a token t walked from q on terminal τ's
DFA, into:
  cond 1: the walk ends in a live state of D_τ
  cond 2: some *proper* prefix of t lands in F_τ (α = 0 only)
  cond 3: some prefix (incl. ε and all of t) lands in F_τ and the rest of
          t "pmatches" τ' from its start state (α = 1)

Construction is vectorized with numpy over the whole vocabulary at once:
tokens are a padded [V, L] byte matrix; a DFA walk from any state is L
gather steps over the transition table. Complexity matches the paper's
O(|Q_Ω|·|V|·|Γ|^α) with tiny constants; stores are cached on disk keyed by
(grammar, vocab) fingerprints (paper §6.4 reports one-time costs only).

The store holds TWO row families over the same state addressing:

  * grammar_mask rows (the paper's dmatch, OVERapproximate): a token is
    kept if any tokenization could keep the text in L_p(G) — including
    tokens that overshoot a terminal boundary into arbitrary bytes
    (cond 2 / the "rest of t is arbitrary" allowance of cond 3).
  * grammar_strict rows (UNDERapproximate, terminal-boundary-aligned):
    the overshoot allowances are dropped — a token survives only if its
    bytes walk entirely inside the current terminal (cond 1), or split
    exactly once at a final state of the current terminal with the rest
    walking live inside the single lookahead terminal τ'. Strict masks
    never admit a token the mask family bans (strict ⊆ mask, bitwise),
    at the cost of banning some tokens an exact oracle would allow.

Row layout (used by the serving kernel): row(q, α=0) = q·(|Γ|+1);
row(q, τ') = q·(|Γ|+1) + 1 + tid(τ'). The strict family is the same
layout shifted by `strict_offset` = total_states·(|Γ|+1); the packed
array is [2R, W]. Packed as uint32 little-endian bit-words: word w bit
b ⇔ token id w·32+b.

The per-state build is shardable: `build_rows_shard` computes the rows
for any global-state range (the offline parallel builder
`scripts/build_mask_store.py` farms shards to worker processes) and
`assemble_store` concatenates shard outputs and atomically publishes
the store through the fingerprinted disk cache.

Context split (layout v4, XGrammar-style): every (state, token) pair is
classified offline into a context-INDEPENDENT majority — acceptance
decided by the DFA walk alone (dmatch cond 1: the token's bytes walk
live inside the current terminal; the CI row of state `s` is the
strict-M0 / end_live row `packed[strict_offset + s*stride]`, shared by
BOTH families) — and a context-DEPENDENT part whose acceptance depends
on the step's accept sequences. The CD part decomposes further, and
every sub-class except the last is resolved by choosing PRECOMPUTED
store rows (device-resident ids, zero host bit work):

  * α=0 overshoot (family M0 bits beyond end_live): selected by one
    accept-set boolean — when the length-1 sequence is present the
    runtime emits the family M0 row (a superset of the CI row) as the
    group's base row instead of the CI row.
  * position-0 follow splits: when the remainder walk lands IN F, every
    token that pmatches follow terminal τ' from its start is allowed —
    and that set is exactly the store row of τ''s DFA START state
    (mask-family M0 row / strict CI row; an identity of the suffix
    tables, asserted by tests). The runtime emits those per-follow
    start rows whenever `finals[s]`.
  * interior (j>0) splits whose residue is BIG (> CD_ROW_THRESHOLD
    tokens): the legacy M1 row id is emitted directly; `cd_big` bit
    1+g of `[fam*S + s]` marks these rows.
  * interior splits with a SMALL residue — the only per-token work
    left: `cd_token` lists the tokens per (family, state) with a
    per-token follow bitmask `cd_follow` (bit 1+g = M1[τ_g]-residue,
    matching row addressing; bit 0 reserved), indexed by
    `cd_ptr[fam*S + s]`. The runtime overlay is a
    select-by-accept-bits scatter over this residue — a few tokens per
    step on the builtin grammars, replacing the wide accept-row
    unions on the host hot path.

The classification (`derive_context_split`) is a pure function of the
packed rows plus per-state finals flags and the per-terminal
start-state rows (both derivable from grammar + packed), so shard
outputs concatenate bitwise deterministically and `--verify` can
re-derive it independently. Per-row popcounts and the [256, W]
first-byte table are also precomputed at build time (they used to be
lazy per-process work).
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import time

import numpy as np

from .grammar import Grammar
from .tokenizer import ByteTokenizer, EOS_ID, PAD_ID

# On-disk cache layout version, hashed into the cache fingerprint. Bump
# whenever the packed representation changes (word dtype, bit order, row
# addressing, padding) so stale caches written by an older layout MISS
# instead of being loaded as garbage masks. v4: context-split tables
# (cd_ptr/cd_token/cd_follow) + build-time popcount and first-byte
# tables ride in the same npz.
STORE_LAYOUT_VERSION = 4

# Context-dependent rows whose interior-split residue exceeds this many
# tokens are kept as whole precomputed M1 rows (`cd_big`) instead of
# entering the per-token residue tables: the per-step scatter stays a
# few tokens while pathological states cost one extra device row id.
# Folded into the cache fingerprint — changing it must miss stale caches.
CD_ROW_THRESHOLD = 16


def compute_row_popcounts(packed: np.ndarray) -> np.ndarray:
    """[rows] int32 allowed-token count per packed row. 256-entry
    popcount LUT over the uint8 view: same result as unpackbits().sum()
    at 1/8 the transient memory (no [R, V] bit expansion next to the
    resident model)."""
    lut = np.unpackbits(
        np.arange(256, dtype=np.uint8)[:, None], axis=1
    ).sum(axis=1, dtype=np.int32)
    return lut[packed.view(np.uint8)].sum(axis=1, dtype=np.int32)


def compute_first_byte_table(tokenizer: ByteTokenizer,
                             words: int) -> np.ndarray:
    """[256, words] uint32: row c is the packed bitmask of vocab tokens
    whose first byte is c (special / empty tokens excluded)."""
    fb = np.zeros((256, words), np.uint32)
    for tid, b in enumerate(tokenizer.id_to_bytes):
        if b and tid < tokenizer.vocab_size:
            fb[b[0], tid // 32] |= np.uint32(1 << (tid % 32))
    return fb


def compute_state_finals(grammar: Grammar, lo: int = 0,
                         hi: int | None = None) -> np.ndarray:
    """[hi-lo] bool: global DFA state s+lo is a FINAL state of its
    terminal's DFA. Final states admit position-0 follow splits (the
    remainder already completes the terminal), which the runtime
    resolves with the follow terminal's start-state row."""
    if hi is None:
        hi = grammar.total_dfa_states
    finals = np.zeros(hi - lo, dtype=bool)
    for name in grammar.terminal_names:
        dfa = grammar.terminals[name].dfa
        off = grammar.state_offset[name]
        for q in range(max(0, lo - off), min(dfa.num_states, hi - off)):
            finals[off + q - lo] = bool(dfa.finals[q])
    return finals


def term_start_states(grammar: Grammar) -> np.ndarray:
    """[G] int32 global DFA state of each terminal's start state, in
    `terminal_names` order — the addressing base for the position-0
    follow-split rows."""
    return np.array([grammar.state_offset[t] + grammar.terminals[t].dfa.start
                     for t in grammar.terminal_names], dtype=np.int32)


def pm0_rows_from_packed(grammar: Grammar, packed: np.ndarray,
                         stride: int) -> tuple[np.ndarray, np.ndarray]:
    """([G, W], [G, W]) uint32 pmatch-from-start rows per family, read
    from a FULL packed array: mask family = M0 row of the terminal's
    start state, strict family = its CI (strict-M0) row."""
    starts = term_start_states(grammar)
    strict_offset = packed.shape[0] // 2
    return (packed[starts * stride],
            packed[strict_offset + starts * stride])


def derive_context_split(mask_packed: np.ndarray, strict_packed: np.ndarray,
                         stride: int, vocab_size: int,
                         finals: np.ndarray, pm0_mask: np.ndarray,
                         pm0_strict: np.ndarray,
                         threshold: int = CD_ROW_THRESHOLD):
    """Classify (state, token) pairs into the context-dependent residue,
    per row family — a pure function of the packed rows plus the
    per-state `finals` flags (aligned to this packed slice) and the
    per-terminal pmatch-from-start rows `pm0_*` [G, W] (the start-state
    rows of the FULL store; see `pm0_rows_from_packed`).

    For state `s` the context-independent bits are the strict-M0 /
    end_live row `strict_packed[s*stride]` (cond 1 — shared by both
    families). The context-dependent remainder of M1[τ_g] beyond those
    bits is classified per (family, state, follow):

      * if `finals[s]`, the position-0 split contribution — exactly the
        pm0 row of τ_g — is subtracted: the runtime emits that
        precomputed row directly, so it never enters the tables;
      * a residue still larger than `threshold` tokens marks bit 1+g of
        `cd_big[fam*S + s]`: the runtime emits the legacy M1 row id
        (also precomputed) for these;
      * otherwise the residue tokens enter `cd_token` (ascending —
        deterministic for shard concatenation and bitwise --verify)
        with follow bit 1+g set in `cd_follow`.

    Returns (cd_ptr [2S+1] int64, cd_token [N] int32,
    cd_follow [N, FW] uint64, cd_big [2S, FW] uint64),
    FW = ceil(stride/64); the residue of (family f, state s) lives at
    cd_token[cd_ptr[f*S+s] : cd_ptr[f*S+s+1]].
    """
    S = mask_packed.shape[0] // stride
    FW = (stride + 63) // 64
    cd_ptr = np.zeros(2 * S + 1, dtype=np.int64)
    cd_big = np.zeros((2 * S, FW), dtype=np.uint64)
    pclut = np.unpackbits(np.arange(256, dtype=np.uint8)[:, None],
                          axis=1).sum(axis=1, dtype=np.int32)
    tok_parts: list = []
    fol_parts: list = []
    n = 0
    for fam, (fam_rows, pm0) in enumerate(((mask_packed, pm0_mask),
                                           (strict_packed, pm0_strict))):
        for s in range(S):
            rows = fam_rows[s * stride + 1:(s + 1) * stride]   # M1 only
            ci = strict_packed[s * stride]
            extra = rows & ~ci[None, :]
            if finals[s]:
                extra = extra & ~pm0
            pcs = pclut[extra.view(np.uint8)].sum(axis=1, dtype=np.int32)
            big = pcs > threshold
            for g in np.nonzero(big)[0]:
                j = 1 + int(g)                       # bit 1+tid(τ_g)
                cd_big[fam * S + s, j >> 6] |= np.uint64(1) << np.uint64(j & 63)
            extra = np.where(big[:, None], np.uint32(0), extra)
            union = np.bitwise_or.reduce(extra, axis=0)
            if union.any():
                bits = np.unpackbits(union.view(np.uint8),
                                     bitorder="little")[:vocab_size]
                toks = np.nonzero(bits)[0].astype(np.int32)
                cols = (extra[:, toks >> 5]
                        >> (toks & 31).astype(np.uint32)) & np.uint32(1)
                fol = np.zeros((toks.size, FW), dtype=np.uint64)
                for g in range(stride - 1):
                    j = 1 + g                       # bit 1+tid(τ_g)
                    fol[:, j >> 6] |= (cols[g].astype(np.uint64)
                                       << np.uint64(j & 63))
                tok_parts.append(toks)
                fol_parts.append(fol)
                n += toks.size
            cd_ptr[fam * S + s + 1] = n
    cd_token = (np.concatenate(tok_parts) if tok_parts
                else np.zeros(0, np.int32))
    cd_follow = (np.concatenate(fol_parts) if fol_parts
                 else np.zeros((0, FW), np.uint64))
    return cd_ptr, cd_token, cd_follow, cd_big


def _concat_context_splits(splits, stride: int):
    """Concatenate per-shard context splits (each family-major over its
    OWN state range) into the global family-major layout. Bitwise equal
    to `derive_context_split` over the concatenated packed rows."""
    FW = (stride + 63) // 64
    tok_parts: list = []
    fol_parts: list = []
    big_parts: list = []
    count_parts: list = []
    for fam in range(2):
        for ptr, tok, fol, big in splits:
            Si = (ptr.shape[0] - 1) // 2
            lo, hi = int(ptr[fam * Si]), int(ptr[(fam + 1) * Si])
            tok_parts.append(tok[lo:hi])
            fol_parts.append(fol[lo:hi])
            big_parts.append(big[fam * Si:(fam + 1) * Si])
            count_parts.append(np.diff(ptr[fam * Si:(fam + 1) * Si + 1]))
    counts = (np.concatenate(count_parts) if count_parts
              else np.zeros(0, np.int64))
    cd_ptr = np.zeros(counts.size + 1, np.int64)
    np.cumsum(counts, out=cd_ptr[1:])
    cd_token = (np.concatenate(tok_parts) if tok_parts
                else np.zeros(0, np.int32))
    cd_follow = (np.concatenate(fol_parts) if fol_parts
                 else np.zeros((0, FW), np.uint64))
    cd_big = (np.concatenate(big_parts) if big_parts
              else np.zeros((0, FW), np.uint64))
    return cd_ptr, cd_token, cd_follow, cd_big


class MaskStore:
    def __init__(self, grammar: Grammar, tokenizer: ByteTokenizer,
                 packed: np.ndarray, meta: dict, split=None,
                 row_pc: np.ndarray | None = None,
                 fb: np.ndarray | None = None):
        self.grammar = grammar
        self.tokenizer = tokenizer
        self.packed = packed            # [rows, words] uint32
        self.meta = meta
        self.num_terminals = len(grammar.terminal_names)
        self.row_stride = self.num_terminals + 1
        # the strict family occupies the second half of the packed array
        self.strict_offset = packed.shape[0] // 2
        self.num_states = self.strict_offset // self.row_stride
        # per-state finals flags and per-terminal start states: the
        # runtime's position-0 follow-split addressing (cheap, from the
        # grammar — never serialized)
        self.state_finals = compute_state_finals(grammar)
        self.term_start = term_start_states(grammar)
        # context-split tables: loaded from the v4 cache / shard builds,
        # or re-derived here (raw constructions — same pure function)
        if split is None:
            pm0_mask, pm0_strict = pm0_rows_from_packed(
                grammar, packed, self.row_stride)
            split = derive_context_split(
                packed[:self.strict_offset], packed[self.strict_offset:],
                self.row_stride, tokenizer.vocab_size,
                self.state_finals, pm0_mask, pm0_strict)
        self.cd_ptr, self.cd_token, self.cd_follow, self.cd_big = split
        self.follow_words = self.cd_follow.shape[1]
        # residue scatter addressing, precomputed once: token t sets bit
        # cd_bit[i] of word cd_word[i] of the step's overlay
        self.cd_word = (self.cd_token >> 5).astype(np.int64)
        self.cd_bit = np.uint32(1) << (self.cd_token & 31).astype(np.uint32)
        self._row_pc = row_pc           # build-time per-row popcounts
        self._fb = fb                   # build-time first-byte bitmasks

    # ---- row addressing ----
    def global_state(self, terminal: str, q: int) -> int:
        return self.grammar.state_offset[terminal] + q

    def row_m0(self, terminal: str, q: int, strict: bool = False) -> int:
        off = self.strict_offset if strict else 0
        return self.global_state(terminal, q) * self.row_stride + off

    def row_m1(self, terminal: str, q: int, next_terminal: str,
               strict: bool = False) -> int:
        tid = self.grammar.term_id[next_terminal]
        off = self.strict_offset if strict else 0
        return (self.global_state(terminal, q) * self.row_stride
                + 1 + tid + off)

    def row_ci(self, global_state: int) -> int:
        """Context-independent row of a global DFA state: the strict-M0
        / end_live row, shared by BOTH families (the mode only selects
        which CD residue table applies)."""
        return self.strict_offset + global_state * self.row_stride

    def row_fam_m0(self, fam: int, global_state: int) -> int:
        """Family M0 row of a global state — the base row when the
        accept set contains the length-1 (α=0) sequence. For the strict
        family this coincides with the CI row."""
        return fam * self.strict_offset + global_state * self.row_stride

    def row_follow_start(self, fam: int, tid: int) -> int:
        """pmatch-from-start row of follow terminal tid: the store row
        of its DFA start state (mask M0 / strict CI) — emitted when the
        remainder walk lands in F (position-0 split)."""
        return (fam * self.strict_offset
                + int(self.term_start[tid]) * self.row_stride)

    def cd_range(self, fam: int, global_state: int) -> tuple[int, int]:
        """[lo, hi) slice of cd_token/cd_follow holding the residue of
        (family fam: 0 = grammar_mask, 1 = grammar_strict; state)."""
        i = fam * self.num_states + global_state
        return int(self.cd_ptr[i]), int(self.cd_ptr[i + 1])

    def cd_big_bits(self, fam: int, global_state: int) -> int:
        """Python int bitmask of big CD rows at (family, state): bit
        1+g set means M1[τ_g]'s residue overflowed CD_ROW_THRESHOLD and
        the legacy row id must be emitted when τ_g is a follow."""
        w = self.cd_big[fam * self.num_states + global_state]
        out = 0
        for k in range(self.follow_words - 1, -1, -1):
            out = (out << 64) | int(w[k])
        return out

    # ---- host-side mask ops (reference; device path is in kernels/) ----
    def union_rows(self, rows) -> np.ndarray:
        """OR of packed rows -> packed [words] uint32."""
        out = np.zeros(self.packed.shape[1], dtype=np.uint32)
        for r in rows:
            if r >= 0:
                out |= self.packed[r]
        return out

    def unpack(self, packed_row: np.ndarray) -> np.ndarray:
        bits = np.unpackbits(packed_row.view(np.uint8), bitorder="little")
        return bits[: self.tokenizer.vocab_size].astype(bool)

    # ---- forced-continuation queries (speculation / jump-forward) ------
    # The spec subsystem (repro.spec.jump) asks, per step, "how many
    # tokens survive this step's mask union, and which one if exactly
    # one?" — popcount + sole-survivor extraction on the packed rows,
    # without ever materializing the [V] boolean mask.

    def row_popcounts(self) -> np.ndarray:
        """[rows] int32 allowed-token count per packed row. Precomputed
        at build time and shipped in the v4 cache; raw constructions
        compute it once here. The jump-forward analyzer uses it as a
        short-circuit: the union of a row set can only collapse to <= 1
        token if every member row already allows <= 1, so per-step
        forced detection is a gather + max instead of a mask union."""
        if self._row_pc is None:
            self._row_pc = compute_row_popcounts(self.packed)
        return self._row_pc

    @staticmethod
    def popcount_packed(packed: np.ndarray) -> int:
        """Allowed-token count of an already-unioned packed row. Padding
        bits past vocab_size are zero by construction, so a plain bit
        count over the packed words is exact."""
        return int(np.unpackbits(packed.view(np.uint8)).sum())

    @staticmethod
    def sole_from_packed(packed: np.ndarray):
        """Single allowed token id of an already-unioned packed row, or
        None when the popcount is not exactly 1."""
        nz = np.nonzero(packed)[0]
        if nz.size != 1:
            return None
        word = int(packed[nz[0]])
        if word & (word - 1):               # more than one bit in the word
            return None
        return int(nz[0]) * 32 + word.bit_length() - 1

    def union_popcount(self, rows) -> int:
        """Number of vocabulary tokens allowed by the OR of `rows`."""
        return self.popcount_packed(self.union_rows(rows))

    def allowed_first_bytes(self, packed_union: np.ndarray) -> np.ndarray:
        """[256] bool: byte c is True iff some token allowed by the packed
        union starts with c. When exactly one byte survives, EVERY valid
        tokenization of the continuation begins with it — the byte is
        grammar-FORCED even though several tokens (prefix-nested merges)
        remain in the mask. The jump-forward analyzer chains this to
        recover forced literal byte-strings that token-level popcount
        misses. [256, words] first-byte bitmasks precomputed at build
        time (computed once here on raw constructions), one AND per
        query."""
        if self._fb is None:
            self._fb = compute_first_byte_table(self.tokenizer,
                                                self.packed.shape[1])
        return (self._fb & packed_union[None, :]).any(axis=1)

    def sole_survivor(self, rows):
        """If exactly one token survives the union of `rows`, return its
        id; else None."""
        return self.sole_from_packed(self.union_rows(rows))

    @property
    def num_rows(self):
        return self.packed.shape[0]

    @property
    def num_words(self):
        return self.packed.shape[1]

    def nbytes(self):
        return self.packed.nbytes


def _fingerprint(grammar: Grammar, tok: ByteTokenizer) -> str:
    h = hashlib.sha256()
    # layout version + packed-word geometry first: a cache produced by an
    # older packed layout must not fingerprint-match (it would load as
    # wrong masks — soundness, not just staleness)
    words = (tok.vocab_size + 31) // 32
    # ":ctxsplit" folds the context-split classification into the
    # fingerprint explicitly (beyond the version bump): any change to
    # how CI/CD tables are derived must miss stale caches
    h.update(f"layout{STORE_LAYOUT_VERSION}:uint32le:w{words}"
             f":ctxsplit2-t{CD_ROW_THRESHOLD}".encode())
    h.update(grammar.name.encode())
    for t in grammar.terminal_names:
        h.update(t.encode())
        h.update(grammar.terminals[t].dfa.trans.tobytes())
        h.update(grammar.terminals[t].dfa.finals.tobytes())
    h.update(str(tok.vocab_size).encode())
    # hash EVERY token, length-prefixed: two vocabs sharing a prefix and
    # total byte length must not collide onto the same cached store
    for b in tok.id_to_bytes:
        h.update(len(b).to_bytes(4, "little"))
        h.update(b)
    return h.hexdigest()[:16]


class _Prep:
    """Shared per-(grammar, vocab) precomputation reused by every shard:
    the padded token byte-matrix and the packed suffix-pmatch tables for
    both row families."""
    __slots__ = ("V", "L", "T", "tok_len", "nonempty", "terms", "G",
                 "stride", "lanes", "S_bits", "Ss_bits")


def _prep(grammar: Grammar, tokenizer: ByteTokenizer) -> _Prep:
    p = _Prep()
    V = p.V = tokenizer.vocab_size
    toks = tokenizer.token_bytes()
    L = p.L = max(1, max(len(b) for b in toks))
    if L + 1 > 64:
        raise ValueError("token length > 63 unsupported by packed build")
    T = p.T = np.zeros((V, L), dtype=np.int32)
    tok_len = p.tok_len = np.zeros(V, dtype=np.int32)
    for i, b in enumerate(toks):
        tok_len[i] = len(b)
        if b:
            T[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    # special tokens (len 0) must never be "valid": we make their rows 0
    nonempty = p.nonempty = tok_len > 0

    terms = p.terms = grammar.terminal_names
    G = p.G = len(terms)
    p.stride = G + 1

    # ---- per-terminal suffix pmatch tables S[g, v, i]:
    #   mask family   — dmatch(t[i:], start(τ_g), ()) (end live OR a
    #                   proper prefix of the suffix lands in F)
    #   strict family — the suffix's ENTIRE walk stays live (no
    #                   overshoot past a terminal boundary)
    # for i in 0..L (i > len -> False). Packed over the split index i
    # into uint64 bit-lanes so the per-state M1 computation is a single
    # AND+nonzero over [G, V] (instead of a [G, V, L] reduction) —
    # TPU-thinking applied to the host build.
    S = np.zeros((G, V, L + 1), dtype=bool)
    Ss = np.zeros((G, V, L + 1), dtype=bool)
    for g, name in enumerate(terms):
        dfa = grammar.terminals[name].dfa
        trans, finals, live = dfa.trans, dfa.finals, dfa.live
        # suffix walk: for each start position i, walk from q0 over
        # t[i:]; each walk is <= L steps over [V] vectors.
        for i in range(L + 1):
            ok = tok_len >= i
            st = np.full(V, dfa.start, dtype=np.int32)
            hitF = np.zeros(V, dtype=bool)   # F hit strictly before suffix end
            for j in range(i, L):
                act = j < tok_len
                hitF |= ok & act & finals[st]       # prefix ending at j (proper)
                st_new = trans[st, T[:, j]]
                st = np.where(act, st_new, st)
            end_live = live[st]
            base_ok = ok & nonempty
            # mask: dmatch(suffix, q0, ()) = end live (cond1) or
            # proper-prefix in F (cond2); strict: end live only
            S[g, :, i] = base_ok & (end_live | hitF)
            Ss[g, :, i] = base_ok & end_live
            # note: empty suffix (i == len): cond1 with ε -> q0 live == True
            isempty = (tok_len == i) & live[dfa.start]
            S[g, :, i] |= isempty
            Ss[g, :, i] |= isempty
        # tokens shorter than i already masked by ok

    # bit-pack S over the split axis: S_bits[g, v] bit i <-> S[g, v, i]
    lanes = p.lanes = (np.uint64(1) << np.arange(L + 1, dtype=np.uint64))
    p.S_bits = (S.astype(np.uint64) * lanes[None, None, :]).sum(
        axis=2, dtype=np.uint64)
    p.Ss_bits = (Ss.astype(np.uint64) * lanes[None, None, :]).sum(
        axis=2, dtype=np.uint64)
    return p


def _pack_rows(rows: np.ndarray, V: int) -> np.ndarray:
    """[rows, V] bool -> [rows, W] uint32, little-endian bit-words."""
    Wbits = ((V + 31) // 32) * 32
    padded = np.zeros((rows.shape[0], Wbits), dtype=bool)
    padded[:, :V] = rows
    packed = np.packbits(padded, axis=1, bitorder="little")
    return np.ascontiguousarray(packed).view(np.uint32)


def build_rows_shard(grammar: Grammar, tokenizer: ByteTokenizer,
                     lo: int, hi: int, prep: _Prep | None = None):
    """Packed rows + context split for the global DFA states [lo, hi).

    Returns (mask_packed, strict_packed, split), the packed halves
    uint32 of shape [(hi-lo)·stride, W] and `split` the shard-local
    (cd_ptr, cd_token, cd_follow) from `derive_context_split`. Shards
    concatenated in global-state order reproduce the full store (and
    its CI/CD tables) bit-for-bit regardless of how the range
    [0, total_dfa_states) was split — the parallel offline builder
    relies on this.
    """
    p = prep if prep is not None else _prep(grammar, tokenizer)
    V, L, T, tok_len = p.V, p.L, p.T, p.tok_len
    nonempty, G, stride, lanes = p.nonempty, p.G, p.stride, p.lanes
    n = hi - lo
    mask_rows = np.zeros((n * stride, V), dtype=bool)
    strict_rows = np.zeros((n * stride, V), dtype=bool)
    pos = np.arange(L + 1)[None, :]
    for name in p.terms:
        dfa = grammar.terminals[name].dfa
        trans, finals, live = dfa.trans, dfa.finals, dfa.live
        off = grammar.state_offset[name]
        for q in range(max(0, lo - off), min(dfa.num_states, hi - off)):
            if not live[q]:
                continue  # dead-state rows stay all-zero (never queried)
            st = np.full(V, q, dtype=np.int32)
            # hitF_at[v, i]: state after consuming t[:i] is in F  (i=0..L)
            hitF_at = np.zeros((V, L + 1), dtype=bool)
            hitF_at[:, 0] = finals[q]
            for j in range(L):
                act = j < tok_len
                st_new = trans[st, T[:, j]]
                st = np.where(act, st_new, st)
                hitF_at[:, j + 1] = act & finals[st]
            end_live = live[st] & nonempty
            proper = hitF_at & (pos < tok_len[:, None])   # strict prefix in F
            anyF = hitF_at & (pos <= tok_len[:, None])    # any prefix incl. full
            anyF_bits = (anyF.astype(np.uint64) *
                         lanes[None, :]).sum(axis=1, dtype=np.uint64)
            r0 = (off + q - lo) * stride
            # mask M0: cond1 | cond2; strict M0: cond1 only (the token
            # must stay inside the current terminal)
            mask_rows[r0] = end_live | proper.any(axis=1)
            strict_rows[r0] = end_live
            # M1[τ']: cond1 | (split in F and suffix pmatches τ'), with
            # the family's own suffix table
            m1 = (p.S_bits & anyF_bits[None, :]) != 0
            m1s = (p.Ss_bits & anyF_bits[None, :]) != 0
            mask_rows[r0 + 1: r0 + 1 + G] = m1 | end_live
            strict_rows[r0 + 1: r0 + 1 + G] = m1s | end_live

    # never allow specials through the grammar mask (EOS handled separately)
    mask_rows[:, ~nonempty] = False
    strict_rows[:, ~nonempty] = False
    mask_packed = _pack_rows(mask_rows, V)
    strict_packed = _pack_rows(strict_rows, V)
    # the shard may not contain the terminals' start states, so the
    # pmatch-from-start rows come from the prep suffix tables (bit 0 =
    # split position 0); identical to the start-state rows of the full
    # store — tests assert the identity
    pm0_mask = _pack_rows(
        ((p.S_bits & np.uint64(1)) != 0) & nonempty[None, :], V)
    pm0_strict = _pack_rows(
        ((p.Ss_bits & np.uint64(1)) != 0) & nonempty[None, :], V)
    split = derive_context_split(
        mask_packed, strict_packed, stride, V,
        compute_state_finals(grammar, lo, hi), pm0_mask, pm0_strict)
    return mask_packed, strict_packed, split


def assemble_store(grammar: Grammar, tokenizer: ByteTokenizer, parts,
                   cache_dir: str | None = None, verbose: bool = False,
                   t0: float | None = None) -> MaskStore:
    """Concatenate shard outputs (in global-state order, covering the
    whole state space) into the [2R, W] packed array plus the global
    context-split / popcount / first-byte tables, and publish it all
    atomically through the disk cache."""
    fp = _fingerprint(grammar, tokenizer)
    stride = len(grammar.terminal_names) + 1
    packed = np.concatenate([part[0] for part in parts] +
                            [part[1] for part in parts], axis=0)
    if any(len(part) < 3 for part in parts):
        # legacy 2-tuple parts (tests, old pickles): derive globally —
        # the full packed array carries the start-state rows
        pm0_mask, pm0_strict = pm0_rows_from_packed(grammar, packed, stride)
        split = derive_context_split(
            packed[:packed.shape[0] // 2], packed[packed.shape[0] // 2:],
            stride, tokenizer.vocab_size,
            compute_state_finals(grammar), pm0_mask, pm0_strict)
    else:
        split = _concat_context_splits([part[2] for part in parts], stride)
    cd_ptr, cd_token, cd_follow, cd_big = split
    row_pc = compute_row_popcounts(packed)
    fb = compute_first_byte_table(tokenizer, packed.shape[1])
    per_state = np.diff(cd_ptr)
    meta = {
        "build_seconds": time.time() - (t0 if t0 is not None else time.time()),
        "rows": int(packed.shape[0]),
        "bytes": int(packed.nbytes),
        "grammar": grammar.name,
        "vocab": tokenizer.vocab_size,
        "cached": False,
        # context-split shape: total residue entries, the worst
        # per-(family, state) residue as a fraction of the vocab (the
        # "almost everything is precomputable" claim, measured), and how
        # many (state, follow) rows fell back to whole-row gathers
        "cd_entries": int(cd_token.shape[0]),
        "cd_max_tokens": int(per_state.max()) if per_state.size else 0,
        "cd_max_frac": (float(per_state.max()) / tokenizer.vocab_size
                        if per_state.size else 0.0),
        "cd_big_rows": int(compute_row_popcounts(
            cd_big.view(np.uint32)).sum()) if cd_big.size else 0,
    }
    if verbose:
        print(f"[mask_store] {grammar.name}: {meta['rows']} rows x "
              f"{packed.shape[1]} words, {meta['bytes']/1e6:.1f} MB, "
              f"cd_max {meta['cd_max_tokens']}/{tokenizer.vocab_size} "
              f"tok, {meta['build_seconds']:.1f}s")
    if cache_dir:
        os.makedirs(cache_dir, exist_ok=True)
        path = os.path.join(cache_dir, f"maskstore_{grammar.name}_{fp}.npz")
        # atomic publish, safe under concurrent multi-process (and
        # multi-thread) builds: mkstemp gives each writer a private
        # temp file in the SAME directory (os.replace must not cross
        # filesystems), the pid in the prefix aids debugging, and
        # os.replace atomically publishes — concurrent builders race
        # benignly (last writer wins, all write identical bytes) and
        # readers never see a torn .npz. The unlink is tolerant: the
        # temp name is private, so ENOENT can only mean our own
        # os.replace already consumed it.
        fd, tmp = tempfile.mkstemp(
            dir=cache_dir,
            prefix=f".maskstore_{grammar.name}_{fp}.{os.getpid()}.")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez_compressed(f, packed=packed, cd_ptr=cd_ptr,
                                    cd_token=cd_token, cd_follow=cd_follow,
                                    cd_big=cd_big, row_pc=row_pc, fb=fb)
            os.replace(tmp, path)
        finally:
            try:
                os.remove(tmp)
            except OSError:
                pass
        meta["path"] = path
    return MaskStore(grammar, tokenizer, packed, meta, split=split,
                     row_pc=row_pc, fb=fb)


def load_cached_store(grammar: Grammar, tokenizer: ByteTokenizer,
                      cache_dir: str | None) -> "MaskStore | None":
    """The cache-hit path, shared by the serial and parallel builders."""
    if not cache_dir:
        return None
    fp = _fingerprint(grammar, tokenizer)
    path = os.path.join(cache_dir, f"maskstore_{grammar.name}_{fp}.npz")
    if not os.path.exists(path):
        return None
    z = np.load(path)
    # the v4 fingerprint guarantees the split tables are present; the
    # guard keeps a hand-rolled npz (tests) loadable by re-deriving
    split = ((z["cd_ptr"], z["cd_token"], z["cd_follow"], z["cd_big"])
             if "cd_big" in z.files else None)
    return MaskStore(grammar, tokenizer, z["packed"],
                     {"cached": True, "path": path}, split=split,
                     row_pc=z["row_pc"] if "row_pc" in z.files else None,
                     fb=z["fb"] if "fb" in z.files else None)


def build_mask_store(grammar: Grammar, tokenizer: ByteTokenizer,
                     cache_dir: str | None = None,
                     verbose: bool = False) -> MaskStore:
    cached = load_cached_store(grammar, tokenizer, cache_dir)
    if cached is not None:
        return cached
    t0 = time.time()
    prep = _prep(grammar, tokenizer)
    part = build_rows_shard(grammar, tokenizer, 0,
                            grammar.total_dfa_states, prep)
    return assemble_store(grammar, tokenizer, [part],
                          cache_dir=cache_dir, verbose=verbose, t0=t0)
