"""Online grammar-mask computation (paper Algorithm 2 + §4.3).

Per decoding step, the CPU side is O(|A|·len(r) + |A|) — walk the first
terminal's DFA on the remainder r for each accept sequence, then emit the
mask-store *row ids*. The expensive part — unioning |A| vocabulary masks
and applying them to the logits — runs on the accelerator
(`repro.kernels.masked_logits`, the paper's GPU-offload adapted to TPU).

`GrammarConstraint` also implements the paper's *opportunistic masking*
(§5 Baselines, Beurer-Kellner et al. 2024): first let the model propose a
token, and only compute the full mask if the proposal is syntactically
invalid.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .grammar import Grammar
from .lexer import LexError
from .lr import LRTable
from .mask_store import MaskStore
from .parser import IncrementalParser, ParseError
from .tokenizer import ByteTokenizer, EOS_ID


@dataclass
class StepMask:
    """Host-side result for one sequence at one decoding step."""
    rows: np.ndarray          # [max_accept] int32 row ids into the store, -1 pad
    eos_allowed: bool
    num_sequences: int        # |A| before dedup/capping (diagnostics)


class GrammarConstraint:
    """Per-sequence constrained-decoding state (owns an incremental parser)."""

    def __init__(self, grammar: Grammar, table: LRTable, store: MaskStore,
                 tokenizer: ByteTokenizer, max_accept: int = 48):
        self.grammar = grammar
        self.store = store
        self.tokenizer = tokenizer
        self.parser = IncrementalParser(grammar, table)
        self.max_accept = max_accept
        self._stride = store.row_stride

    def reset(self):
        self.parser.reset_cache()

    # ---- Algorithm 2 (host part): accept sequences + r -> store row ids --

    def step_rows(self, partial_output: bytes) -> StepMask:
        res = self.parser.partial_parse(partial_output)
        r = res.remainder
        rows: list[int] = []
        seen = set()
        for seq in res.accept_sequences:
            t1 = seq[0]
            term = self.grammar.terminals[t1]
            dfa = term.dfa
            q = dfa.walk_live(dfa.start, r)
            if not dfa.live[q]:
                continue
            base = (self.grammar.state_offset[t1] + q) * self._stride
            if len(seq) == 1:
                rid = base
            else:
                rid = base + 1 + self.grammar.term_id[seq[1]]
            if rid not in seen:
                seen.add(rid)
                rows.append(rid)
        arr = np.full(self.max_accept, -1, dtype=np.int32)
        n = min(len(rows), self.max_accept)
        arr[:n] = rows[:n]
        return StepMask(rows=arr, eos_allowed=res.eos_allowed,
                        num_sequences=len(res.accept_sequences))

    # ---- host reference mask (numpy; the device path lives in kernels/) --

    def token_mask(self, partial_output: bytes) -> np.ndarray:
        """Full boolean vocab mask (reference / tests / CPU serving)."""
        sm = self.step_rows(partial_output)
        packed = self.store.union_rows(sm.rows)
        mask = self.store.unpack(packed)
        if sm.eos_allowed:
            mask[EOS_ID] = True
        return mask

    # ---- validity oracle (used by tests and opportunistic masking) ------

    def is_valid_extension(self, partial_output: bytes, token_id: int) -> bool:
        """partial_output + token stays in L_p(G)?

        Never over-approximates (safe for the opportunistic fast path):
        the parse must succeed AND the remainder must still be a viable
        prefix of some *acceptable* terminal. It may under-approximate in
        the rare case where the final lexical token's type must change in
        the future — then the caller just falls back to the mask.
        """
        if token_id == EOS_ID:
            return self.parser.partial_parse(partial_output).eos_allowed
        tb = self.tokenizer.id_to_bytes[token_id]
        if not tb:
            return False
        try:
            res = self.parser.partial_parse(partial_output + tb,
                                            incremental=False)
        except (ParseError, LexError):
            return False
        if not res.remainder:
            return True
        for seq in res.accept_sequences:
            dfa = self.grammar.terminals[seq[0]].dfa
            q = dfa.walk_live(dfa.start, res.remainder)
            if dfa.live[q]:
                return True
        return False
