"""Online grammar-mask computation (paper Algorithm 2 + §4.3).

Per decoding step, the CPU side is O(|A|·len(r) + |A|) — walk the first
terminal's DFA on the remainder r for each accept sequence, then emit the
mask-store *row ids*. The expensive part — unioning |A| vocabulary masks
and applying them to the logits — runs on the accelerator
(`repro.kernels.masked_logits`, the paper's GPU-offload adapted to TPU).

`GrammarConstraint` also implements the paper's *opportunistic masking*
(§5 Baselines, Beurer-Kellner et al. 2024): first let the model propose a
token, and only compute the full mask if the proposal is syntactically
invalid.

Two mask modes select between the store's row families
(docs/grammars.md): `grammar_mask` (default — the paper's sound
overapproximation) and `grammar_strict` (terminal-boundary-aligned
underapproximation; strict ⊆ mask bitwise). The mode is a single row-id
offset added in `step_rows`; everything downstream is mode-oblivious.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .grammar import Grammar
from .lexer import LexError
from .lr import LRTable
from .mask_store import MaskStore
from .parser import IncrementalParser, ParseError
from .tokenizer import ByteTokenizer, EOS_ID


# base accept-sequence width: the batched engine's [B, A] row matrix uses
# one A for every slot, so the default lives here rather than per-call.
# This is a PADDING bucket, never a cap — steps whose accept set overflows
# it get a wider (power-of-two multiple) row vector, so the mask is always
# the union of EVERY accept sequence (paper soundness; a silent cap here
# over-constrains the mask and bans grammar-valid tokens).
MAX_ACCEPT = 48


def accept_width(n_rows: int, base: int = MAX_ACCEPT) -> int:
    """Smallest power-of-two multiple of `base` holding n_rows rows.

    Row vectors/matrices are padded to these buckets so the jitted fused
    mask+sample call specializes once per bucket (wide accept sets are
    rare) instead of once per distinct row count."""
    a = max(1, int(base))
    while a < n_rows:
        a *= 2
    return a


@dataclass
class StepMask:
    """Host-side result for one sequence at one decoding step."""
    rows: np.ndarray          # [>= max_accept] int32 store row ids, -1 pad
                              # (width grows in accept_width buckets; the
                              # valid prefix covers ALL accept sequences)
    eos_allowed: bool
    num_sequences: int        # |A| before dedup (diagnostics)


class GrammarConstraint:
    """Per-sequence constrained-decoding state (owns an incremental parser)."""

    MODES = ("grammar_mask", "grammar_strict")

    def __init__(self, grammar: Grammar, table: LRTable, store: MaskStore,
                 tokenizer: ByteTokenizer, max_accept: int = MAX_ACCEPT,
                 mode: str = "grammar_mask"):
        if mode not in self.MODES:
            raise ValueError(f"unknown grammar mode {mode!r}; "
                             f"expected one of {self.MODES}")
        self.grammar = grammar
        self.store = store
        self.tokenizer = tokenizer
        self.parser = IncrementalParser(grammar, table)
        self.max_accept = max_accept
        self.mode = mode
        self._stride = store.row_stride
        # the two approximation families share state addressing; the mode
        # only selects which half of the packed store the row ids hit, so
        # everything downstream (batched row matrices, the device union
        # kernel, jump-forward popcounts) is mode-oblivious
        self._mode_offset = (store.strict_offset
                             if mode == "grammar_strict" else 0)

    def reset(self):
        self.parser.reset_cache()

    # ---- Algorithm 2 (host part): accept sequences + r -> store row ids --

    def step_rows(self, partial_output: bytes) -> StepMask:
        res = self.parser.partial_parse(partial_output)
        r = res.remainder
        rows: list[int] = []
        seen = set()
        for seq in res.accept_sequences:
            t1 = seq[0]
            term = self.grammar.terminals[t1]
            dfa = term.dfa
            q = dfa.walk_live(dfa.start, r)
            if not dfa.live[q]:
                continue
            base = ((self.grammar.state_offset[t1] + q) * self._stride
                    + self._mode_offset)
            if len(seq) == 1:
                rid = base
            else:
                rid = base + 1 + self.grammar.term_id[seq[1]]
            if rid not in seen:
                seen.add(rid)
                rows.append(rid)
        arr = np.full(accept_width(len(rows), self.max_accept), -1,
                      dtype=np.int32)
        arr[:len(rows)] = rows
        return StepMask(rows=arr, eos_allowed=res.eos_allowed,
                        num_sequences=len(res.accept_sequences))

    # ---- batched host side of Algorithm 2 (one row matrix per step) -----

    @staticmethod
    def step_rows_batch(constraints, texts, max_accept: int = MAX_ACCEPT,
                        row_offsets=None):
        """Fill the batched engine's per-step mask inputs in one pass.

        constraints: length-B list of GrammarConstraint or None (None =
        unconstrained slot -> all-pad rows, eos False). texts: length-B
        list of partial outputs (bytes). row_offsets: optional [B] int
        offsets shifting each slot's row ids into a store concatenated
        across grammars (the engine keeps one device array for all
        grammars; a slot's rows index its grammar's block).

        Returns (rows [B, A] int32 with -1 pad, eos_allowed [B] bool,
        num_sequences [B] int32). `max_accept` is the BASE width of A:
        when some slot's accept set overflows it, A grows to the next
        accept_width bucket so no row is ever dropped (soundness).
        """
        B = len(constraints)
        sms = [gc.step_rows(texts[b]) if gc is not None else None
               for b, gc in enumerate(constraints)]
        A = max([max_accept] + [sm.rows.shape[0] for sm in sms
                                if sm is not None])
        rows = np.full((B, A), -1, dtype=np.int32)
        eos = np.zeros(B, dtype=bool)
        nseq = np.zeros(B, dtype=np.int32)
        for b, sm in enumerate(sms):
            if sm is None:
                continue
            r = sm.rows
            if row_offsets is not None:
                r = np.where(r >= 0, r + int(row_offsets[b]), r)
            rows[b, :r.shape[0]] = r
            eos[b] = sm.eos_allowed
            nseq[b] = sm.num_sequences
        return rows, eos, nseq

    # ---- forced-continuation query (speculation / jump-forward) ---------

    def forced_step(self, partial_output: bytes):
        """Classify this step's mask for the jump-forward analyzer.

        Returns (kind, token, step_mask):
          ("token", t, sm) — exactly one token survives the mask union,
                         EOS is not allowed, and t passes the exact
                         oracle: the grammar (as seen through this step's
                         capped row set — the same rows the engine masks
                         with) forces t, so it can be emitted without a
                         model call.
          ("eos", None, sm)  — mask empty but C_k ∈ L(G): EOS is forced.
          ("dead", None, sm) — mask empty and EOS disallowed (the
                         engine's mask_exhausted outcome).
          ("free", None, sm) — more than one candidate; the model must
                         choose. The returned StepMask is this step's row
                         set, so the caller can mask without recomputing.

        Fast path: the union can only collapse to <= 1 token if every
        member row allows <= 1, so a precomputed per-row popcount gather
        decides "free" without touching the packed words.
        """
        sm = self.step_rows(partial_output)
        valid = sm.rows[sm.rows >= 0]
        if valid.size and int(self.store.row_popcounts()[valid].max()) > 1:
            return ("free", None, sm)
        packed = self.store.union_rows(sm.rows)     # one union feeds both
        n = self.store.popcount_packed(packed)
        if n == 0:
            return (("eos", None, sm) if sm.eos_allowed
                    else ("dead", None, sm))
        if n == 1 and not sm.eos_allowed:
            t = self.store.sole_from_packed(packed)
            if t is not None and self.is_valid_extension(partial_output, t):
                return ("token", t, sm)
            # sole candidate is a mask over-approximation the oracle
            # rejects: the exact allowed set is empty (matches the plain
            # engine's demote -> exhausted path)
            return ("dead", None, sm)
        return ("free", None, sm)

    # ---- host reference mask (numpy; the device path lives in kernels/) --

    def token_mask(self, partial_output: bytes) -> np.ndarray:
        """Full boolean vocab mask (reference / tests / CPU serving)."""
        sm = self.step_rows(partial_output)
        packed = self.store.union_rows(sm.rows)
        mask = self.store.unpack(packed)
        if sm.eos_allowed:
            mask[EOS_ID] = True
        return mask

    # ---- validity oracle (used by tests and opportunistic masking) ------

    def is_valid_extension(self, partial_output: bytes, token_id: int) -> bool:
        """partial_output + token stays in L_p(G)?

        Never over-approximates (safe for the opportunistic fast path):
        the parse must succeed AND the remainder must still be a viable
        prefix of some *acceptable* terminal. It may under-approximate in
        the rare case where the final lexical token's type must change in
        the future — then the caller just falls back to the mask.
        """
        if token_id == EOS_ID:
            return self.parser.partial_parse(partial_output).eos_allowed
        tb = self.tokenizer.id_to_bytes[token_id]
        if not tb:
            return False
        try:
            # incremental: the prefix-stack cache makes the hypothetical
            # extension O(delta); a rejected hypothesis merely truncates
            # the cache back on the next prefix-diverging call
            res = self.parser.partial_parse(partial_output + tb)
        except (ParseError, LexError):
            return False
        if not res.remainder:
            return True
        if res.eos_allowed:
            # the extended text is itself a complete sentence (exact:
            # eos_allowed shifts the final token and checks acceptance).
            # Without this, a grammar with NO ignore terminals rejected
            # the token that exactly completes the sentence — the accept
            # sequences only describe CONTINUATIONS of the remainder
            return True
        for seq in res.accept_sequences:
            dfa = self.grammar.terminals[seq[0]].dfa
            q = dfa.walk_live(dfa.start, res.remainder)
            if dfa.live[q]:
                return True
        return False
