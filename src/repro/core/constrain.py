"""Online grammar-mask computation (paper Algorithm 2 + §4.3), on top of
the context-split mask store (docs/architecture.md).

Per decoding step the host no longer unions accept-row sets. It:

  1. groups the accept sequences by first terminal τ1 — walks τ1's DFA
     on the remainder ONCE per live terminal (not once per sequence)
     and ORs each sequence into a per-state accept-bits word (bit 0 =
     the length-1 α=0 sequence, bit 1+tid(τ2) = follow terminal τ2);
  2. emits precomputed store ROW IDS for everything the offline
     classification resolved: the group's base row (family M0 when the
     α=0 bit is set, else the shared CI row), the follow terminals'
     start-state rows when the walk landed in F (position-0 splits),
     and the legacy M1 rows the classifier marked big (`cd_big`);
  3. overlays the remaining context-dependent residue — a few tokens
     per step on the builtin grammars — as a packed [W] uint32 word
     vector scatter from the store's `cd_token`/`cd_follow` tables.

The union of (rows ∪ residue words) is BITWISE equal to the legacy
full accept-row union (tests/test_context_split.py fuzzes this), so
token-for-token output identity holds in every serving mode; only
*where* the bits come from changed. The expensive part — ORing the
rows and applying mask+sample to the logits — runs on the accelerator
(`repro.kernels.fused_select`, the paper's GPU-offload adapted to TPU).

`GrammarConstraint` also implements the paper's *opportunistic masking*
(§5 Baselines, Beurer-Kellner et al. 2024): first let the model propose a
token, and only compute the full mask if the proposal is syntactically
invalid.

Two mask modes select between the store's row families
(docs/grammars.md): `grammar_mask` (default — the paper's sound
overapproximation) and `grammar_strict` (terminal-boundary-aligned
underapproximation; strict ⊆ mask bitwise). Both families SHARE the
context-independent rows; the mode picks the family's M0/M1 rows and
which half of the residue tables applies.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .grammar import Grammar
from .lexer import LexError
from .lr import LRTable
from .mask_store import MaskStore
from .parser import IncrementalParser, ParseError
from .tokenizer import ByteTokenizer, EOS_ID


# base accept-row width: the batched engine's [B, A] row matrix uses
# one A for every slot, so the default lives here rather than per-call.
# This is a PADDING bucket, never a cap — steps whose row set overflows
# it get a wider (power-of-two multiple) row vector, so the mask always
# covers EVERY accept sequence (paper soundness; a silent cap here
# over-constrains the mask and bans grammar-valid tokens). With the
# context split the emitted rows are deduplicated per group, so typical
# steps use a handful of rows and the bucket rarely grows.
MAX_ACCEPT = 48


def accept_width(n_rows: int, base: int = MAX_ACCEPT) -> int:
    """Smallest power-of-two multiple of `base` holding n_rows rows.

    Row vectors/matrices are padded to these buckets so the jitted fused
    mask+sample call specializes once per bucket (wide accept sets are
    rare) instead of once per distinct row count."""
    a = max(1, int(base))
    while a < n_rows:
        a *= 2
    return a


@dataclass
class StepGroups:
    """Result of the accept-sequence grouping (the ci_lookup stage)."""
    groups: dict                 # global DFA state -> accept-bits int
    eos_allowed: bool
    num_sequences: int           # |A| before grouping (diagnostics)


@dataclass
class StepMask:
    """Host-side result for one sequence at one decoding step."""
    rows: np.ndarray          # [>= max_accept] int32 store row ids, -1 pad
                              # (width grows in accept_width buckets; the
                              # valid prefix + cd_words cover ALL accept
                              # sequences)
    eos_allowed: bool
    num_sequences: int        # |A| before grouping (diagnostics)
    cd_words: np.ndarray = field(default=None, repr=False)
                              # [W] uint32 context-dependent residue
                              # overlay, ORed into the row union on
                              # device (None == all-zero)


class GrammarConstraint:
    """Per-sequence constrained-decoding state (owns an incremental parser)."""

    MODES = ("grammar_mask", "grammar_strict")

    def __init__(self, grammar: Grammar, table: LRTable, store: MaskStore,
                 tokenizer: ByteTokenizer, max_accept: int = MAX_ACCEPT,
                 mode: str = "grammar_mask"):
        if mode not in self.MODES:
            raise ValueError(f"unknown grammar mode {mode!r}; "
                             f"expected one of {self.MODES}")
        self.grammar = grammar
        self.store = store
        self.tokenizer = tokenizer
        self.parser = IncrementalParser(grammar, table)
        self.max_accept = max_accept
        self.mode = mode
        self._stride = store.row_stride
        # the two approximation families share state addressing AND the
        # context-independent rows; the family index only selects the
        # M0/M1 half of the packed store and the residue-table half, so
        # everything downstream (batched row matrices, the device union
        # kernel, jump-forward popcounts) is mode-oblivious
        self._fam = 1 if mode == "grammar_strict" else 0
        # persistent per-step residue caches: the parser returns the
        # SAME accept_sequences object while the stack configuration
        # repeats (its seq memo), so the per-first-terminal walk plan,
        # the row-id emission, and the residue overlay all collapse to
        # dict hits on consecutive decode steps. All values are shared
        # read-only; keys are pure functions of the inputs.
        self._plan_memo: dict[int, tuple] = {}
        self._sg_memo: dict[tuple, tuple] = {}
        self._sg_last: "tuple | None" = None
        self._rows_memo: dict[tuple, list] = {}
        self._rows_fast: dict[int, tuple] = {}
        self._arr_fast: dict[tuple, tuple] = {}
        self._cd_fast: dict[int, tuple] = {}
        self._cd_memo: dict[tuple, "np.ndarray | None"] = {}
        # whole-batch result memos (hosted on the batch's first live
        # constraint): while every slot's walk states are saturated the
        # assembled [B, A] row matrix / [B, W] residue matrix repeat
        # verbatim, so the batch stages return the SAME arrays — callers
        # (the engine dispatch) treat them as read-only.
        self._batch_memo: dict[tuple, tuple] = {}
        self._cd_batch_memo: dict[tuple, tuple] = {}
        # incremental remainder walk: (plan entry, remainder, states).
        # walk(start, r) restarts from the previous step's states when r
        # only grew — the common case while a lexeme is being extended —
        # so each step walks O(|delta|) bytes, not O(|r|).
        self._walk: "tuple | None" = None

    _MEMO_CAP = 1 << 12

    def reset(self):
        self.parser.reset_cache()
        self._walk = None

    # ---- Algorithm 2 (host part), stage 1: accept sequences -> groups --

    def step_groups(self, partial_output: bytes) -> StepGroups:
        """Parse + one DFA walk per live first-terminal: the accept
        sequences collapse into {global state: accept bits} (bit 0 =
        α=0 sequence present, bit 1+tid(τ2) = follow terminal τ2).

        The per-first-terminal plan (t1 -> OR of accept bits, in first-
        occurrence order) depends only on the accept_sequences object —
        which the parser's seq memo returns shared across steps — so it
        is cached by object identity; only the remainder walk (O(|r|)
        per distinct t1) runs every step."""
        res = self.parser.partial_parse(partial_output)
        r = res.remainder
        grammar = self.grammar
        seqs = res.accept_sequences
        ent = self._plan_memo.get(id(seqs))
        if ent is None or ent[0] is not seqs:
            bits_by_t1: dict[str, int] = {}
            term_id = grammar.term_id
            for seq in seqs:
                bit = (1 if len(seq) == 1
                       else 1 << (1 + term_id[seq[1]]))
                t1 = seq[0]
                bits_by_t1[t1] = bits_by_t1.get(t1, 0) | bit
            plan = [(grammar.terminals[t1].dfa, grammar.state_offset[t1],
                     bits) for t1, bits in bits_by_t1.items()]
            if len(self._plan_memo) >= self._MEMO_CAP:
                self._plan_memo.clear()
            # the seqs reference keeps the id() stable for the cache key
            ent = (seqs, plan)
            self._plan_memo[id(seqs)] = ent
        plan = ent[1]
        w = self._walk
        if w is not None and w[0] is ent and len(r) >= len(w[1]) \
                and r.startswith(w[1]):
            qs = w[2]
            delta = r[len(w[1]):]
            if delta:
                qs = [p[0].walk_live(q, delta)
                      for p, q in zip(plan, qs)]
        else:
            qs = [dfa.walk_live(dfa.start, r) for dfa, _off, _bits in plan]
        self._walk = (ent, r, qs)
        # share ONE groups dict per (plan, walk states): the walks
        # saturate inside a growing lexeme, so consecutive steps reuse
        # the same object — and the row/residue stages can then memoize
        # by object identity instead of re-hashing the contents.
        eos = res.eos_allowed
        last = self._sg_last
        if last is not None and last[0] is ent and qs == last[1] \
                and last[2].eos_allowed == eos:
            return last[2]
        skey = (id(ent), tuple(qs))
        hit = self._sg_memo.get(skey)
        if hit is not None and hit[0] is ent:
            sg = hit[1]
            if sg.eos_allowed != eos:
                sg = StepGroups(groups=sg.groups, eos_allowed=eos,
                                num_sequences=len(seqs))
                self._sg_memo[skey] = (ent, sg)
            self._sg_last = (ent, qs, sg)
            return sg
        groups: dict[int, int] = {}
        for i, (dfa, off, bits) in enumerate(plan):
            q = qs[i]
            if dfa.live[q]:
                groups[off + q] = bits
        sg = StepGroups(groups=groups, eos_allowed=eos,
                        num_sequences=len(seqs))
        if len(self._sg_memo) >= self._MEMO_CAP:
            self._sg_memo.clear()
        self._sg_memo[skey] = (ent, sg)
        self._sg_last = (ent, qs, sg)
        return sg

    # ---- stage 2: groups -> precomputed store row ids (ci_lookup) ------

    def group_rows(self, groups: dict) -> list:
        """Deduplicated store row ids covering everything the offline
        classification precomputed: base row (family M0 / shared CI),
        position-0 follow-split start rows, and big-residue M1 rows.
        Memoized on the groups signature (walk states saturate inside a
        growing lexeme, so consecutive steps repeat it); the returned
        list is shared and read-only."""
        fast = self._rows_fast.get(id(groups))
        if fast is not None and fast[0] is groups:
            return fast[1]
        gkey = tuple(groups.items())
        cached = self._rows_memo.get(gkey)
        if cached is not None:
            if len(self._rows_fast) >= self._MEMO_CAP:
                self._rows_fast.clear()
            self._rows_fast[id(groups)] = (groups, cached)
            return cached
        st = self.store
        fam = self._fam
        stride = self._stride
        fam_off = fam * st.strict_offset
        rows: list[int] = []
        seen = set()
        for s0, bits in groups.items():
            base = (fam_off + s0 * stride if bits & 1
                    else st.strict_offset + s0 * stride)
            if base not in seen:
                seen.add(base)
                rows.append(base)
            fbits = bits & ~1
            if not fbits:
                continue
            if st.state_finals[s0]:
                fb = fbits >> 1
                g = 0
                while fb:
                    if fb & 1:
                        rid = st.row_follow_start(fam, g)
                        if rid not in seen:
                            seen.add(rid)
                            rows.append(rid)
                    fb >>= 1
                    g += 1
            bigsel = st.cd_big_bits(fam, s0) & fbits
            while bigsel:
                j = bigsel.bit_length() - 1          # j = 1 + tid(τ_g)
                rid = fam_off + s0 * stride + j
                if rid not in seen:
                    seen.add(rid)
                    rows.append(rid)
                bigsel &= ~(1 << j)
        if len(self._rows_memo) >= self._MEMO_CAP:
            self._rows_memo.clear()
        self._rows_memo[gkey] = rows
        if len(self._rows_fast) >= self._MEMO_CAP:
            self._rows_fast.clear()
        self._rows_fast[id(groups)] = (groups, rows)
        return rows

    def _rows_array(self, rows: list, off: int) -> np.ndarray:
        """int32 view of a (shared, memoized) row-id list with the slot's
        store offset pre-added; cached per (row list, offset) since both
        repeat across steps. Read-only."""
        key = (id(rows), off)
        hit = self._arr_fast.get(key)
        if hit is not None and hit[0] is rows:
            return hit[1]
        arr = np.array(rows, dtype=np.int32)
        if off:
            arr += np.int32(off)
        if len(self._arr_fast) >= self._MEMO_CAP:
            self._arr_fast.clear()
        self._arr_fast[key] = (rows, arr)
        return arr

    # ---- stage 3: groups -> residue overlay words (cd_check) -----------

    def cd_overlay(self, groups: dict) -> np.ndarray | None:
        """[W] uint32 packed overlay of the context-dependent residue
        selected by the accept bits, or None when no residue token is
        selected (the common case on the builtin grammars). Memoized on
        the groups signature; callers copy the returned words, never
        mutate them."""
        fast = self._cd_fast.get(id(groups))
        if fast is not None and fast[0] is groups:
            return fast[1]
        gkey = tuple(groups.items())
        if gkey in self._cd_memo:
            out = self._cd_memo[gkey]
            if len(self._cd_fast) >= self._MEMO_CAP:
                self._cd_fast.clear()
            self._cd_fast[id(groups)] = (groups, out)
            return out
        st = self.store
        fam = self._fam
        out = None
        for s0, bits in groups.items():
            fbits = bits & ~1
            if not fbits:
                continue
            lo, hi = st.cd_range(fam, s0)
            if hi <= lo:
                continue
            fol = st.cd_follow[lo:hi]
            if st.follow_words == 1:
                sel = (fol[:, 0] & np.uint64(fbits)) != 0
            else:
                fw = np.array([(fbits >> (64 * k)) & 0xFFFFFFFFFFFFFFFF
                               for k in range(st.follow_words)],
                              dtype=np.uint64)
                sel = (fol & fw[None, :]).any(axis=1)
            if sel.any():
                if out is None:
                    out = np.zeros(st.num_words, dtype=np.uint32)
                np.bitwise_or.at(out, st.cd_word[lo:hi][sel],
                                 st.cd_bit[lo:hi][sel])
        if len(self._cd_memo) >= self._MEMO_CAP:
            self._cd_memo.clear()
        self._cd_memo[gkey] = out
        if len(self._cd_fast) >= self._MEMO_CAP:
            self._cd_fast.clear()
        self._cd_fast[id(groups)] = (groups, out)
        return out

    # ---- composed per-sequence step (sequential engine, tests) ---------

    def step_rows(self, partial_output: bytes) -> StepMask:
        sg = self.step_groups(partial_output)
        rows = self.group_rows(sg.groups)
        arr = np.full(accept_width(len(rows), self.max_accept), -1,
                      dtype=np.int32)
        arr[:len(rows)] = rows
        return StepMask(rows=arr, eos_allowed=sg.eos_allowed,
                        num_sequences=sg.num_sequences,
                        cd_words=self.cd_overlay(sg.groups))

    # ---- batched host side of Algorithm 2 (one row matrix per step) -----

    @staticmethod
    def ci_rows_batch(constraints, texts, max_accept: int = MAX_ACCEPT,
                      row_offsets=None):
        """The ci_lookup stage for a batch: parse, group, and emit the
        precomputed row ids per slot.

        constraints: length-B list of GrammarConstraint or None (None =
        unconstrained slot -> all-pad rows, eos False). texts: length-B
        list of partial outputs (bytes). row_offsets: optional [B] int
        offsets shifting each slot's row ids into a store concatenated
        across grammars (the engine keeps one device array for all
        grammars; a slot's rows index its grammar's block).

        Returns (rows [B, A] int32 with -1 pad, eos_allowed [B] bool,
        num_sequences [B] int32, groups_list length-B) — the groups are
        handed to `cd_overlay_batch` so the engine can time the residue
        stage separately. `max_accept` is the BASE width of A: when some
        slot's row set overflows it, A grows to the next accept_width
        bucket so no row is ever dropped (soundness)."""
        B = len(constraints)
        per_slot = []
        A = max_accept
        first = None
        for b, gc in enumerate(constraints):
            if gc is None:
                per_slot.append(None)
                continue
            if first is None:
                first = gc
            sg = gc.step_groups(texts[b])
            r = gc.group_rows(sg.groups)
            if len(r) > A:
                A = accept_width(len(r), max_accept)
            per_slot.append((sg, r))
        # whole-batch memo: same per-slot (groups, eos, offset) -> the
        # exact same output arrays (same groups => same rows => same A;
        # nseq is a function of the accept plan the groups came from).
        # id() keys are validated against kept references before use.
        klist = []
        for b, item in enumerate(per_slot):
            if item is None:
                klist.append(-1)
            else:
                klist.append(id(item[0].groups))
                klist.append(item[0].eos_allowed)
                klist.append(0 if row_offsets is None
                             else int(row_offsets[b]))
        key = tuple(klist)
        if first is not None:
            hit = first._batch_memo.get(key)
            if hit is not None:
                refs = hit[0]
                for b, item in enumerate(per_slot):
                    if item is None:
                        if refs[b] is not None:
                            hit = None
                            break
                    elif refs[b] is not item[0].groups:
                        hit = None
                        break
                if hit is not None:
                    return hit[1]
        rows = np.full((B, A), -1, dtype=np.int32)
        eos = np.zeros(B, dtype=bool)
        nseq = np.zeros(B, dtype=np.int32)
        groups_list = [None] * B
        for b, item in enumerate(per_slot):
            if item is None:
                continue
            sg, r = item
            off = int(row_offsets[b]) if row_offsets is not None else 0
            arr = constraints[b]._rows_array(r, off)
            rows[b, :arr.size] = arr
            eos[b] = sg.eos_allowed
            nseq[b] = sg.num_sequences
            groups_list[b] = sg.groups
        if first is not None:
            memo = first._batch_memo
            if len(memo) >= GrammarConstraint._MEMO_CAP:
                memo.clear()
            memo[key] = (tuple(g for g in groups_list),
                         (rows, eos, nseq, groups_list))
        return rows, eos, nseq, groups_list

    @staticmethod
    def cd_overlay_batch(constraints, groups_list, num_words: int):
        """The cd_check stage for a batch: [B, W] uint32 residue words
        (all-zero rows for unconstrained or residue-free slots)."""
        B = len(constraints)
        first = None
        for gc in constraints:
            if gc is not None:
                first = gc
                break
        klist = [num_words]
        for g in groups_list:
            klist.append(-1 if g is None else id(g))
        key = tuple(klist)
        if first is not None:
            hit = first._cd_batch_memo.get(key)
            if hit is not None:
                refs = hit[0]
                for b, g in enumerate(groups_list):
                    if (refs[b] is not g) if g is not None \
                            else (refs[b] is not None):
                        hit = None
                        break
                if hit is not None:
                    return hit[1]
        cd = np.zeros((B, num_words), dtype=np.uint32)
        for b, gc in enumerate(constraints):
            if gc is None or groups_list[b] is None:
                continue
            w = gc.cd_overlay(groups_list[b])
            if w is not None:
                cd[b] = w
        if first is not None:
            memo = first._cd_batch_memo
            if len(memo) >= GrammarConstraint._MEMO_CAP:
                memo.clear()
            memo[key] = (tuple(g for g in groups_list), cd)
        return cd

    @staticmethod
    def step_rows_batch(constraints, texts, max_accept: int = MAX_ACCEPT,
                        row_offsets=None):
        """Composed batch step: (rows [B, A], cd [B, W], eos [B],
        nseq [B]). The engine's dispatch path calls the two stages
        directly to attribute ci_lookup and cd_check separately."""
        rows, eos, nseq, groups_list = GrammarConstraint.ci_rows_batch(
            constraints, texts, max_accept, row_offsets)
        W = 0
        for gc in constraints:
            if gc is not None:
                W = gc.store.num_words
                break
        cd = GrammarConstraint.cd_overlay_batch(constraints, groups_list,
                                                W or 1)
        return rows, cd, eos, nseq

    # ---- packed union (host reference; device path is in kernels/) -----

    def union_packed(self, sm: StepMask) -> np.ndarray:
        """OR of the step's store rows and residue overlay — the exact
        packed mask the device computes."""
        packed = self.store.union_rows(sm.rows)
        if sm.cd_words is not None:
            packed |= sm.cd_words
        return packed

    # ---- forced-continuation query (speculation / jump-forward) ---------

    def forced_step(self, partial_output: bytes):
        """Classify this step's mask for the jump-forward analyzer.

        Returns (kind, token, step_mask):
          ("token", t, sm) — exactly one token survives the mask union,
                         EOS is not allowed, and t passes the exact
                         oracle: the grammar (as seen through this step's
                         row set + residue — the same bits the engine
                         masks with) forces t, so it can be emitted
                         without a model call.
          ("eos", None, sm)  — mask empty but C_k ∈ L(G): EOS is forced.
          ("dead", None, sm) — mask empty and EOS disallowed (the
                         engine's mask_exhausted outcome).
          ("free", None, sm) — more than one candidate; the model must
                         choose. The returned StepMask is this step's row
                         set, so the caller can mask without recomputing.

        Fast path: the union can only collapse to <= 1 token if every
        member row allows <= 1 (build-time per-row popcount gather) and
        the residue overlay is empty — decided without touching the
        packed words.
        """
        sm = self.step_rows(partial_output)
        valid = sm.rows[sm.rows >= 0]
        if valid.size and int(self.store.row_popcounts()[valid].max()) > 1:
            return ("free", None, sm)
        packed = self.union_packed(sm)              # one union feeds both
        n = self.store.popcount_packed(packed)
        if n == 0:
            return (("eos", None, sm) if sm.eos_allowed
                    else ("dead", None, sm))
        if n == 1 and not sm.eos_allowed:
            t = self.store.sole_from_packed(packed)
            if t is not None and self.is_valid_extension(partial_output, t):
                return ("token", t, sm)
            # sole candidate is a mask over-approximation the oracle
            # rejects: the exact allowed set is empty (matches the plain
            # engine's demote -> exhausted path)
            return ("dead", None, sm)
        return ("free", None, sm)

    # ---- host reference mask (numpy; the device path lives in kernels/) --

    def token_mask(self, partial_output: bytes) -> np.ndarray:
        """Full boolean vocab mask (reference / tests / CPU serving)."""
        sm = self.step_rows(partial_output)
        mask = self.store.unpack(self.union_packed(sm))
        if sm.eos_allowed:
            mask[EOS_ID] = True
        return mask

    # ---- validity oracle (used by tests and opportunistic masking) ------

    def is_valid_extension(self, partial_output: bytes, token_id: int) -> bool:
        """partial_output + token stays in L_p(G)?

        Never over-approximates (safe for the opportunistic fast path):
        the parse must succeed AND the remainder must still be a viable
        prefix of some *acceptable* terminal. It may under-approximate in
        the rare case where the final lexical token's type must change in
        the future — then the caller just falls back to the mask.
        """
        if token_id == EOS_ID:
            return self.parser.partial_parse(partial_output).eos_allowed
        tb = self.tokenizer.id_to_bytes[token_id]
        if not tb:
            return False
        try:
            # incremental: the prefix-stack cache makes the hypothetical
            # extension O(delta); a rejected hypothesis merely truncates
            # the cache back on the next prefix-diverging call
            res = self.parser.partial_parse(partial_output + tb)
        except (ParseError, LexError):
            return False
        if not res.remainder:
            return True
        if res.eos_allowed:
            # the extended text is itself a complete sentence (exact:
            # eos_allowed shifts the final token and checks acceptance).
            # Without this, a grammar with NO ignore terminals rejected
            # the token that exactly completes the sentence — the accept
            # sequences only describe CONTINUATIONS of the remainder
            return True
        for seq in res.accept_sequences:
            dfa = self.grammar.terminals[seq[0]].dfa
            q = dfa.walk_live(dfa.start, res.remainder)
            if dfa.live[q]:
                return True
        return False
