"""Builtin grammars (paper §4.7: "shipped with several built-in grammars").

`load_grammar(name)` compiles (and memoizes) the grammar + LR table.
The builtin definitions are embedded in `builtin_defs.py` (no data files
required); users add or override grammars by dropping `<name>.lark` files
here or calling `Grammar(text)` directly.
"""
from __future__ import annotations

import os

from ..grammar import Grammar
from ..lr import build_lr_table
from .builtin_defs import EMBEDDED

_DIR = os.path.dirname(__file__)
_CACHE: dict[tuple[str, bool], tuple] = {}

BUILTIN = ("json", "calc", "sql", "minilang", "jsonmsg", "python_mini")


def grammar_text(name: str) -> str:
    path = os.path.join(_DIR, f"{name}.lark")
    if os.path.exists(path):
        with open(path) as f:
            return f.read()
    if name in EMBEDDED:
        return EMBEDDED[name]
    raise FileNotFoundError(f"no builtin grammar {name!r}; have {BUILTIN}")


def load_grammar(name: str, lalr: bool = True):
    """Returns (Grammar, LRTable), memoized per-process."""
    key = (name, lalr)
    if key not in _CACHE:
        g = Grammar(grammar_text(name), name=name)
        t = build_lr_table(g, lalr=lalr)
        _CACHE[key] = (g, t)
    return _CACHE[key]
