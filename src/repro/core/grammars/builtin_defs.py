"""Embedded builtin grammar definitions (paper §4.7).

These are the source of truth for the builtin grammars; `load_grammar`
falls back to them when no `<name>.lark` override file is present in this
package directory, so the repo needs no checked-in data files and the
test-suite / examples / benchmarks work from a bare checkout. Dropping a
`<name>.lark` file next to this module still overrides (or extends) the
builtins — that remains the user extension path.

Syntax is the Lark subset documented in `repro.core.grammar`.
"""
from __future__ import annotations

JSON = r"""
// RFC-8259-shaped JSON (byte-level strings, no unicode validation).
start: value
value: object | array | STRING | NUMBER | "true" | "false" | "null"
object: "{" [pair ("," pair)*] "}"
pair: STRING ":" value
array: "[" [value ("," value)*] "]"

STRING: /"(\\.|[^"\\])*"/
NUMBER: /-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?/
WS: /[ \t\r\n]+/
%ignore WS
"""

CALC = r"""
// Arithmetic with a few math_* builtins. Deliberately has NO identifier
// terminal: unknown bytes (e.g. '@') are immediate lex errors, and
// "math_sqrt" is a literal keyword token (__MATH_SQRT).
start: expr
expr: term | expr "+" term | expr "-" term
term: factor | term "*" factor | term "/" factor
factor: atom | "-" factor
atom: INT | FLOAT | func | "(" expr ")"
func: ("math_sqrt" | "math_sin" | "math_cos" | "math_exp") "(" expr ")"

INT: /[0-9]+/
FLOAT: /[0-9]+\.[0-9]+([eE][+-]?[0-9]+)?/
WS: /[ \t\n]+/
%ignore WS
"""

SQL = r"""
// A SELECT-only SQL subset (uppercase keywords, lowercase identifiers —
// the case split keeps keywords and NAME disjoint in the lexer).
start: query
query: "SELECT" select_list "FROM" NAME [where_clause] [order_clause] [limit_clause] ";"
select_list: "*" | column ("," column)*
column: agg | NAME
agg: ("COUNT" | "SUM" | "AVG" | "MIN" | "MAX") "(" agg_arg ")"
agg_arg: "*" | NAME
where_clause: "WHERE" cond
cond: pred (("AND" | "OR") pred)*
pred: NAME cmp_op value
cmp_op: "=" | "<" | ">" | "<=" | ">=" | "!="
value: NUMBER | STRING | NAME
order_clause: "ORDER" "BY" NAME ["ASC" | "DESC"]
limit_clause: "LIMIT" NUMBER

NAME: /[a-z_][a-z0-9_]*/
NUMBER: /-?[0-9]+(\.[0-9]+)?/
STRING: /'[^'\n]*'/
WS: /[ \t\n]+/
%ignore WS
"""

MINILANG = r"""
// The GPL stand-in: a tiny imperative language with braced blocks,
// keywords that lex-overlap the NAME terminal (keyword-vs-identifier
// maximal munch), and multi-byte operators ("<=" etc.).
start: stmt stmt*
stmt: "let" NAME "=" expr ";"
    | NAME "=" expr ";"
    | "if" "(" expr ")" block ["else" block]
    | "while" "(" expr ")" block
    | "return" expr ";"
    | "print" "(" expr ")" ";"
block: "{" stmt* "}"
expr: sum [("<" | ">" | "<=" | ">=" | "==" | "!=") sum]
sum: prod (("+" | "-") prod)*
prod: atom (("*" | "/") atom)*
atom: INT | NAME | "(" expr ")"

NAME: /[a-z_][a-z0-9_]*/
INT: /[0-9]+/
WS: /[ \t\n]+/
%ignore WS
"""

JSONMSG = r"""
// Schema-constrained COMPACT JSON records (tool-call / extraction
// shaped, machine-canonical: no whitespace): the object keys are
// grammar literals and the leaf terminals are bounded, so large runs of
// the output are grammar-DETERMINED — the workload where jump-forward
// speculation shines (braces, quotes, keys, separators all forced; only
// ids/ops/args are model choices).
start: "[" record ("," record)* "]"
record: "{" KID ":" NUMBER "," KOP ":" OP "," KARGS ":" "[" [ARG ("," ARG)*] "]" "}"

KID.2: /"id"/
KOP.2: /"op"/
KARGS.2: /"args"/
OP.2: /"(get|set|del|add|list|ping)"/
ARG: /"[a-z0-9_]{1,8}"/
NUMBER: /[0-9]{1,4}/
"""

EMBEDDED: dict[str, str] = {
    "json": JSON,
    "calc": CALC,
    "sql": SQL,
    "minilang": MINILANG,
    "jsonmsg": JSONMSG,
}
