"""Embedded builtin grammar definitions (paper §4.7).

These are the source of truth for the builtin grammars; `load_grammar`
falls back to them when no `<name>.lark` override file is present in this
package directory, so the repo needs no checked-in data files and the
test-suite / examples / benchmarks work from a bare checkout. Dropping a
`<name>.lark` file next to this module still overrides (or extends) the
builtins — that remains the user extension path.

Syntax is the Lark subset documented in `repro.core.grammar`.
"""
from __future__ import annotations

JSON = r"""
// RFC-8259-shaped JSON (byte-level strings, no unicode validation).
start: value
value: object | array | STRING | NUMBER | "true" | "false" | "null"
object: "{" [pair ("," pair)*] "}"
pair: STRING ":" value
array: "[" [value ("," value)*] "]"

STRING: /"(\\.|[^"\\])*"/
NUMBER: /-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?/
WS: /[ \t\r\n]+/
%ignore WS
"""

CALC = r"""
// Arithmetic with a few math_* builtins. Deliberately has NO identifier
// terminal: unknown bytes (e.g. '@') are immediate lex errors, and
// "math_sqrt" is a literal keyword token (__MATH_SQRT).
start: expr
expr: term | expr "+" term | expr "-" term
term: factor | term "*" factor | term "/" factor
factor: atom | "-" factor
atom: INT | FLOAT | func | "(" expr ")"
func: ("math_sqrt" | "math_sin" | "math_cos" | "math_exp") "(" expr ")"

INT: /[0-9]+/
FLOAT: /[0-9]+\.[0-9]+([eE][+-]?[0-9]+)?/
WS: /[ \t\n]+/
%ignore WS
"""

SQL = r"""
// A SELECT-only SQL subset (uppercase keywords, lowercase identifiers —
// the case split keeps keywords and NAME disjoint in the lexer).
start: query
query: "SELECT" select_list "FROM" NAME [where_clause] [order_clause] [limit_clause] ";"
select_list: "*" | column ("," column)*
column: agg | NAME
agg: ("COUNT" | "SUM" | "AVG" | "MIN" | "MAX") "(" agg_arg ")"
agg_arg: "*" | NAME
where_clause: "WHERE" cond
cond: pred (("AND" | "OR") pred)*
pred: NAME cmp_op value
cmp_op: "=" | "<" | ">" | "<=" | ">=" | "!="
value: NUMBER | STRING | NAME
order_clause: "ORDER" "BY" NAME ["ASC" | "DESC"]
limit_clause: "LIMIT" NUMBER

NAME: /[a-z_][a-z0-9_]*/
NUMBER: /-?[0-9]+(\.[0-9]+)?/
STRING: /'[^'\n]*'/
WS: /[ \t\n]+/
%ignore WS
"""

MINILANG = r"""
// The GPL stand-in: a tiny imperative language with braced blocks,
// keywords that lex-overlap the NAME terminal (keyword-vs-identifier
// maximal munch), and multi-byte operators ("<=" etc.).
start: stmt stmt*
stmt: "let" NAME "=" expr ";"
    | NAME "=" expr ";"
    | "if" "(" expr ")" block ["else" block]
    | "while" "(" expr ")" block
    | "return" expr ";"
    | "print" "(" expr ")" ";"
block: "{" stmt* "}"
expr: sum [("<" | ">" | "<=" | ">=" | "==" | "!=") sum]
sum: prod (("+" | "-") prod)*
prod: atom (("*" | "/") atom)*
atom: INT | NAME | "(" expr ")"

NAME: /[a-z_][a-z0-9_]*/
INT: /[0-9]+/
WS: /[ \t\n]+/
%ignore WS
"""

JSONMSG = r"""
// Schema-constrained COMPACT JSON records (tool-call / extraction
// shaped, machine-canonical: no whitespace): the object keys are
// grammar literals and the leaf terminals are bounded, so large runs of
// the output are grammar-DETERMINED — the workload where jump-forward
// speculation shines (braces, quotes, keys, separators all forced; only
// ids/ops/args are model choices).
start: "[" record ("," record)* "]"
record: "{" KID ":" NUMBER "," KOP ":" OP "," KARGS ":" "[" [ARG ("," ARG)*] "]" "}"

KID.2: /"id"/
KOP.2: /"op"/
KARGS.2: /"args"/
OP.2: /"(get|set|del|add|list|ping)"/
ARG: /"[a-z0-9_]{1,8}"/
NUMBER: /[0-9]{1,4}/
"""

PYTHON_MINI = r"""
// A real-language target: a Python subset with layout-sensitive lexing
// (%indent). Designed so that anything the masked decoder completes is
// ast.parse()-able CPython:
//   * assignment targets are NAME only (keeps the grammar LALR(1): '='
//     appears nowhere else, '==' is the comparison operator);
//   * 'return' is only reachable inside function suites (fstmt/fsuite
//     mirror stmt/suite) — no return-outside-function SyntaxError;
//   * non-grammar Python keywords are claimed by RESERVED (priority 2,
//     referenced by an unreachable rule so it joins the lexer DFA) —
//     'break = 1' is a lex-level dead end, not a generated program;
//   * integer literals ban leading zeros; string escapes are a safe
//     subset valid in str AND bytes literals; strings/comments are
//     printable-ASCII, and no terminal matches TAB or CR, so the
//     byte-level column count always agrees with CPython's tokenizer.
start: program
program: stmt*

stmt: simple_stmt
    | "if" test ":" suite ("elif" test ":" suite)* ["else" ":" suite]
    | "while" test ":" suite
    | "for" NAME "in" test ":" suite
    | func_def
    | class_def

fstmt: fsimple_stmt
    | "if" test ":" fsuite ("elif" test ":" fsuite)* ["else" ":" fsuite]
    | "while" test ":" fsuite
    | "for" NAME "in" test ":" fsuite
    | func_def
    | class_def

simple_stmt: small_stmt NEWLINE
fsimple_stmt: small_stmt NEWLINE | "return" [test] NEWLINE
small_stmt: expr_stmt | "pass"
expr_stmt: test | NAME "=" test

func_def: "def" NAME "(" [params] ")" ":" fsuite
params: NAME ("," NAME)*
class_def: "class" NAME ["(" [args] ")"] ":" suite

suite: simple_stmt | NEWLINE INDENT stmt+ DEDENT
fsuite: fsimple_stmt | NEWLINE INDENT fstmt+ DEDENT

test: or_test
or_test: and_test ("or" and_test)*
and_test: not_test ("and" not_test)*
not_test: "not" not_test | comparison
comparison: arith (comp_op arith)*
comp_op: "==" | "!=" | "<" | ">" | "<=" | ">=" | "in" | "not" "in" | "is" | "is" "not"
arith: term (("+" | "-") term)*
term: factor (("*" | "/" | "//" | "%") factor)*
factor: "+" factor | "-" factor | power
power: atom_expr ["**" factor]
atom_expr: atom trailer*
trailer: "(" [args] ")" | "[" test "]" | "." NAME
args: test ("," test)*
atom: NAME | NUMBER | STRING | "True" | "False" | "None"
    | "(" test ")" | "[" [args] "]"

// unreachable: exists only so RESERVED participates in the lexer DFA
reserved_unreachable: RESERVED

NAME: /[A-Za-z_][A-Za-z0-9_]*/
RESERVED.2: /as|assert|async|await|break|continue|del|except|finally|from|global|import|lambda|nonlocal|raise|try|with|yield/
NUMBER: /(0|[1-9][0-9]*)([eE][+-]?[0-9]+)?|[0-9]+\.[0-9]*([eE][+-]?[0-9]+)?|\.[0-9]+([eE][+-]?[0-9]+)?/
STRING: /(r|R|b|B|u|U|rb|rB|Rb|RB|br|bR|Br|BR)?("(\\[\\'"nrtfvab0]|[ !#-\[\]-~])*"|'(\\[\\'"nrtfvab0]|[ -&(-\[\]-~])*')/
NEWLINE: /(\n[ ]*|#[ -~]*)+/
WS: / +/
LINE_CONT: /\\\n[ ]*/

%indent NEWLINE INDENT DEDENT
%ignore WS
%ignore LINE_CONT
"""

EMBEDDED: dict[str, str] = {
    "json": JSON,
    "calc": CALC,
    "sql": SQL,
    "minilang": MINILANG,
    "jsonmsg": JSONMSG,
    "python_mini": PYTHON_MINI,
}
