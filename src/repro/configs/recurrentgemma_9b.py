"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rec
[arXiv:2402.19427]. 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000, local window 2048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    vocab_size=256000,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=4096,
    conv_kernel=4,
    tie_embeddings=True,
    source="[arXiv:2402.19427] RecurrentGemma-9B",
)
