"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].
48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    arch_type="moe",
    num_layers=48,
    d_model=2048,
    vocab_size=151936,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    moe_d_ff=768,
    num_experts=128,
    experts_per_token=8,
    moe_capacity_factor=1.25,
    rope_theta=1e6,
    source="[hf:Qwen/Qwen3-30B-A3B]",
)
