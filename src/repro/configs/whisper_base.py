"""whisper-base [audio] — encoder-decoder, conv/mel frontend stubbed
[arXiv:2212.04356]. 6L decoder (+6L encoder) d_model=512 8H (kv=8)
d_ff=2048 vocab=51865; encoder consumes precomputed frame embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    num_layers=6,
    encoder_layers=6,
    d_model=512,
    vocab_size=51865,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    audio_frames=1500,
    source="[arXiv:2212.04356] Whisper base",
)
