"""deepseek-coder-33b [dense] — llama-arch [arXiv:2401.14196].
62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    num_layers=62,
    d_model=7168,
    vocab_size=32256,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    rope_theta=1e5,
    source="[arXiv:2401.14196] DeepSeek-Coder 33B",
)
