"""Config registry: `get_config("--arch id")` for every assigned
architecture (+ the paper-demo substrate)."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "mamba2-370m": "mamba2_370m",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "smollm-360m": "smollm_360m",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama-3.2-vision-90b": "llama3_2_vision_90b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "whisper-base": "whisper_base",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "syncode-demo": "syncode_demo",
}

ARCH_IDS = [k for k in _MODULES if k != "syncode-demo"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs():
    return {k: get_config(k) for k in _MODULES}
