"""syncode-demo — the paper's own experiments run against small LMs; this
is the CPU-runnable config used by examples/ and benchmarks/ (random-init;
see DESIGN.md deviations)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="syncode-demo",
    arch_type="dense",
    num_layers=4,
    d_model=256,
    vocab_size=2048,
    num_heads=8,
    num_kv_heads=4,
    head_dim=32,
    d_ff=1024,
    attn_chunk=256,
    remat=False,
    source="paper demo substrate (SynCode §5 uses small open models)",
)
