"""qwen1.5-0.5b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B].
24L d_model=1024 16H (MHA kv=16) d_ff=2816 vocab=151936."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=1024,
    vocab_size=151936,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    qkv_bias=True,
    rope_theta=1e6,
    source="[hf:Qwen/Qwen1.5-0.5B]",
)
