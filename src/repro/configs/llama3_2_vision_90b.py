"""llama-3.2-vision-90b [vlm] — cross-attention image layers every 5th
layer [hf:meta-llama/Llama-3.2-11B-Vision, 90B scaling]. 100L d_model=8192
64H (GQA kv=8) d_ff=28672 vocab=128256. The ViT tower is a stub: the
language model consumes precomputed patch embeddings (input_specs)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    vocab_size=128256,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    cross_attn_every=5,
    num_image_tokens=1601,
    rope_theta=5e5,
    source="[hf:meta-llama/Llama-3.2-11B-Vision] 90B variant",
)
