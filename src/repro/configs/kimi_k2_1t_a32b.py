"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table)
[arXiv:2501.kimi2]. 61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048
vocab=163840, MoE 384 experts top-8, first layer dense."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    vocab_size=163840,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,          # the single leading dense layer (~8 experts worth)
    moe_d_ff=2048,       # per-expert hidden (assignment d_ff=2048)
    num_experts=384,
    experts_per_token=8,
    first_dense_layers=1,
    moe_capacity_factor=1.25,
    rope_theta=5e6,
    source="[arXiv:2501.kimi2] Kimi K2 paper table",
)
