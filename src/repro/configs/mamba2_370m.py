"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].
48L d_model=1024, attn-free, ssm_state=128, vocab=50280."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_kernel=4,
    tie_embeddings=True,
    source="[arXiv:2405.21060] Mamba-2 370m table",
)
