"""internlm2-1.8b [dense] — GQA [arXiv:2403.17297].
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    vocab_size=92544,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    rope_theta=1e6,
    source="[arXiv:2403.17297] InternLM2 1.8B",
)
