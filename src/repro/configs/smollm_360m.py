"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-360M].
32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    num_layers=32,
    d_model=960,
    vocab_size=49152,
    num_heads=15,
    num_kv_heads=5,
    d_ff=2560,
    tie_embeddings=True,
    source="[hf:HuggingFaceTB/SmolLM-135M] scaled per assignment",
)
