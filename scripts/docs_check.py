#!/usr/bin/env python
"""Docs hygiene gate (`make docs-check`).

Fails if any package under src/repro/ is missing from README.md's module
map, or if the core doc files are absent — so documentation cannot
silently rot as the codebase grows.
"""
from __future__ import annotations

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src", "repro")
REQUIRED_DOCS = ("README.md", os.path.join("docs", "architecture.md"),
                 os.path.join("benchmarks", "README.md"))


def repro_packages() -> list[str]:
    """Every directory under src/repro containing python code."""
    out = []
    for name in sorted(os.listdir(SRC)):
        path = os.path.join(SRC, name)
        if not os.path.isdir(path):
            continue
        if any(f.endswith(".py") for f in os.listdir(path)):
            out.append(name)
    return out


def main() -> int:
    bad = 0
    for doc in REQUIRED_DOCS:
        if not os.path.exists(os.path.join(ROOT, doc)):
            print(f"docs-check: MISSING {doc}")
            bad += 1
    readme_path = os.path.join(ROOT, "README.md")
    readme = open(readme_path).read() if os.path.exists(readme_path) else ""
    for pkg in repro_packages():
        # a module-map mention is a backquoted package name
        if f"`{pkg}" not in readme:
            print(f"docs-check: package src/repro/{pkg} not mentioned in "
                  f"README.md module map")
            bad += 1
    if bad:
        print(f"docs-check: FAILED ({bad} problem(s))")
        return 1
    print(f"docs-check: OK ({len(repro_packages())} packages documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
