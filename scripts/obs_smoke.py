"""End-to-end observability smoke gate (`make obs-smoke`; wired into CI).

Boots the real serving stack — paged engine, persistent async step
loop, stdlib HTTP server — against the `python_mini` grammar, turns on
trace capture, streams a few requests through `POST /generate`, then
asserts the whole telemetry surface is live:

  * `GET /metrics` exposes the step-phase counters/histograms, the
    request-lifecycle histograms (TTFT / inter-token), the KV pool
    gauges and the token/mask counters, and parses as Prometheus
    text exposition;
  * `GET /stats` returns the JSON snapshot with request summaries;
  * `POST /trace {"action": "dump"}` returns a Chrome trace-event
    document with phase slices and track-name metadata (loadable in
    ui.perfetto.dev);
  * `GET /healthz` carries uptime, queue depth, finish-reason
    counts and the build identity (git SHA / jax version / device);
  * `POST /profile start|stop|dump` captures a live device-timing
    window during real `/generate` traffic and dumps ONE merged
    Perfetto timeline with host phase tracks AND device tracks.

Everything runs in-process on an ephemeral port; seconds-scale, no
network dependencies. Exit code 0 iff every assertion holds.
"""
from __future__ import annotations

import asyncio
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

N_REQUESTS = 4
MAX_NEW = 12

# series that must be present (as a HELP/TYPE family with at least one
# sample) after the workload: step phases, lifecycle, KV pool, counters
REQUIRED_FAMILIES = (
    "repro_step_phase_seconds_total",
    "repro_step_phase_calls_total",
    "repro_step_phase_duration_seconds",
    "repro_request_ttft_seconds",
    "repro_request_itl_seconds",
    "repro_request_queue_wait_seconds",
    "repro_requests_total",
    "repro_tokens_total",
    "repro_mask_computations_total",
    "repro_overlap_forwards_total",
    "repro_kv_pages_total",
    "repro_kv_pages_in_use",
    "repro_queue_depth",
    "repro_uptime_seconds",
    "repro_step_attribution_seconds_total",
)

# device-attribution components /metrics must expose (scrape-time
# counters wired by Telemetry._wire_attribution)
REQUIRED_ATTRIBUTION = ("host_grammar", "host_grammar_ci",
                        "host_grammar_cd", "mask_sample_kernel",
                        "forward_kernel", "overlap_hidden")

# phases the paged workload must have timed at least once
REQUIRED_PHASES = ("admit", "feed_build", "forward", "ci_lookup",
                   "cd_check", "mask_dispatch", "select_resolve")

_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? "
    r"(NaN|[+-]?(\d+\.?\d*([eE][+-]?\d+)?|\.\d+([eE][+-]?\d+)?|"
    r"Inf|inf))$")


async def _http(host, port, method, path, body=b""):
    reader, writer = await asyncio.open_connection(host, port)
    req = (f"{method} {path} HTTP/1.1\r\nHost: x\r\n"
           f"Content-Length: {len(body)}\r\n\r\n").encode() + body
    writer.write(req)
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionError, BrokenPipeError):
        pass
    head, _, rest = data.partition(b"\r\n\r\n")
    status = int(head.split(b" ")[1])
    if b"chunked" in head.lower():
        out, rem = b"", rest
        while rem:
            size, _, rem = rem.partition(b"\r\n")
            n = int(size, 16)
            if n == 0:
                break
            out += rem[:n]
            rem = rem[n + 2:]
        return status, out
    return status, rest


def _check_prometheus(text: str) -> None:
    """Every non-comment line must be a well-formed sample line."""
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        assert _SAMPLE_RE.match(line), f"bad Prometheus line: {line!r}"


async def _run() -> int:
    from repro.launch.serve import build_engine
    from repro.serving.async_engine import AsyncEngine
    from repro.serving.server import EngineServer

    print("obs-smoke: building paged engine (python_mini, vocab=512)...")
    engine, _, _ = build_engine("syncode-demo",
                                grammars=("python_mini", "json"),
                                vocab=512, max_len=160, slots=4,
                                paged=True, page_size=8)
    aeng = AsyncEngine(engine)
    srv = EngineServer(aeng)
    host, port = await srv.start(port=0)
    print(f"obs-smoke: server on http://{host}:{port}")
    try:
        # -- tracing on before any work so slices land in the buffer
        status, body = await _http(host, port, "POST", "/trace",
                                   b'{"action": "start"}')
        assert status == 200 and json.loads(body)["tracing"] is True, \
            (status, body)

        async def gen(i):
            st, out = await _http(
                host, port, "POST", "/generate",
                json.dumps({"prompt": "x =", "grammar": "python_mini",
                            "max_new_tokens": MAX_NEW,
                            "method": "sample", "temperature": 1.0,
                            "seed": i}).encode())
            assert st == 200, (st, out)
            lines = [json.loads(l) for l in out.splitlines() if l]
            assert lines[-1]["done"] is True, lines[-1]
            return lines[-1]["tokens"]

        tokens = await asyncio.gather(*(gen(i) for i in range(N_REQUESTS)))
        total = sum(tokens)
        assert total > 0, "no tokens generated"
        print(f"obs-smoke: {N_REQUESTS} requests, {total} tokens")

        # -- /metrics: families present, phases timed, output well-formed
        status, body = await _http(host, port, "GET", "/metrics")
        assert status == 200, status
        text = body.decode()
        _check_prometheus(text)
        for fam in REQUIRED_FAMILIES:
            assert f"# TYPE {fam} " in text, f"missing family {fam}"
        for ph in REQUIRED_PHASES:
            pat = (f'repro_step_phase_calls_total{{phase="{ph}"}}')
            m = re.search("^" + re.escape(pat) + r" (\S+)$", text, re.M)
            assert m and float(m.group(1)) > 0, f"phase {ph} never timed"
        m = re.search(r'^repro_tokens_total (\S+)$', text, re.M)
        assert m and float(m.group(1)) >= total, "token counter short"
        m = re.search(r'^repro_request_ttft_seconds_count (\S+)$', text,
                      re.M)
        assert m and float(m.group(1)) == N_REQUESTS, "TTFT count wrong"
        print(f"obs-smoke: /metrics OK "
              f"({len(text.splitlines())} lines, "
              f"{len(REQUIRED_FAMILIES)} required families)")

        # -- /stats: JSON snapshot with request summaries
        status, body = await _http(host, port, "GET", "/stats")
        assert status == 200, status
        stats = json.loads(body)
        assert stats["enabled"] is True
        assert stats["requests"]["ttft"]["count"] == N_REQUESTS, stats
        assert stats["trace"]["active"] is True
        print("obs-smoke: /stats OK")

        # -- /trace dump: Chrome trace events with named tracks
        status, body = await _http(host, port, "POST", "/trace",
                                   b'{"action": "dump"}')
        assert status == 200, status
        doc = json.loads(body)
        evs = doc["traceEvents"]
        assert evs, "empty trace"
        phases = {e.get("name") for e in evs if e.get("ph") == "X"}
        assert "forward" in phases and "ci_lookup" in phases, phases
        tracks = {e["args"]["name"] for e in evs
                  if e.get("name") == "thread_name"}
        assert any(t.startswith("slot ") for t in tracks), tracks
        assert all(e["ts"] >= 0 for e in evs if "ts" in e)
        print(f"obs-smoke: /trace dump OK ({len(evs)} events, "
              f"{len(tracks)} tracks)")

        status, body = await _http(host, port, "POST", "/trace",
                                   b'{"action": "stop"}')
        assert status == 200 and json.loads(body)["tracing"] is False

        # -- attribution: every component series present and summed
        status, body = await _http(host, port, "GET", "/metrics")
        text = body.decode()
        for comp in REQUIRED_ATTRIBUTION:
            pat = ('repro_step_attribution_seconds_total'
                   f'{{component="{comp}"}}')
            m = re.search("^" + re.escape(pat) + r" (\S+)$", text, re.M)
            assert m, f"attribution component {comp} missing"
        status, body = await _http(host, port, "GET", "/stats")
        stats = json.loads(body)
        attr = stats["attribution"]
        assert attr["enabled"] is True
        assert attr["seconds"]["host_grammar"] > 0, attr
        assert attr["source"]["forward_kernel"] == "host-dispatch"
        assert stats["device"]["sync_calls"] == 0      # serving mode
        assert stats["build"]["git_sha"], stats["build"]
        print("obs-smoke: attribution OK "
              f"(host_grammar={attr['seconds']['host_grammar']:.3f}s, "
              "no syncs in serving mode)")

        # -- /profile: live device-timing capture during real traffic
        status, body = await _http(host, port, "POST", "/profile",
                                   b'{"action": "dump"}')
        assert status == 409, (status, body)           # nothing captured
        status, body = await _http(host, port, "POST", "/profile",
                                   b'{"action": "start"}')
        assert status == 200, (status, body)
        prof = json.loads(body)
        assert prof["profiling"] is True, prof
        await asyncio.gather(*(gen(100 + i) for i in range(N_REQUESTS)))
        status, body = await _http(host, port, "POST", "/profile",
                                   b'{"action": "stop"}')
        assert status == 200, (status, body)
        stopped = json.loads(body)
        assert stopped["buffered_events"] > 0, stopped
        status, body = await _http(host, port, "POST", "/profile",
                                   b'{"action": "dump"}')
        assert status == 200, (status, body)
        doc = json.loads(body)
        evs = doc["traceEvents"]
        assert evs, "empty merged trace"
        tracks = {e["args"]["name"] for e in evs
                  if e.get("name") == "thread_name"}
        host_tracks = [t for t in tracks if not t.startswith("device:")]
        dev_tracks = [t for t in tracks if t.startswith("device:")]
        assert host_tracks and dev_tracks, tracks      # merged timeline
        assert "device:forward" in dev_tracks, dev_tracks
        assert all(e["ts"] >= 0 for e in evs if "ts" in e)
        json.dumps(doc)                                # Perfetto-loadable
        status, body = await _http(host, port, "GET", "/metrics")
        text = body.decode()
        assert '# TYPE repro_device_seconds_total ' in text
        m = re.search(r'repro_device_seconds_total\{fn="forward"\}'
                      r' (\S+)', text)
        assert m and float(m.group(1)) > 0, "no device forward seconds"
        status, body = await _http(host, port, "GET", "/stats")
        stats = json.loads(body)
        assert stats["device"]["sync_calls"] > 0       # profile window
        assert stats["device"]["enabled"] is False     # restored after
        assert stats["attribution"]["source"]["forward_kernel"] == \
            "device"
        print(f"obs-smoke: /profile OK (merged trace: {len(evs)} events, "
              f"{len(dev_tracks)} device + {len(host_tracks)} host "
              "tracks)")

        # -- /healthz: uptime, queue depth, finish reasons
        status, body = await _http(host, port, "GET", "/healthz")
        assert status == 200, status
        hz = json.loads(body)
        assert hz["ok"] is True
        assert hz["uptime_seconds"] > 0
        assert hz["queue_depth"] == 0
        assert hz["finish_reasons"].get("eos", 0) + \
            hz["finish_reasons"].get("length", 0) == 2 * N_REQUESTS, hz
        b = hz["build"]
        assert b["git_sha"] and b["jax_version"] and b["device_kind"], b
        print(f"obs-smoke: /healthz OK (build {b['git_sha']} "
              f"jax {b['jax_version']} {b['device_kind']})")
    finally:
        await srv.stop(drain=False)
    print("obs-smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(asyncio.run(_run()))
