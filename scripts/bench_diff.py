#!/usr/bin/env python
"""Perf-regression gate: compare two bench artifacts (benchmarks/common
`write_artifact` JSON, schema v2; v1 artifacts are read and upgraded
in-place by zero-filling the context-split attribution columns) with
robust median + MAD statistics.

    python scripts/bench_diff.py BASELINE CURRENT [--warn-only]
    python scripts/bench_diff.py --self-test BASELINE

Per matched row the per-call latency ratio ``current/baseline`` is
examined on a log scale. A row FAILS when its ratio exceeds
``--fail-over`` (default 2.0x); it WARNS when it exceeds
``--warn-over`` (default 1.25x) *and* sits more than 3 MAD above the
median log-ratio of the whole run — the MAD guard keeps a uniformly
slower machine (every row shifted together) from spraying false
positives, which is what makes the gate usable warn-only on shared CI
runners. ``--warn-only`` downgrades row failures to warnings but still
exits non-zero on schema/match errors.

``--self-test`` proves the gate end-to-end without a second run: it
diffs the baseline against itself (must pass), then against a copy
with a synthetic >2x slowdown injected into one row (must fail).
"""
from __future__ import annotations

import argparse
import copy
import json
import math
import sys

SCHEMA_VERSION = 2

# versions load() can still read; v1 rows lack the context-split
# attribution columns and are upgraded by zero-filling them
_READABLE_VERSIONS = (1, 2)
_V2_ATTR_COLS = ("host_grammar_ci_s", "host_grammar_cd_s")

# rows whose us_per_call is a percentage / score, not a latency — the
# ratio test doesn't apply (they are compared informationally only)
_NON_LATENCY_SUFFIXES = ("_overlap_speedup",)


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    ver = doc.get("schema_version")
    if ver not in _READABLE_VERSIONS:
        raise SystemExit(f"{path}: schema_version {ver!r}, "
                         f"expected one of {_READABLE_VERSIONS}")
    if not isinstance(doc.get("rows"), list):
        raise SystemExit(f"{path}: no rows")
    if ver < SCHEMA_VERSION:
        for r in doc["rows"]:
            attr = r.setdefault("attribution", {})
            for k in _V2_ATTR_COLS:
                attr.setdefault(k, 0.0)
        doc["schema_version"] = SCHEMA_VERSION
    return doc


def _median(xs):
    xs = sorted(xs)
    n = len(xs)
    if n == 0:
        return 0.0
    mid = n // 2
    return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])


def _mad(xs, center):
    return _median([abs(x - center) for x in xs])


def diff(base_doc: dict, cur_doc: dict, warn_over: float = 1.25,
         fail_over: float = 2.0) -> dict:
    """Compare artifacts; returns {rows, median_ratio, mad_log,
    missing, new, failures, warnings}."""
    base = {r["name"]: r for r in base_doc["rows"]}
    cur = {r["name"]: r for r in cur_doc["rows"]}
    names = [n for n in base if n in cur
             and not n.endswith(_NON_LATENCY_SUFFIXES)
             and base[n]["us_per_call"] > 0 and cur[n]["us_per_call"] > 0]
    logr = {n: math.log(cur[n]["us_per_call"] / base[n]["us_per_call"])
            for n in names}
    med = _median(list(logr.values()))
    mad = _mad(list(logr.values()), med)
    rows, failures, warnings = [], [], []
    for n in sorted(names):
        ratio = math.exp(logr[n])
        status = "ok"
        if ratio > fail_over:
            status = "FAIL"
            failures.append(n)
        elif ratio > warn_over and \
                logr[n] - med > 3 * max(mad, math.log(1.05)):
            status = "warn"
            warnings.append(n)
        rows.append({"name": n, "base_us": base[n]["us_per_call"],
                     "cur_us": cur[n]["us_per_call"], "ratio": ratio,
                     "status": status})
    return {"rows": rows, "median_ratio": math.exp(med), "mad_log": mad,
            "missing": sorted(set(base) - set(cur)),
            "new": sorted(set(cur) - set(base)),
            "failures": failures, "warnings": warnings}


def report(res: dict, base_meta: dict, cur_meta: dict) -> None:
    print(f"bench_diff: baseline git={base_meta.get('git_sha', '?')} "
          f"vs current git={cur_meta.get('git_sha', '?')}")
    print(f"{'row':42s} {'base_us':>10s} {'cur_us':>10s} "
          f"{'ratio':>7s} status")
    for r in res["rows"]:
        print(f"{r['name']:42s} {r['base_us']:10.1f} {r['cur_us']:10.1f} "
              f"{r['ratio']:7.2f} {r['status']}")
    print(f"median ratio {res['median_ratio']:.3f}  "
          f"(MAD of log-ratios {res['mad_log']:.3f})")
    if res["missing"]:
        print(f"rows only in baseline: {', '.join(res['missing'])}")
    if res["new"]:
        print(f"rows only in current:  {', '.join(res['new'])}")


def self_test(baseline_path: str, fail_over: float) -> int:
    """The gate must pass on an unchanged re-run and flag an injected
    slowdown strictly above the fail threshold."""
    base = load(baseline_path)
    same = diff(base, base, fail_over=fail_over)
    if same["failures"] or same["warnings"]:
        print("bench_diff self-test: identical artifacts flagged "
              f"({same['failures'] or same['warnings']}) — FAIL")
        return 1
    slowed = copy.deepcopy(base)
    victim = None
    for r in slowed["rows"]:
        if not r["name"].endswith(_NON_LATENCY_SUFFIXES) \
                and r["us_per_call"] > 0:
            r["us_per_call"] *= fail_over * 1.05
            victim = r["name"]
            break
    if victim is None:
        print("bench_diff self-test: baseline has no latency rows — FAIL")
        return 1
    inj = diff(base, slowed, fail_over=fail_over)
    if victim not in inj["failures"]:
        print(f"bench_diff self-test: injected {fail_over * 1.05:.2f}x "
              f"slowdown on {victim!r} NOT flagged — FAIL")
        return 1
    print(f"bench_diff self-test: OK (clean re-run passes; injected "
          f"{fail_over * 1.05:.2f}x slowdown on {victim!r} flagged)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current", nargs="?")
    ap.add_argument("--warn-over", type=float, default=1.25,
                    help="warn band: ratio above this AND >3 MAD above "
                         "the median log-ratio (default 1.25)")
    ap.add_argument("--fail-over", type=float, default=2.0,
                    help="hard-fail ratio (default 2.0)")
    ap.add_argument("--warn-only", action="store_true",
                    help="downgrade row failures to warnings (shared CI "
                         "runners); schema errors still exit non-zero")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate against the baseline itself "
                         "(clean pass + injected-slowdown fail)")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test(args.baseline, args.fail_over)
    if not args.current:
        ap.error("CURRENT artifact required (or use --self-test)")
    base_doc, cur_doc = load(args.baseline), load(args.current)
    res = diff(base_doc, cur_doc, args.warn_over, args.fail_over)
    report(res, base_doc.get("run_meta", {}), cur_doc.get("run_meta", {}))
    if not res["rows"]:
        print("bench_diff: no comparable rows — FAIL")
        return 1
    if res["failures"]:
        verdict = "WARN (perf regression, warn-only mode)" \
            if args.warn_only else "FAIL (perf regression)"
        print(f"bench_diff: {verdict}: {', '.join(res['failures'])}")
        return 0 if args.warn_only else 1
    if res["warnings"]:
        print(f"bench_diff: warnings: {', '.join(res['warnings'])}")
    print("bench_diff: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
