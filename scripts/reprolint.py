#!/usr/bin/env python
"""reprolint entry point (`make lint`).

Runs the repo's AST-based invariant analyzer (src/repro/analysis/)
over src/ + benchmarks/ + scripts/ and fails on any unsuppressed
finding. Works with or without PYTHONPATH=src.

    python scripts/reprolint.py                 # whole tree, all rules
    python scripts/reprolint.py --list-rules
    python scripts/reprolint.py src/repro/serving --rules RL001 --json
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.analysis.cli import main                         # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
