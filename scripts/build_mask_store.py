#!/usr/bin/env python
"""Parallel offline mask-store builder (paper §6.4: one-time costs).

Builds the packed dual-family (grammar_mask + grammar_strict) mask store
for one or more grammars and publishes it through the fingerprinted disk
cache that `build_mask_store` / the serving engine read at startup — or
that `POST /grammars` hot-loads into a live engine.

The per-DFA-state build is embarrassingly parallel: the global state
range [0, total_dfa_states) is split into shards, each worker process
computes `build_rows_shard(lo, hi)` against the shared precomputation
(token byte-matrix + suffix-pmatch tables, built once in the parent and
inherited by fork), and the parent concatenates shard outputs in
global-state order — bit-for-bit identical to the serial build — then
publishes atomically (temp file + os.replace, safe under concurrent
builders).

  PYTHONPATH=src python scripts/build_mask_store.py \
      --grammar python_mini --vocab 1024 --workers 8 \
      --cache-dir ~/.cache/repro-maskstores [--verify]

`--verify` additionally runs the serial builder and asserts the packed
arrays AND the context-split tables (cd_ptr/cd_token/cd_follow/cd_big)
are identical (used by the CI grammar-build job).
"""
from __future__ import annotations

import argparse
import multiprocessing as mp
import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

# worker state: populated in the parent BEFORE the fork so workers
# inherit the compiled grammar + shared precomputation by COW instead of
# pickling the (large) suffix tables per task
_SHARED: dict = {}


def _run_shard(bounds):
    lo, hi = bounds
    from repro.core.mask_store import build_rows_shard
    return build_rows_shard(_SHARED["grammar"], _SHARED["tokenizer"],
                            lo, hi, _SHARED["prep"])


def _shards(total: int, n: int) -> list[tuple[int, int]]:
    """Split [0, total) into n contiguous shards (last absorbs the rest).
    Over-split ~2x the worker count for load balance: terminals' DFAs
    differ wildly in live-state density, so equal state ranges are not
    equal work."""
    n = max(1, min(n, total))
    step = max(1, total // n)
    cuts = list(range(0, total, step)) + [total]
    return [(cuts[i], cuts[i + 1]) for i in range(len(cuts) - 1)
            if cuts[i] < cuts[i + 1]]


def build_parallel(name: str, vocab: int, workers: int,
                   cache_dir: str | None, verify: bool = False,
                   verbose: bool = True):
    import numpy as np

    from repro.core.grammars import load_grammar
    from repro.core.mask_store import (_prep, assemble_store,
                                       build_rows_shard, load_cached_store)
    from repro.core.tokenizer import ByteTokenizer

    tok = ByteTokenizer(vocab)
    g, _ = load_grammar(name)
    cached = load_cached_store(g, tok, cache_dir)
    if cached is not None and not verify:
        if verbose:
            print(f"[{name}] cache hit: {cached.meta['path']}")
        return cached

    t0 = time.time()
    prep = _prep(g, tok)
    total = g.total_dfa_states
    bounds = _shards(total, workers * 2)
    if workers > 1 and len(bounds) > 1:
        _SHARED.update(grammar=g, tokenizer=tok, prep=prep)
        # fork: workers inherit _SHARED; spawn would re-pickle the prep
        # tables per worker and re-import jax in each child
        with mp.get_context("fork").Pool(workers) as pool:
            parts = pool.map(_run_shard, bounds)
        _SHARED.clear()
    else:
        parts = [build_rows_shard(g, tok, lo, hi, prep)
                 for lo, hi in bounds]
    store = assemble_store(g, tok, parts, cache_dir=cache_dir,
                           verbose=verbose, t0=t0)
    if verify:
        serial = build_rows_shard(g, tok, 0, total, prep)
        want = np.concatenate([serial[0], serial[1]], axis=0)
        if not np.array_equal(store.packed, want):
            raise SystemExit(f"[{name}] FAIL: parallel build does not "
                             f"match the serial build")
        # the context-split tables must concatenate shard-obliviously
        # too: CI/CD classification is per-state, so the sharded tables
        # must equal a single [0, total) derivation bit-for-bit
        s_ptr, s_tok, s_fol, s_big = serial[2]
        for label, got, ref in (("cd_ptr", store.cd_ptr, s_ptr),
                                ("cd_token", store.cd_token, s_tok),
                                ("cd_follow", store.cd_follow, s_fol),
                                ("cd_big", store.cd_big, s_big)):
            if not np.array_equal(got, ref):
                raise SystemExit(f"[{name}] FAIL: parallel {label} does "
                                 f"not match the serial build")
        if verbose:
            print(f"[{name}] verify: parallel == serial "
                  f"({len(bounds)} shards, packed + context-split "
                  f"tables bit-exact)")
    return store


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grammar", action="append", default=None,
                    help="grammar name (repeatable; default: all builtin)")
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--workers", type=int,
                    default=max(1, (os.cpu_count() or 2) - 1))
    ap.add_argument("--cache-dir", default=None,
                    help="publish stores here (default: build only)")
    ap.add_argument("--verify", action="store_true",
                    help="also run the serial builder and assert the "
                         "packed stores and context-split tables are "
                         "bit-identical")
    args = ap.parse_args(argv)

    from repro.core.grammars import BUILTIN
    names = args.grammar or list(BUILTIN)
    for name in names:
        store = build_parallel(name, args.vocab, args.workers,
                               args.cache_dir, verify=args.verify)
        meta = store.meta
        if meta.get("cached"):
            continue
        print(f"[{name}] {meta['rows']} rows ({store.num_words} words), "
              f"{meta['bytes'] / 1e6:.1f} MB, "
              f"{meta['build_seconds']:.1f}s with {args.workers} workers"
              + (f" -> {meta['path']}" if "path" in meta else ""))


if __name__ == "__main__":
    main()
