"""Observability subsystem (repro.obs + serving integration).

Four contract groups (docs/observability.md):

  * **unit math** — log-spaced bucket layout, histogram bucket
    placement, PromQL-style quantile interpolation, Prometheus text
    rendering (cumulative buckets, escaping), trace ring buffer +
    Chrome-event export, lifecycle state machine;
  * **identity** — telemetry on vs off is token-for-token identical in
    every engine mode (dense greedy/sampled, overlap on/off, paged,
    speculative, async): observation may never perturb decoding;
  * **overhead** — the disabled span path stays under the named budget
    `DISABLED_SPAN_BUDGET_S` (cheap enough to leave in every hot path
    unconditionally), the enabled path under `ENABLED_SPAN_BUDGET_S`;
  * **purity** — `repro.obs` imports no jax/numpy (structural proof
    that telemetry cannot add device synchronization), and the serving
    loop gained no explicit sync calls.

The HTTP surface (/metrics /stats /trace /healthz) is exercised
end-to-end against a live server at the bottom.
"""
import asyncio
import json
import math
import os
import re
import subprocess
import sys
import time
from pathlib import Path

import jax
import pytest

from repro.core.decoding import DecodeConfig
from repro.obs import (DISABLED_SPAN_BUDGET_S, ENABLED_SPAN_BUDGET_S,
                       Histogram, LifecycleTracker, MetricsRegistry,
                       Telemetry, Tracer, log_buckets)
from repro.serving.engine import Engine, Request

SRC = Path(__file__).resolve().parent.parent / "src"
MAX_LEN = 160


# ============================ unit: buckets ============================

def test_log_buckets_spacing():
    b = log_buckets(1e-3, 10.0, per_decade=4)
    assert b[0] == 1e-3 and b[-1] >= 10.0
    # constant ratio 10^(1/4) between consecutive bounds
    for lo, hi in zip(b, b[1:]):
        assert hi / lo == pytest.approx(10 ** 0.25, rel=1e-9)
    # 4 decades x 4 per decade + the closing bound
    assert len(b) == 17


def test_log_buckets_rejects_bad_spec():
    for lo, hi, per in ((0.0, 1.0, 4), (1.0, 1.0, 4), (1.0, 10.0, 0),
                        (-1.0, 1.0, 4)):
        with pytest.raises(ValueError):
            log_buckets(lo, hi, per)


def test_histogram_bucket_placement():
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 3.0, 100.0):
        h.observe(v)
    # <=1.0: {0.5, 1.0}; <=2.0: {1.5}; <=4.0: {3.0}; +Inf: {100.0}
    assert h.counts == [2, 1, 1, 1]
    assert h.count == 5
    assert h.sum == pytest.approx(106.0)


def test_histogram_quantile_interpolates():
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    for _ in range(10):
        h.observe(1.5)          # all mass in the (1, 2] bucket
    # PromQL interpolation: lo + (hi-lo) * target/c inside the bucket
    assert h.quantile(0.5) == pytest.approx(1.5)
    assert h.quantile(0.0) == pytest.approx(1.0)
    assert h.quantile(1.0) == pytest.approx(2.0)


def test_histogram_quantile_overflow_and_empty():
    h = Histogram(bounds=(1.0, 2.0))
    assert math.isnan(h.quantile(0.5))          # empty
    h.observe(50.0)                             # overflow bucket
    assert h.quantile(0.5) == 2.0               # reports largest bound
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_quantile_single_sample():
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    h.observe(1.5)
    # one observation: quantiles interpolate across its bucket
    assert h.quantile(0.0) == pytest.approx(1.0)
    assert h.quantile(0.5) == pytest.approx(1.5)
    assert h.quantile(1.0) == pytest.approx(2.0)


def test_histogram_quantile_all_overflow():
    h = Histogram(bounds=(1.0, 2.0))
    for _ in range(5):
        h.observe(10.0)                 # every sample beyond the bounds
    # the overflow bucket has no upper edge: report the largest bound
    for q in (0.0, 0.5, 1.0):
        assert h.quantile(q) == 2.0
    assert h.count == 5 and h.sum == pytest.approx(50.0)


def test_histogram_rejects_unsorted_bounds():
    with pytest.raises(ValueError):
        Histogram(bounds=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram(bounds=(1.0, 1.0))


# ============================ unit: registry ===========================

def test_registry_get_or_create_and_kind_conflict():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "help", {"k": "a"})
    b = reg.counter("x_total", "help", {"k": "a"})
    c = reg.counter("x_total", "help", {"k": "b"})
    assert a is b and a is not c
    a.inc(2)
    assert b.value == 2.0
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")


def test_registry_fn_rebind():
    reg = MetricsRegistry()
    g = reg.gauge("pool", fn=lambda: 1.0)
    assert g.value == 1.0
    g2 = reg.gauge("pool", fn=lambda: 7.0)      # re-register: rebind
    assert g2 is g and g.value == 7.0


def test_registry_concurrent_writers_and_scrapes():
    """The documented threading contract: family creation is locked,
    updates are single-writer per instrument, and scrapes running
    concurrently with writers never raise or corrupt the families.
    Per-thread labeled children make the final values exact."""
    import threading
    reg = MetricsRegistry()
    n_threads, n_inc = 8, 2000
    errs = []
    start = threading.Barrier(n_threads + 2)

    def writer(i):
        try:
            start.wait()
            c = reg.counter("conc_total", "c", {"t": str(i)})
            h = reg.histogram("conc_lat", "h", buckets=(1.0, 2.0),
                              labels={"t": str(i)})
            for k in range(n_inc):
                c.inc()
                h.observe(0.5 if k % 2 else 3.0)
        except Exception as e:       # pragma: no cover - failure path
            errs.append(e)

    def scraper():
        try:
            start.wait()
            for _ in range(50):
                text = reg.render_prometheus()
                assert text.endswith("\n")
                reg.snapshot()
        except Exception as e:       # pragma: no cover - failure path
            errs.append(e)

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    threads += [threading.Thread(target=scraper) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    for i in range(n_threads):
        assert reg.counter("conc_total",
                           labels={"t": str(i)}).value == n_inc
        h = reg.histogram("conc_lat", buckets=(1.0, 2.0),
                          labels={"t": str(i)})
        assert h.count == n_inc
        assert h.counts[0] == n_inc // 2        # the 0.5 observations
    _assert_valid_prometheus(reg.render_prometheus())


_PROM_LINE = re.compile(
    r"^(# (HELP|TYPE) [a-zA-Z_:][a-zA-Z0-9_:]* .+"
    r"|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? "
    r"(NaN|[+-]?Inf|[+-]?(\d+\.?\d*([eE][+-]?\d+)?|\.\d+([eE][+-]?\d+)?)))$")


def _assert_valid_prometheus(text: str):
    assert text.endswith("\n")
    for line in text.splitlines():
        if line:
            assert _PROM_LINE.match(line), f"bad line: {line!r}"


def test_render_prometheus_counters_gauges():
    reg = MetricsRegistry()
    reg.counter("t_total", "tokens", {"kind": "a"}).inc(3)
    reg.gauge("depth", "queue").set(2.5)
    text = reg.render_prometheus()
    _assert_valid_prometheus(text)
    assert '# TYPE t_total counter' in text
    assert 't_total{kind="a"} 3' in text
    assert "depth 2.5" in text


def test_render_prometheus_histogram_cumulative():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(1.0, 2.0))
    for v in (0.5, 1.5, 9.0):
        h.observe(v)
    text = reg.render_prometheus()
    _assert_valid_prometheus(text)
    # cumulative buckets, _count == +Inf bucket, exact sum
    assert 'lat_bucket{le="1"} 1' in text
    assert 'lat_bucket{le="2"} 2' in text
    assert 'lat_bucket{le="+Inf"} 3' in text
    assert "lat_count 3" in text
    assert "lat_sum 11" in text


def test_render_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.counter("e_total", "", {"v": 'a"b\\c\nd'}).inc()
    text = reg.render_prometheus()
    assert r'e_total{v="a\"b\\c\nd"} 1' in text


# ============================= unit: trace =============================

def test_tracer_inactive_records_nothing():
    tr = Tracer(capacity=8)
    tr.add("forward", "forward", 1.0, 0.5)
    assert len(tr) == 0
    tr.start()
    tr.add("forward", "forward", 1.0, 0.5)
    tr.stop()
    tr.add("forward", "forward", 2.0, 0.5)
    assert len(tr) == 1


def test_tracer_ring_bounds_and_drop_count():
    tr = Tracer(capacity=4)
    tr.start()
    for i in range(10):
        tr.add("t", "e", float(i), 0.1)
    assert len(tr) == 4
    assert tr.dropped == 6
    doc = tr.export_chrome()
    assert doc["otherData"] == {"dropped_events": 6, "captured_events": 10,
                                "merged_device_events": 0}
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_chrome_export_shape():
    tr = Tracer()
    tr.start()
    tr.add("forward", "forward", 10.0, 0.5, {"step": 1})
    tr.add("slot 0", "req 7", 10.1, 0.2)
    tr.instant("slot 0", "token", 10.2)
    doc = tr.export_chrome()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {e["args"]["name"] for e in meta}
    assert {"repro engine", "forward", "slot 0"} <= names
    # known phase tracks order before slot tracks
    tids = {e["args"]["name"]: e["tid"] for e in meta
            if e["name"] == "thread_name"}
    assert tids["forward"] < tids["slot 0"]
    xs = [e for e in evs if e["ph"] == "X"]
    inst = [e for e in evs if e["ph"] == "i"]
    assert len(xs) == 2 and len(inst) == 1
    # µs timestamps rebased to the earliest event
    assert min(e["ts"] for e in xs) == 0.0
    fwd = next(e for e in xs if e["name"] == "forward")
    assert fwd["dur"] == pytest.approx(0.5e6)
    assert fwd["args"] == {"step": 1}
    assert inst[0]["s"] == "t"
    assert inst[0]["ts"] == pytest.approx(0.2e6)
    json.dumps(doc)             # must be JSON-serializable as-is


# =========================== unit: lifecycle ===========================

def test_lifecycle_ttft_vs_itl():
    lt = LifecycleTracker(MetricsRegistry())
    lt.on_enqueue(1)
    lt.on_admit(1)
    for _ in range(4):
        lt.on_token(1)
    rec = lt.on_finish(1, "eos")
    assert rec.tokens == 4
    assert lt.h_ttft.count == 1         # first token only
    assert lt.h_itl.count == 3          # the other three gaps
    assert lt.h_queue.count == 1
    assert lt.h_tokens.count == 1
    assert lt.inflight() == 0
    assert lt.finish_reasons() == {"eos": 1}


def test_lifecycle_admit_without_enqueue_is_sync_path():
    lt = LifecycleTracker(MetricsRegistry())
    lt.on_admit(5)              # sync engines never enqueue
    lt.on_token(5)
    lt.on_finish(5, "length")
    assert lt.h_queue.count == 1
    assert lt.h_queue.sum == pytest.approx(0.0, abs=1e-3)
    assert lt.summary()["ttft"]["count"] == 1


def test_lifecycle_unknown_rid_is_noop():
    lt = LifecycleTracker(MetricsRegistry())
    lt.on_token(99)
    assert lt.on_finish(99, "cancelled") is None
    assert lt.h_ttft.count == 0
    # the finish reason still counts (request failed before admission)
    assert lt.finish_reasons() == {"cancelled": 1}


def test_telemetry_phase_accounting():
    tele = Telemetry(enabled=True)
    with tele.span("rows_build") as sp:
        time.sleep(0.002)
    assert sp.dur >= 0.002
    assert tele.phase_seconds("rows_build") == pytest.approx(sp.dur)
    assert tele.phase_calls("rows_build") == 1
    assert tele.phase_seconds("never_entered") == 0.0
    # spans record trace events only while a capture is active
    assert len(tele.tracer) == 0
    tele.tracer.start()
    with tele.span("rows_build"):
        pass
    assert len(tele.tracer) == 1


def test_telemetry_disabled_span_is_null():
    tele = Telemetry(enabled=False)
    s1 = tele.span("forward")
    s2 = tele.span("rows_build")
    assert s1 is s2             # one shared object, zero allocation
    with s1 as sp:
        time.sleep(0.001)
    assert sp.dur == 0.0
    assert tele.phase_seconds("forward") == 0.0
    # count-style instruments stay live when disabled
    tele.counter("c_total").inc(3)
    assert tele.counter("c_total").value == 3.0


# ============================== overhead ===============================

def _best_per_call(fn, n=20000, repeats=5):
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(n)
        best = min(best, (time.perf_counter() - t0) / n)
    return best


def test_disabled_span_overhead_under_budget():
    tele = Telemetry(enabled=False)

    def run(n):
        span = tele.span
        for _ in range(n):
            with span("forward"):
                pass
    assert _best_per_call(run) < DISABLED_SPAN_BUDGET_S


def test_enabled_span_overhead_under_budget():
    tele = Telemetry(enabled=True)        # tracing off: steady state

    def run(n):
        span = tele.span
        for _ in range(n):
            with span("forward"):
                pass
    assert _best_per_call(run) < ENABLED_SPAN_BUDGET_S


# ================================ purity ===============================
# The source-level purity/sync invariants are reprolint rules
# (src/repro/analysis/ — the same implementation `make lint` runs); the
# tests here keep the original failure stories as regression tests and
# prove each rule still FIRES on the forbidden edit via source overlays.

def _lint(paths, select, overlay=None):
    from repro.analysis import lint
    return lint(SRC.parent, paths=paths, select=select, overlay=overlay)


def test_obs_package_never_imports_jax_or_numpy():
    """RL002 obs-purity: repro.obs must not import jax/numpy,
    transitively over module-level imports — the structural proof
    telemetry can never add a device sync."""
    report = _lint(("src/repro/obs", "src/repro/serving"), ["RL002"])
    assert report.ok, report.render_human()
    # adding the import back must fail with the purity story
    bad = "import numpy as np\n\n" + \
        (SRC / "repro" / "obs" / "registry.py").read_text()
    report = _lint(("src/repro/obs",), ["RL002"],
                   overlay={"src/repro/obs/registry.py": bad})
    hits = report.by_rule("RL002")
    assert hits and any("numpy" in f.message for f in hits), \
        report.render_human()
    # and transitively: a fresh interpreter importing repro.obs must not
    # end up with jax or numpy in sys.modules (runtime half of RL002)
    code = ("import sys; import repro.obs; "
            "bad = [m for m in ('jax', 'numpy') if m in sys.modules]; "
            "sys.exit(1 if bad else 0)")
    r = subprocess.run([sys.executable, "-c", code],
                       env={**os.environ, "PYTHONPATH": str(SRC)},
                       capture_output=True, text=True, timeout=60)
    assert r.returncode == 0, r.stderr


def test_serving_loop_has_no_explicit_device_sync():
    """RL003 sync-confinement: telemetry must not have smuggled a sync
    into the step loop — no block_until_ready / .item() / device_get in
    the serving package; devbridge.py is the ONE deliberate exception
    (it binds block_until_ready INTO the obs layer as an injected
    capability, invoked only in bench/profile mode —
    tests/test_devtime.py proves serving mode never calls it)."""
    report = _lint(("src/repro/serving", "src/repro/obs"), ["RL003"])
    assert report.ok, report.render_human()
    # devbridge really is the sole block_until_ready site (the rule
    # would only prove absence elsewhere, not presence there)
    bridge = (SRC / "repro" / "serving" / "devbridge.py").read_text()
    assert "block_until_ready" in bridge
    # smuggling a sync into the loop must fail with the confinement story
    loop_rel = "src/repro/serving/loop.py"
    src = (SRC / "repro" / "serving" / "loop.py").read_text()
    bad = src.replace("loop.c_decode_steps.inc()",
                      "jax.block_until_ready(logits); "
                      "loop.c_decode_steps.inc()", 1)
    assert bad != src
    report = _lint((loop_rel,), ["RL003"], overlay={loop_rel: bad})
    hits = report.by_rule("RL003")
    assert hits and any("devbridge" in f.message for f in hits), \
        report.render_human()


def test_span_bodies_stay_host_only():
    """RL004 span-hygiene: a device sync inside a telemetry span body
    would bill device time to a host phase and break the no-added-syncs
    contract. Clean at HEAD; a sync smuggled into a span body fires."""
    report = _lint(("src", "benchmarks"), ["RL004"])
    assert report.ok, report.render_human()
    loop_rel = "src/repro/serving/loop.py"
    src = (SRC / "repro" / "serving" / "loop.py").read_text()
    bad = src.replace(
        "with tele.span(\"forward\"):",
        "with tele.span(\"forward\"):\n"
        "                    jax.block_until_ready(self.caches)", 1)
    assert bad != src
    report = _lint((loop_rel,), ["RL004"], overlay={loop_rel: bad})
    assert report.by_rule("RL004"), report.render_human()


# ======================= identity: telemetry off =======================

@pytest.fixture(scope="module")
def obs_engines(tokenizer, grammar_bundle):
    """(make) factory building engine pairs that share model + params so
    telemetry on/off runs are comparable bit-for-bit."""
    from dataclasses import replace

    from repro.configs import get_config
    from repro.models.model import build_model
    bundles = {}
    for name in ("json", "jsonmsg"):
        g, tab, store, _ = grammar_bundle(name)
        bundles[name] = (g, tab, store)
    cfg = get_config("syncode-demo")
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(**kw):
        kw.setdefault("slots", 4)
        return Engine(model, params, tokenizer, bundles, max_len=MAX_LEN,
                      **kw)
    return make


def _reqs(grammar="json", n=3, max_new=12, method="sample",
          temperature=1.0):
    return [Request(rid=i, prompt=b"Q: generate. A:", grammar=grammar,
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method=method,
                                        temperature=temperature),
                    seed=i) for i in range(n)]


def _ids(states):
    return {s.req.rid: (s.token_ids, s.finish_reason) for s in states}


@pytest.mark.parametrize("method", ["greedy", "sample"])
@pytest.mark.parametrize("overlap", [True, False])
def test_dense_identity_telemetry_off(obs_engines, method, overlap):
    on = obs_engines(telemetry=True, overlap=overlap)
    off = obs_engines(telemetry=False, overlap=overlap)
    s_on, st_on = on.generate(_reqs(method=method))
    s_off, st_off = off.generate(_reqs(method=method))
    assert _ids(s_on) == _ids(s_off)
    # exact count stats survive telemetry off; timing stats read 0
    assert st_on.tokens == st_off.tokens
    assert st_on.mask_computations == st_off.mask_computations
    assert st_on.opportunistic_hits == st_off.opportunistic_hits
    assert st_on.mask_time > 0.0
    assert st_off.mask_time == 0.0


def test_paged_identity_telemetry_off(obs_engines):
    on = obs_engines(telemetry=True, paged=True, page_size=8)
    off = obs_engines(telemetry=False, paged=True, page_size=8)
    s_on, st_on = on.generate(_reqs(n=5))
    s_off, st_off = off.generate(_reqs(n=5))
    assert _ids(s_on) == _ids(s_off)
    assert st_on.kv_pages_in_use == st_off.kv_pages_in_use
    assert st_on.prefix_hit_rate == st_off.prefix_hit_rate


def test_spec_identity_telemetry_off(obs_engines):
    from repro.spec import SpecConfig
    spec = SpecConfig(literal_jump=False)
    on = obs_engines(telemetry=True)
    off = obs_engines(telemetry=False)
    s_on, st_on = on.generate_speculative(
        _reqs("jsonmsg", method="greedy"), spec=spec)
    s_off, st_off = off.generate_speculative(
        _reqs("jsonmsg", method="greedy"), spec=spec)
    assert _ids(s_on) == _ids(s_off)
    assert st_on.jump_tokens == st_off.jump_tokens
    assert st_on.draft_accepted == st_off.draft_accepted
    assert st_on.plan_time >= 0.0 and st_off.plan_time == 0.0


def test_async_identity_telemetry_off(obs_engines):
    from repro.serving.async_engine import AsyncEngine

    def run(engine):
        async def go():
            aeng = AsyncEngine(engine)
            try:
                return await aeng.generate(_reqs(n=6)), aeng
            finally:
                await aeng.drain()
        return asyncio.run(go())

    (s_on, _), aeng_on = run(obs_engines(telemetry=True))
    (s_off, _), aeng_off = run(obs_engines(telemetry=False))
    assert _ids(s_on) == _ids(s_off)
    # the enabled async engine accumulated lifecycle records
    assert aeng_on.telemetry.lifecycle.summary()["ttft"]["count"] == 6
    assert aeng_off.telemetry.lifecycle.summary() == {}


def test_sync_stats_derive_from_registry(obs_engines):
    """EngineStats.mask_time is the ci_lookup + cd_check +
    mask_dispatch + select_resolve phase sum — one source of truth,
    two views."""
    from repro.serving.async_engine import AsyncEngine

    async def go():
        aeng = AsyncEngine(obs_engines(telemetry=True))
        try:
            return await aeng.generate(_reqs()), aeng.telemetry
        finally:
            await aeng.drain()
    (_, stats), tele = asyncio.run(go())
    want = sum(tele.phase_seconds(p) for p in
               ("ci_lookup", "cd_check", "mask_dispatch",
                "select_resolve"))
    assert stats.mask_time == pytest.approx(want)
    assert tele.phase_calls("forward") > 0
    assert tele.phase_calls("host_oracle") >= 0


# ============================ HTTP surface =============================

async def _http(host, port, method, path, body=b""):
    reader, writer = await asyncio.open_connection(host, port)
    req = (f"{method} {path} HTTP/1.1\r\nHost: x\r\n"
           f"Content-Length: {len(body)}\r\n\r\n").encode() + body
    writer.write(req)
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionError, BrokenPipeError):
        pass
    head, _, rest = data.partition(b"\r\n\r\n")
    status = int(head.split(b" ")[1])
    if b"chunked" in head.lower():
        out, rem = b"", rest
        while rem:
            size, _, rem = rem.partition(b"\r\n")
            n = int(size, 16)
            if n == 0:
                break
            out += rem[:n]
            rem = rem[n + 2:]
        return status, out
    return status, rest


def test_http_observability_surface(obs_engines):
    from repro.serving.async_engine import AsyncEngine
    from repro.serving.server import EngineServer

    async def go():
        aeng = AsyncEngine(obs_engines(telemetry=True))
        srv = EngineServer(aeng)
        host, port = await srv.start(port=0)
        try:
            status, body = await _http(host, port, "POST", "/trace",
                                       b'{"action": "start"}')
            assert status == 200 and json.loads(body)["tracing"] is True

            status, body = await _http(
                host, port, "POST", "/generate",
                json.dumps({"prompt": "say:", "grammar": "json",
                            "max_new_tokens": 8, "method": "sample",
                            "temperature": 1.0, "seed": 0}).encode())
            assert status == 200
            final = [json.loads(l) for l in body.splitlines() if l][-1]
            assert final["done"] is True and final["tokens"] > 0

            # ---- /metrics: valid exposition with live series
            status, body = await _http(host, port, "GET", "/metrics")
            assert status == 200
            text = body.decode()
            _assert_valid_prometheus(text)
            for fam in ("repro_step_phase_seconds_total",
                        "repro_request_ttft_seconds",
                        "repro_tokens_total", "repro_requests_total",
                        "repro_overlap_forwards_total",
                        "repro_queue_depth", "repro_uptime_seconds"):
                assert f"# TYPE {fam} " in text, fam
            m = re.search(r"^repro_tokens_total (\S+)$", text, re.M)
            assert m and float(m.group(1)) == final["tokens"]
            m = re.search(r"^repro_request_ttft_seconds_count (\S+)$",
                          text, re.M)
            assert m and float(m.group(1)) == 1

            # ---- /stats: JSON twin of the same registry
            status, body = await _http(host, port, "GET", "/stats")
            assert status == 200
            stats = json.loads(body)
            assert stats["enabled"] is True
            assert stats["requests"]["ttft"]["count"] == 1
            assert stats["requests"]["tokens"]["mean"] == final["tokens"]
            assert stats["trace"]["active"] is True
            assert stats["metrics"]["repro_tokens_total"][
                "series"][0]["value"] == final["tokens"]

            # ---- /trace: dump carries phase slices + slot tracks
            status, body = await _http(host, port, "POST", "/trace",
                                       b'{"action": "dump"}')
            assert status == 200
            evs = json.loads(body)["traceEvents"]
            phases = {e["name"] for e in evs if e["ph"] == "X"}
            assert "forward" in phases and "ci_lookup" in phases
            tracks = {e["args"]["name"] for e in evs
                      if e.get("name") == "thread_name"}
            assert any(t.startswith("slot ") for t in tracks)

            status, body = await _http(host, port, "POST", "/trace",
                                       b'{"action": "stop"}')
            assert json.loads(body)["tracing"] is False
            status, body = await _http(host, port, "POST", "/trace",
                                       b'{"action": "clear"}')
            assert json.loads(body)["buffered_events"] == 0
            status, _ = await _http(host, port, "POST", "/trace",
                                    b'{"action": "bogus"}')
            assert status == 400

            # ---- /healthz: uptime + queue + finish reasons
            status, body = await _http(host, port, "GET", "/healthz")
            assert status == 200
            hz = json.loads(body)
            assert hz["ok"] is True and hz["uptime_seconds"] > 0
            assert hz["queue_depth"] == 0
            assert sum(hz["finish_reasons"].values()) == 1
        finally:
            await srv.stop(drain=False)
    asyncio.run(go())


def test_http_trace_start_rejected_when_disabled(obs_engines):
    from repro.serving.async_engine import AsyncEngine
    from repro.serving.server import EngineServer

    async def go():
        aeng = AsyncEngine(obs_engines(telemetry=False))
        srv = EngineServer(aeng)
        host, port = await srv.start(port=0)
        try:
            status, body = await _http(host, port, "POST", "/trace",
                                       b'{"action": "start"}')
            assert status == 409
            assert "disabled" in json.loads(body)["error"]

            status, body = await _http(
                host, port, "POST", "/generate",
                json.dumps({"prompt": "say:", "grammar": "json",
                            "max_new_tokens": 6, "method": "greedy",
                            "stream": False}).encode())
            assert status == 200
            final = json.loads(body.splitlines()[-1])
            assert final["done"] is True and final["tokens"] > 0

            # exact counters still render when telemetry is off; the
            # timing families (phases, lifecycle histograms) are absent
            status, body = await _http(host, port, "GET", "/metrics")
            assert status == 200
            text = body.decode()
            _assert_valid_prometheus(text)
            m = re.search(r"^repro_tokens_total (\S+)$", text, re.M)
            assert m and float(m.group(1)) == final["tokens"]
            assert "repro_step_phase" not in text
            assert "repro_request_ttft_seconds" not in text

            status, body = await _http(host, port, "GET", "/stats")
            assert status == 200
            assert json.loads(body)["enabled"] is False
        finally:
            await srv.stop(drain=False)
    asyncio.run(go())
