"""Jump-forward boundary fuzz (ISSUE 5 satellite).

`spec/jump.py` documents a merge-table boundary hazard: a forced literal
re-tokenized standalone might not be the canonical tokenization of the
full stream. These tests pin down, by fuzzing over grammar-sampled texts
and random cut points, that jump-forward can never COMMIT anything the
plain engine would not have committed:

  * default mode — the forced-token chain must equal an independent
    reference walk that uses only the FULL-width mask union
    (`token_mask`) + the exact oracle, i.e. exactly what any selector
    over the masked distribution is forced to pick. (Before the
    accept-row truncation fix, `forced_step`'s capped row set could
    claim popcount-1 on a wide accept set and "force" a token the true
    mask did not force.)
  * literal mode — every emitted token passes the exact oracle at its
    emission point, the emitted ids retokenize to exactly the emitted
    bytes, and the emitted bytes are grammar-forced byte-for-byte.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.constrain import GrammarConstraint
from repro.core.grammars import load_grammar
from repro.core.mask_store import build_mask_store
from repro.core.sampling import GrammarSampler
from repro.core.tokenizer import EOS_ID
from repro.spec.jump import forced_literal, jump_forward

_GRAMMARS = ("json", "jsonmsg", "calc")
_CORPUS: dict = {}


def _corpus(name, tokenizer):
    """A pile of grammar-valid texts to cut prefixes from."""
    if name not in _CORPUS:
        g, tab = load_grammar(name)
        store = build_mask_store(g, tokenizer)
        gc = GrammarConstraint(g, tab, store, tokenizer)
        texts = GrammarSampler(g, seed=7).sample_batch(
            20, budget=24, max_bytes=220)
        _CORPUS[name] = (gc, [t for t in texts if t])
    return _CORPUS[name]


def _reference_forced_walk(gc, text: bytes, budget: int):
    """What the plain engine is FORCED to emit from `text`: while the
    full-width mask union (token_mask — no row caps anywhere) has
    exactly one support point and EOS is disallowed, every selector
    commits that token. Returns the forced token ids."""
    out = []
    cur = text
    while len(out) < budget:
        mask = gc.token_mask(cur)
        eos = bool(mask[EOS_ID])
        mask = mask.copy()
        mask[EOS_ID] = False
        ids = mask.nonzero()[0]
        if eos or ids.size != 1:
            break
        t = int(ids[0])
        if not gc.is_valid_extension(cur, t):
            break               # mask over-approximation: not forced
        out.append(t)
        cur += gc.tokenizer.id_to_bytes[t]
    return out


@pytest.mark.parametrize("gname", _GRAMMARS)
@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_jump_matches_full_mask_reference(gname, data, tokenizer):
    gc, texts = _corpus(gname, tokenizer)
    text = data.draw(st.sampled_from(texts))
    cut = data.draw(st.integers(min_value=0, max_value=len(text)))
    prefix = text[:cut]
    try:
        gc.parser.partial_parse(prefix)
    except Exception:
        return                  # cut landed outside L_p(G): skip
    budget = data.draw(st.integers(min_value=1, max_value=12))
    jr = jump_forward(gc, prefix, budget)
    ref = _reference_forced_walk(gc, prefix, budget)
    assert jr.tokens == ref, (prefix, jr.tokens, ref)
    # soundness: the whole jumped run stays in L_p(G)
    cur = prefix
    for t in jr.tokens:
        assert gc.is_valid_extension(cur, t)
        cur += gc.tokenizer.id_to_bytes[t]


@pytest.mark.parametrize("gname", _GRAMMARS)
@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_literal_jump_sound_and_byte_exact(gname, data, tokenizer):
    """Literal mode may split bytes differently than the plain engine,
    but every committed token must be oracle-valid and the committed
    ids must decode to exactly the grammar-forced bytes."""
    gc, texts = _corpus(gname, tokenizer)
    text = data.draw(st.sampled_from(texts))
    cut = data.draw(st.integers(min_value=0, max_value=len(text)))
    prefix = text[:cut]
    try:
        gc.parser.partial_parse(prefix)
    except Exception:
        return
    jr = jump_forward(gc, prefix, 12, literal=True)
    cur = prefix
    for t in jr.tokens:
        assert gc.is_valid_extension(cur, t), (prefix, jr.tokens, t)
        cur += gc.tokenizer.id_to_bytes[t]
    # the ids tile the emitted byte string exactly
    assert cur == prefix + jr.text
    # and the emitted bytes never leave the grammar-forced byte chain:
    # re-walking forced bytes from the prefix must reproduce a prefix-
    # compatible chain (jump stops at branches, never crosses one)
    if jr.text:
        forced = forced_literal(gc, prefix,
                                max_bytes=max(len(jr.text), 1))
        # token-level forcing can outrun the byte-level analyzer (a
        # popcount-1 token commits multi-byte chunks at once), so only
        # require consistency where the byte analyzer DID walk
        assert jr.text[:len(forced)] == forced[:len(jr.text)] or \
            forced == b""


@pytest.mark.parametrize("gname", _GRAMMARS)
def test_jump_matches_reference_sweep(gname, tokenizer):
    """Deterministic sweep of the same property as the hypothesis fuzz
    (runs even where hypothesis is unavailable): every cut point of a
    handful of sampled texts, both modes."""
    gc, texts = _corpus(gname, tokenizer)
    checked = 0
    for text in texts:
        for cut in range(0, len(text), 3):
            prefix = text[:cut]
            try:
                gc.parser.partial_parse(prefix)
            except Exception:
                continue
            jr = jump_forward(gc, prefix, 8)
            assert jr.tokens == _reference_forced_walk(gc, prefix, 8), \
                (gname, prefix)
            lj = jump_forward(gc, prefix, 8, literal=True)
            cur = prefix
            for t in lj.tokens:
                assert gc.is_valid_extension(cur, t), (gname, prefix, t)
                cur += gc.tokenizer.id_to_bytes[t]
            assert cur == prefix + lj.text
            checked += 1
    assert checked >= 8


def test_jump_respects_budget(tokenizer):
    gc, texts = _corpus("jsonmsg", tokenizer)
    for text in texts[:5]:
        jr = jump_forward(gc, text[:4], 3)
        assert len(jr.tokens) <= 3


def test_jump_on_overflow_grammar_is_sound(tokenizer):
    """The wide-accept-set grammar from the truncation regression: the
    jump analyzer must see the FULL union at the 62-way branch point
    (kind 'free'), then force the literal tail after one byte."""
    from tests.test_accept_overflow import WIDE_GRAMMAR
    from repro.core.grammar import Grammar
    from repro.core.lr import build_lr_table
    g = Grammar(WIDE_GRAMMAR, name="wide")
    tab = build_lr_table(g)
    store = build_mask_store(g, tokenizer)
    gc = GrammarConstraint(g, tab, store, tokenizer)
    jr = jump_forward(gc, b"", 8)
    assert jr.tokens == []          # 62-way branch: nothing is forced
    ref = _reference_forced_walk(gc, b"Z", 8)
    jr2 = jump_forward(gc, b"Z", 8)
    assert jr2.tokens == ref
    assert b"".join(gc.tokenizer.id_to_bytes[t] for t in jr2.tokens) \
        == b"q"
