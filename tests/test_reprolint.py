"""reprolint (src/repro/analysis/) — the analyzer's own test suite.

Three layers:

  * **fixtures** — for each rule RL001–RL005, minimal snippets where
    the rule must FIRE (positive) and near-miss variants where it must
    stay QUIET (negative), injected as virtual overlay files so nothing
    touches disk;
  * **suppressions** — `# reprolint: disable=` parsing, mandatory
    justifications, staleness detection (RL000), and the annotation
    grammar (fresh-batch / dispatch / mutated-inflight);
  * **whole tree** — `lint(ROOT)` is clean at HEAD (zero unsuppressed
    findings: the exact gate `make lint` / CI runs) and stays inside
    the <10 s runtime budget that keeps it a cheap gate.
"""
import json
import subprocess
import sys
import time
from pathlib import Path

from repro.analysis import DEFAULT_PATHS, RULES, lint
from repro.analysis.cli import main as cli_main
from repro.analysis.project import Project, module_name
from repro.analysis.suppress import parse_directives

ROOT = Path(__file__).resolve().parents[1]
FX = "src/repro/_fx"           # fixture namespace: no on-disk files


def run_fixture(select, overlay):
    """Lint ONLY the virtual fixture files (scan path matches nothing
    on disk; overlay keys become virtual files)."""
    return lint(ROOT, paths=(FX,), select=select, overlay=overlay)


# ========================= RL001 alias-race ============================

def _rl001(src):
    body = "import numpy as np\nimport jax.numpy as jnp\n\n" + src
    return run_fixture(["RL001"], {f"{FX}/case.py": body})


def test_rl001_fires_on_mutation_after_dispatch():
    r = _rl001(
        "def f(buf):\n"
        "    out = jnp.asarray(buf)\n"
        "    buf[0] = 1\n"
        "    return out\n")
    assert len(r.by_rule("RL001")) == 1
    f = r.by_rule("RL001")[0]
    assert "mutated in place" in f.message and "PR 5" in f.message
    assert ".copy()" in f.hint


def test_rl001_quiet_when_copy_shipped():
    r = _rl001(
        "def f(buf):\n"
        "    out = jnp.asarray(buf.copy())\n"
        "    buf[0] = 1\n"
        "    return out\n")
    assert r.ok, r.render_human()


def test_rl001_quiet_when_mutation_precedes_dispatch():
    # near-miss: the mutation is BEFORE the dispatch, no loop — the
    # buffer is never touched while the computation is in flight
    r = _rl001(
        "def f(buf):\n"
        "    buf[0] = 1\n"
        "    return jnp.asarray(buf)\n")
    assert r.ok, r.render_human()


def test_rl001_quiet_on_fresh_temporaries():
    r = _rl001(
        "def f(buf):\n"
        "    a = jnp.asarray(buf + 1)\n"          # computed: fresh
        "    b = jnp.asarray(np.zeros(4))\n"      # allocation call
        "    buf[0] = 1\n"
        "    return a, b\n")
    assert r.ok, r.render_human()


def test_rl001_fires_on_loop_carried_mutation():
    r = _rl001(
        "def f(n):\n"
        "    buf = np.zeros(4)\n"
        "    for i in range(n):\n"
        "        buf[0] = i\n"
        "        yield jnp.asarray(buf)\n")
    hits = r.by_rule("RL001")
    assert len(hits) == 1 and "iteration k+1" in hits[0].message


def test_rl001_quiet_when_loop_rebinds_fresh_buffer():
    # near-miss: the buffer is reallocated every iteration, so the
    # mutation touches a NEW object, never the dispatched one
    r = _rl001(
        "def f(n):\n"
        "    for i in range(n):\n"
        "        buf = np.zeros(4)\n"
        "        buf[0] = i\n"
        "        yield jnp.asarray(buf)\n")
    assert r.ok, r.render_human()


def test_rl001_sees_through_aliases():
    r = _rl001(
        "def f(buf):\n"
        "    view = buf\n"
        "    out = jnp.asarray(view)\n"
        "    buf[0] = 1\n"
        "    return out\n")
    assert len(r.by_rule("RL001")) == 1


def test_rl001_fires_on_mutator_methods_and_copyto():
    r = _rl001(
        "def f(buf, other):\n"
        "    a = jnp.asarray(buf)\n"
        "    buf.fill(0)\n"
        "    b = jnp.asarray(other)\n"
        "    np.copyto(other, a)\n"
        "    return a, b\n")
    assert len(r.by_rule("RL001")) == 2


def test_rl001_fires_on_mutated_inflight_declaration():
    r = _rl001(
        "def f(cfg):\n"
        "    # reprolint: mutated-inflight=cfg admit() rewrites it\n"
        "    return jnp.asarray(cfg)\n")
    hits = r.by_rule("RL001")
    assert len(hits) == 1 and "mutated-inflight" in hits[0].message


def test_rl001_mutated_inflight_satisfied_by_copy():
    r = _rl001(
        "def f(cfg):\n"
        "    # reprolint: mutated-inflight=cfg admit() rewrites it\n"
        "    return jnp.asarray(cfg.copy())\n")
    assert r.ok, r.render_human()


def test_rl001_dispatch_annotation_reveals_bare_jit_calls():
    # a jitted call taking numpy args directly is invisible without the
    # annotation (near-miss: same code, no annotation -> quiet)
    bare = (
        "def f(fn, cfg):\n"
        "    out = fn(cfg)\n"
        "    cfg[0] = 1\n"
        "    return out\n")
    assert _rl001(bare).ok
    annotated = bare.replace("out = fn(cfg)",
                             "out = fn(cfg)  # reprolint: dispatch")
    hits = _rl001(annotated).by_rule("RL001")
    assert len(hits) == 1 and "mutated in place" in hits[0].message


def test_rl001_fires_on_opaque_producer_in_loop():
    r = _rl001(
        "def f(it, n):\n"
        "    for i in range(n):\n"
        "        batch = next(it)\n"
        "        yield jnp.asarray(batch)\n")
    hits = r.by_rule("RL001")
    assert len(hits) == 1 and "opaque producer" in hits[0].message
    assert "fresh-batch" in hits[0].hint


def test_rl001_fresh_batch_annotation_waives_producer():
    r = _rl001(
        "def f(it, n):\n"
        "    for i in range(n):\n"
        "        # reprolint: fresh-batch test_pipelines enforces it\n"
        "        batch = next(it)\n"
        "        yield jnp.asarray(batch)\n")
    assert r.ok, r.render_human()


def test_rl001_producer_taint_propagates_through_items():
    r = _rl001(
        "def f(it, n):\n"
        "    for i in range(n):\n"
        "        batch = next(it)\n"
        "        yield {k: jnp.asarray(v) for k, v in batch.items()}\n")
    hits = r.by_rule("RL001")
    assert len(hits) == 1 and "'v'" in hits[0].message


def test_rl001_nested_functions_are_separate_scopes():
    # the nested closure's dispatch sees no mutation in ITS scope, and
    # the outer scope has no dispatch: quiet (documented scope model)
    r = _rl001(
        "def f(buf):\n"
        "    def g():\n"
        "        return jnp.asarray(buf.copy())\n"
        "    buf[0] = 1\n"
        "    return g\n")
    assert r.ok, r.render_human()


# ========================= RL002 obs-purity ============================

def test_rl002_fires_on_direct_import_even_function_local():
    r = run_fixture(["RL002"], {
        "src/repro/obs/_fx_probe.py":
            "def f():\n"
            "    import numpy as np\n"
            "    return np.zeros(1)\n"})
    hits = r.by_rule("RL002")
    assert len(hits) == 1 and "numpy" in hits[0].message


def test_rl002_fires_transitively_with_chain_story():
    r = run_fixture(["RL002"], {
        "src/repro/obs/_fx_probe.py": "from repro import _fx_mid\n",
        "src/repro/_fx_mid.py": "import jax\n"})
    hits = r.by_rule("RL002")
    assert any("transitively" in f.message and
               "repro._fx_mid -> jax" in f.message for f in hits), \
        r.render_human()


def test_rl002_quiet_when_intermediate_import_is_lazy():
    # function-local imports in the intermediate module are lazy: they
    # cannot pull jax in at import time
    r = run_fixture(["RL002"], {
        "src/repro/obs/_fx_probe.py": "from repro import _fx_mid\n",
        "src/repro/_fx_mid.py":
            "def f():\n"
            "    import jax\n"
            "    return jax\n"})
    assert r.ok, r.render_human()


def test_rl002_ignores_non_obs_importers():
    r = run_fixture(["RL002"],
                    {f"{FX}/elsewhere.py": "import numpy as np\n"})
    assert r.ok, r.render_human()


# ====================== RL003 sync-confinement =========================

def test_rl003_fires_outside_devbridge():
    r = run_fixture(["RL003"], {
        f"{FX}/helper.py":
            "import jax\n\n"
            "def f(x):\n"
            "    return jax.block_until_ready(x)\n"})
    hits = r.by_rule("RL003")
    assert len(hits) == 1 and "devbridge" in hits[0].message


def test_rl003_quiet_in_devbridge_and_in_docstrings():
    r = run_fixture(["RL003"], {
        f"{FX}/doc.py":
            '"""block_until_ready may appear in prose freely."""\n'
            "# and in comments: block_until_ready\n"})
    assert r.ok, r.render_human()
    # the real devbridge.py (which genuinely syncs) is clean at HEAD
    r2 = lint(ROOT, paths=("src/repro/serving/devbridge.py",),
              select=["RL003"])
    assert r2.ok, r2.render_human()


def test_rl003_serving_bans_item_and_device_get():
    r = run_fixture(["RL003"], {
        "src/repro/serving/_fx_sync.py":
            "def f(x, jax):\n"
            "    a = x.item()\n"
            "    b = jax.device_get(x)\n"
            "    return a, b\n"})
    msgs = [f.message for f in r.by_rule("RL003")]
    assert len(msgs) == 2
    assert any(".item()" in m for m in msgs)
    assert any("device_get" in m for m in msgs)


def test_rl003_item_with_args_and_outside_serving_quiet():
    # dict.item(i)-style calls take args; .item() outside serving is
    # not the serving-confinement concern
    r = run_fixture(["RL003"], {
        "src/repro/serving/_fx_ok.py": "def f(x):\n"
                                       "    return x.item(0)\n",
        f"{FX}/notserving.py": "def f(x):\n"
                               "    return x.item()\n"})
    assert r.ok, r.render_human()


# ======================== RL004 span-hygiene ===========================

def test_rl004_fires_on_sync_inside_span_body():
    r = run_fixture(["RL004"], {
        f"{FX}/spanned.py":
            "def f(tele, jax, x):\n"
            "    with tele.span('forward'):\n"
            "        jax.block_until_ready(x)\n"})
    hits = r.by_rule("RL004")
    assert len(hits) == 1 and "no-added-syncs" in hits[0].message


def test_rl004_fires_on_pallas_call_and_item_in_span():
    r = run_fixture(["RL004"], {
        f"{FX}/spanned.py":
            "def f(tele, pl, x):\n"
            "    with tele.span('mask'):\n"
            "        y = pl.pallas_call(x)\n"
            "        return y.item()\n"})
    assert len(r.by_rule("RL004")) == 2


def test_rl004_quiet_for_device_span_and_nested_defs():
    r = run_fixture(["RL004"], {
        f"{FX}/spanned.py":
            "def f(tele, jax, x):\n"
            "    with tele.device_span('forward'):\n"
            "        jax.block_until_ready(x)\n"   # the bracket's job
            "    with tele.span('plan'):\n"
            "        def later():\n"               # executes elsewhere
            "            return jax.block_until_ready(x)\n"
            "        return later\n"})
    assert r.ok, r.render_human()


# ======================== RL005 kernel-parity ==========================

_KERNEL = ("import jax.experimental.pallas as pl\n\n"
           "def run(x):\n"
           "    return pl.pallas_call(None)(x)\n")
# fixture package path built at runtime: RL005 greps every
# tests/test_*.py (including THIS file) for "kernels.<pkg>" /
# "kernels/<pkg>", so the joined literal must not appear in our source
_PKG = "_fx" + "pkg"
_KDIR = "/".join(["src", "repro", "kernels", _PKG])


def test_rl005_fires_on_missing_ops_ref_and_test():
    r = run_fixture(["RL005"], {f"{_KDIR}/kernel.py": _KERNEL})
    msgs = [f.message for f in r.by_rule("RL005")]
    assert len(msgs) == 3, msgs
    assert any("ops.py" in m for m in msgs)
    assert any("ref.py" in m for m in msgs)
    assert any("no tests/test_*.py" in m for m in msgs)


def test_rl005_quiet_with_full_contract():
    r = run_fixture(["RL005"], {
        f"{_KDIR}/kernel.py": _KERNEL,
        f"{_KDIR}/ops.py": "def op():\n    pass\n",
        f"{_KDIR}/ref.py": "def ref():\n    pass\n",
        f"tests/test{_PKG}.py":
            f"from repro.kernels.{_PKG} import ops\n"})
    assert r.ok, r.render_human()


def test_rl005_missing_test_is_the_only_gap_detected():
    # near-miss: ops/ref shipped, but no test references the package
    r = run_fixture(["RL005"], {
        f"{_KDIR}/kernel.py": _KERNEL,
        f"{_KDIR}/ops.py": "def op():\n    pass\n",
        f"{_KDIR}/ref.py": "def ref():\n    pass\n"})
    msgs = [f.message for f in r.by_rule("RL005")]
    assert len(msgs) == 1 and "no tests/test_*.py" in msgs[0]


def test_rl005_ignores_packages_without_pallas_call():
    r = run_fixture(["RL005"], {
        "src/repro/kernels/_fxutil/helpers.py": "def pad(x):\n"
                                                "    return x\n"})
    assert r.ok, r.render_human()


# ================== RL000 suppressions & directives ====================

_VIOLATION = ("import jax\n\n"
              "def f(x):\n"
              "    return jax.block_until_ready(x){}\n")


def test_justified_suppression_moves_finding_aside():
    src = _VIOLATION.format(
        "  # reprolint: disable=RL003 deliberate bench timing bracket")
    r = run_fixture(["RL003"], {f"{FX}/s.py": src})
    assert r.ok and len(r.suppressed) == 1
    s = r.suppressed[0]
    assert s.rule == "RL003" and s.suppressed
    assert s.justification == "deliberate bench timing bracket"


def test_suppression_on_line_above_works():
    src = ("import jax\n\n"
           "def f(x):\n"
           "    # reprolint: disable=RL003 deliberate timing bracket\n"
           "    return jax.block_until_ready(x)\n")
    r = run_fixture(["RL003"], {f"{FX}/s.py": src})
    assert r.ok and len(r.suppressed) == 1


def test_unjustified_suppression_is_its_own_finding():
    src = _VIOLATION.format("  # reprolint: disable=RL003")
    r = run_fixture(["RL003"], {f"{FX}/s.py": src})
    rules = {f.rule for f in r.findings}
    # the malformed directive suppresses nothing AND reports itself
    assert rules == {"RL000", "RL003"}, r.render_human()
    assert any("unjustified" in f.message for f in r.by_rule("RL000"))


def test_one_word_justification_is_rejected():
    src = _VIOLATION.format("  # reprolint: disable=RL003 benchmark")
    r = run_fixture(["RL003"], {f"{FX}/s.py": src})
    assert any("unjustified" in f.message for f in r.by_rule("RL000"))


def test_stale_suppression_is_a_finding():
    src = ("def f(x):\n"
           "    return x  # reprolint: disable=RL003 nothing here syncs\n")
    r = run_fixture(["RL003"], {f"{FX}/s.py": src})
    hits = r.by_rule("RL000")
    assert len(hits) == 1 and "stale" in hits[0].message


def test_stale_check_only_counts_rules_that_ran():
    # RL003 never ran, so its suppression cannot be judged stale
    src = ("def f(x):\n"
           "    return x  # reprolint: disable=RL003 nothing here syncs\n")
    r = run_fixture(["RL001"], {f"{FX}/s.py": src})
    assert r.ok, r.render_human()


def test_unknown_directive_and_rl000_disable_are_findings():
    src = ("def f(x):  # reprolint: disable=RL000 self-suppress attempt\n"
           "    return x  # reprolint: frobnicate the whatsit\n")
    r = run_fixture(["RL001"], {f"{FX}/s.py": src})
    msgs = [f.message for f in r.by_rule("RL000")]
    assert len(msgs) == 2
    assert any("no valid rule ids" in m for m in msgs)
    assert any("unknown reprolint directive" in m for m in msgs)


def test_directives_in_strings_are_ignored():
    d = parse_directives(
        's = "# reprolint: disable=RL001 not a real directive"\n'
        "x = 1  # reprolint: disable=RL001 a real justified one\n")
    assert len(d.disables) == 1 and d.disables[0].line == 2
    assert not d.errors


def test_fresh_batch_requires_justification():
    src = ("import jax.numpy as jnp\n\n"
           "def f(it, n):\n"
           "    for i in range(n):\n"
           "        batch = next(it)  # reprolint: fresh-batch\n"
           "        yield jnp.asarray(batch)\n")
    r = run_fixture(["RL001"], {f"{FX}/s.py": src})
    rules = {f.rule for f in r.findings}
    assert "RL000" in rules and "RL001" in rules, r.render_human()


def test_multi_rule_disable_tracks_usage_per_rule():
    src = ("import jax\n\n"
           "def f(tele, x):\n"
           "    with tele.span('t'):\n"
           "        # reprolint: disable=RL003,RL004 deliberate probe here\n"
           "        return jax.block_until_ready(x)\n")
    r = run_fixture(["RL003", "RL004"], {f"{FX}/s.py": src})
    assert r.ok and {f.rule for f in r.suppressed} == {"RL003", "RL004"}


# ============================== project ================================

def test_module_name_mapping():
    assert module_name("src/repro/core/lexer.py") == "repro.core.lexer"
    assert module_name("src/repro/obs/__init__.py") == "repro.obs"
    assert module_name("benchmarks/run.py") is None


def test_overlay_replaces_disk_and_adds_virtual_files():
    proj = Project.load(ROOT, paths=("src/repro/analysis",),
                        overlay={"src/repro/analysis/cli.py": "x = 1\n",
                                 "src/virtual/extra.py": "y = 2\n"})
    assert proj.file("src/repro/analysis/cli.py").text == "x = 1\n"
    assert proj.file("src/virtual/extra.py").text == "y = 2\n"


def test_syntax_error_fixture_raises_cleanly():
    try:
        run_fixture(["RL001"], {f"{FX}/bad.py": "def f(:\n"})
    except SyntaxError:
        pass
    else:
        raise AssertionError("expected SyntaxError to propagate")


# ================================ CLI ==================================

def test_cli_clean_fixture_exits_zero(tmp_path, capsys):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "ok.py").write_text("def f():\n    return 1\n")
    rc = cli_main(["--root", str(tmp_path), "src"])
    out = capsys.readouterr().out
    assert rc == 0 and "0 finding(s)" in out


def test_cli_findings_exit_one_with_json(tmp_path, capsys):
    (tmp_path / "src").mkdir()
    (tmp_path / "src" / "bad.py").write_text(
        "import jax\n\ndef f(x):\n    return jax.block_until_ready(x)\n")
    rc = cli_main(["--root", str(tmp_path), "src", "--json"])
    out = capsys.readouterr().out
    assert rc == 1
    payload = json.loads(out)
    assert not payload["ok"]
    assert payload["findings"][0]["rule"] == "RL003"
    assert payload["findings"][0]["line"] == 4


def test_cli_list_rules_and_bad_rule_id(capsys):
    rc = cli_main(["--list-rules"])
    out = capsys.readouterr().out
    assert rc == 0
    for rid in ("RL001", "RL002", "RL003", "RL004", "RL005"):
        assert rid in out
    rc = cli_main(["--root", str(ROOT), "--rules", "RL999"])
    assert rc == 2


def test_cli_script_entrypoint_runs_without_pythonpath():
    r = subprocess.run([sys.executable, str(ROOT / "scripts" /
                                            "reprolint.py"),
                        "--list-rules"],
                       capture_output=True, text=True, timeout=60,
                       cwd=str(ROOT))
    assert r.returncode == 0 and "RL005" in r.stdout


# ============================ whole tree ===============================

def test_whole_tree_is_clean_at_head():
    """The exact gate `make lint` runs: zero unsuppressed findings over
    src/ + benchmarks/ + scripts/, every suppression justified."""
    report = lint(ROOT)
    assert report.ok, report.render_human()
    assert set(report.rules_run) == set(RULES)
    assert report.files_scanned > 50
    for s in report.suppressed:
        assert len(s.justification.split()) >= 2, s.as_dict()


def test_all_five_rules_registered_with_docs():
    assert sorted(RULES) == ["RL001", "RL002", "RL003", "RL004", "RL005"]
    for r in RULES.values():
        assert r.doc, f"{r.rid} has no docstring"
    assert DEFAULT_PATHS == ("src", "benchmarks", "scripts")


def test_lint_runtime_stays_under_budget():
    """make lint must stay a cheap gate: whole tree, all rules, < 10 s
    (CI budget asserted here so a quadratic rule cannot creep in)."""
    t0 = time.perf_counter()
    report = lint(ROOT)
    elapsed = time.perf_counter() - t0
    assert report.ok
    assert elapsed < 10.0, f"reprolint took {elapsed:.1f}s (budget 10s)"
