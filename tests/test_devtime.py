"""Device-time attribution (repro.obs.devtime + serving integration).

Contract groups (docs/observability.md §Device-time attribution):

  * **no-sync default** — with device timing off (serving mode) the
    injected sync capability is NEVER invoked: `DeviceTimer.sync_calls`
    stays 0 across a full engine run, and `span()` hands back the shared
    `NULL_DEV_SPAN` (PR 7's no-sync contract holds verbatim);
  * **measured brackets** — in bench/profile mode the span syncs on the
    arrays passed to `done()` and records a true device interval into
    the `repro_device_*` families and the `device:<fn>` trace track;
  * **profiler session** — the `POST /profile` state machine flips the
    timer into sync-on-exit mode for exactly the capture window,
    tolerates an unbound/broken backend profiler, and rebases backend
    Chrome events onto the host clock for the merged export;
  * **attribution math** — the step wall-time split prefers synced
    device seconds per kernel family and falls back to host dispatch
    spans, reporting which source produced each number;
  * **identity** — devtime on vs off is token-for-token identical
    (observation may never perturb decoding).
"""
import gzip
import json
import os
import time

import jax
import pytest

from repro.core.decoding import DecodeConfig
from repro.obs import (DEVICE_TRACK_PREFIX, NULL_DEV_SPAN, DeviceTimer,
                       MetricsRegistry, ProfilerSession, Telemetry, Tracer)
from repro.serving.engine import Engine, Request

MAX_LEN = 160


def _timer(tracer=None):
    tr = tracer or Tracer()
    return DeviceTimer(MetricsRegistry(), tr), tr


# ========================= unit: DeviceTimer ==========================

def test_span_is_null_unless_enabled_and_bound():
    dt, _ = _timer()
    assert dt.span("forward") is NULL_DEV_SPAN          # neither
    dt.enabled = True
    assert dt.span("forward") is NULL_DEV_SPAN          # no sync bound
    dt.bind(lambda out: out)
    dt.enabled = False
    assert dt.span("forward") is NULL_DEV_SPAN          # serving mode
    dt.enabled = True
    assert dt.span("forward") is not NULL_DEV_SPAN


def test_null_span_never_syncs():
    dt, _ = _timer()
    dt.bind(lambda out: (_ for _ in ()).throw(AssertionError("synced")))
    with dt.span("forward") as dv:                      # disabled
        dv.done(object())
    assert dt.sync_calls == 0
    assert dt.seconds("forward") == 0.0


def test_bound_span_syncs_and_measures():
    dt, _ = _timer()
    synced = []
    dt.bind(synced.append)
    dt.enabled = True
    with dt.span("forward") as dv:
        time.sleep(0.002)
        dv.done("arrays")
    assert synced == ["arrays"]
    assert dt.sync_calls == 1
    assert dv.dur >= 0.002
    assert dt.seconds("forward") == pytest.approx(dv.dur)
    assert dt.calls("forward") == 1
    s = dt.summary()["forward"]
    assert s["calls"] == 1 and s["seconds"] == pytest.approx(dv.dur)


def test_span_without_done_records_but_never_syncs():
    dt, _ = _timer()
    dt.bind(lambda out: (_ for _ in ()).throw(AssertionError("synced")))
    dt.enabled = True
    with dt.span("forward"):
        pass
    assert dt.sync_calls == 0
    assert dt.calls("forward") == 1


def test_span_skips_sync_on_exception():
    dt, _ = _timer()
    dt.bind(lambda out: (_ for _ in ()).throw(AssertionError("synced")))
    dt.enabled = True
    with pytest.raises(RuntimeError):
        with dt.span("forward") as dv:
            dv.done("arrays")
            raise RuntimeError("step failed")
    assert dt.sync_calls == 0                   # arrays may be invalid


def test_bind_is_idempotent():
    dt, _ = _timer()
    calls = []
    dt.bind(lambda out: calls.append("first"))
    dt.bind(lambda out: calls.append("second"))  # ignored
    dt.enabled = True
    with dt.span("f") as dv:
        dv.done(1)
    assert calls == ["first"]


def test_device_track_only_while_tracing():
    dt, tr = _timer()
    dt.bind(lambda out: out)
    dt.enabled = True
    with dt.span("forward") as dv:
        dv.done(1)
    assert len(tr) == 0
    tr.start()
    with dt.span("forward") as dv:
        dv.done(1)
    tr.stop()
    assert len(tr) == 1
    evs = tr.export_chrome()["traceEvents"]
    tracks = {e["args"]["name"] for e in evs
              if e.get("name") == "thread_name"}
    assert DEVICE_TRACK_PREFIX + "forward" in tracks


def test_set_cost_surfaces_roofline_inputs():
    dt, _ = _timer()
    dt.bind(lambda out: out)
    dt.enabled = True
    dt.set_cost("forward", flops=2e9, hbm_bytes=1e8)
    with dt.span("forward") as dv:
        time.sleep(0.001)
        dv.done(1)
    s = dt.summary()["forward"]
    assert s["flops_per_call"] == 2e9
    assert s["achieved_flops_per_s"] == pytest.approx(2e9 / s["seconds"])
    text = dt.registry.render_prometheus()
    assert 'repro_device_flops_per_call{fn="forward"} 2e+09' in text \
        or 'repro_device_flops_per_call{fn="forward"} 2000000000' in text


# ======================= unit: ProfilerSession ========================

def test_profiler_session_state_machine():
    dt, tr = _timer()
    dt.bind(lambda out: out)
    ps = ProfilerSession(dt, tr)
    assert ps.state()["active"] is False
    with pytest.raises(RuntimeError):
        ps.stop()                               # stop before start
    info = ps.start()
    assert ps.active and dt.enabled and tr.active
    assert info["backend_profiler"] is False    # no backend bound
    with pytest.raises(RuntimeError):
        ps.start()                              # double start
    out = ps.stop()
    assert not ps.active and not dt.enabled and not tr.active
    assert out["duration_s"] > 0.0
    assert ps.collect_chrome_events() == []     # nothing captured


def test_profiler_session_restores_prior_devtime_mode():
    dt, tr = _timer()
    dt.bind(lambda out: out)
    dt.enabled = True                           # bench mode before capture
    ps = ProfilerSession(dt, tr)
    ps.start()
    ps.stop()
    assert dt.enabled is True                   # restored, not reset


def test_profiler_session_tolerates_broken_backend():
    dt, tr = _timer()
    ps = ProfilerSession(dt, tr)

    def broken_start(log_dir):
        raise OSError("no backend")
    ps.bind(broken_start, lambda: None)
    info = ps.start()
    assert info["backend_profiler"] is False    # swallowed, still capturing
    assert ps.active
    ps.stop()


def test_collect_chrome_events_parses_and_rebases(tmp_path):
    dt, tr = _timer()
    ps = ProfilerSession(dt, tr)
    ps.bind(lambda d: None, lambda: None)
    ps.start(log_dir=str(tmp_path))
    # synthetic backend capture: one device thread, one python thread,
    # one noise slice — only the device kernel slice must survive
    doc = {"traceEvents": [
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 2,
         "args": {"name": "TFRT XLATfrtCpuClient/0"}},
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 3,
         "args": {"name": "python main"}},
        {"ph": "X", "pid": 1, "tid": 2, "name": "fusion.1",
         "ts": 5000.0, "dur": 40.0},
        {"ph": "X", "pid": 1, "tid": 2, "name": "ThunkExecutor work",
         "ts": 5010.0, "dur": 1.0},
        {"ph": "X", "pid": 1, "tid": 3, "name": "host_python_frame",
         "ts": 5000.0, "dur": 500.0},
    ]}
    d = tmp_path / "plugins" / "profile" / "run1"
    os.makedirs(d)
    with gzip.open(d / "host.trace.json.gz", "wt") as f:
        json.dump(doc, f)
    ps.stop()
    evs = ps.collect_chrome_events()
    assert len(evs) == 1
    ev = evs[0]
    assert ev["name"] == "fusion.1"
    assert ev["track"].startswith(DEVICE_TRACK_PREFIX + "xla ")
    # earliest picked event is pinned to the host-clock capture start
    assert ev["ts_us"] == pytest.approx(ps.host_t0 * 1e6)
    assert ev["dur_us"] == 40.0


def test_merged_export_aligns_host_and_device_tracks():
    tele = Telemetry(enabled=True)
    tele.tracer.start()
    with tele.span("rows_build"):
        time.sleep(0.001)
    tele.tracer.stop()
    host_t0 = tele.tracer._ring[0][3]           # ("X", track, name, t0, …)
    extra = [{"track": "device:xla main", "name": "fusion.7",
              "ts_us": host_t0 * 1e6, "dur_us": 10.0}]
    doc = tele.tracer.export_chrome(extra_events=extra)
    evs = doc["traceEvents"]
    tracks = {e["args"]["name"] for e in evs
              if e.get("name") == "thread_name"}
    assert "rows_build" in tracks and "device:xla main" in tracks
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"rows_build", "fusion.7"}
    assert all(e["ts"] >= 0.0 for e in xs)      # shared rebase
    assert doc["otherData"]["merged_device_events"] == 1


# ====================== unit: attribution math ========================

def _fabricate(tele, phase_seconds=(), device_seconds=()):
    for phase, s in phase_seconds:
        tele._phase(phase)[0].inc(s)
    for fn, s in device_seconds:
        tele.devtime._record(fn, 0.0, s)


def test_attribution_host_dispatch_fallback():
    tele = Telemetry(enabled=True)
    _fabricate(tele, phase_seconds=[("ci_lookup", 0.25), ("cd_check", 0.05),
                                    ("plan", 0.1),
                                    ("mask_dispatch", 0.2),
                                    ("forward", 0.4)])
    a = tele.attribution()
    assert a["seconds"]["host_grammar"] == pytest.approx(0.4)
    assert a["seconds"]["host_grammar_ci"] == pytest.approx(0.25)
    assert a["seconds"]["host_grammar_cd"] == pytest.approx(0.05)
    assert a["seconds"]["mask_sample_kernel"] == pytest.approx(0.2)
    assert a["seconds"]["forward_kernel"] == pytest.approx(0.4)
    assert a["source"] == {"mask_sample_kernel": "host-dispatch",
                           "forward_kernel": "host-dispatch"}
    assert sum(a["fractions"].values()) == pytest.approx(1.0)


def test_attribution_prefers_device_seconds():
    tele = Telemetry(enabled=True)
    _fabricate(tele,
               phase_seconds=[("mask_dispatch", 0.001), ("forward", 0.002)],
               device_seconds=[("mask_sample", 0.25), ("forward", 0.5),
                               ("overlap_forward", 0.1)])
    a = tele.attribution()
    assert a["seconds"]["mask_sample_kernel"] == pytest.approx(0.25)
    assert a["seconds"]["forward_kernel"] == pytest.approx(0.6)
    assert a["source"] == {"mask_sample_kernel": "device",
                           "forward_kernel": "device"}
    # the scrape-time counters agree with the attribution() view
    text = tele.registry.render_prometheus()
    assert 'repro_step_attribution_seconds_total' \
           '{component="forward_kernel"} 0.6' in text


def test_overlap_hidden_is_a_real_counter():
    tele = Telemetry(enabled=True)
    tele.add_overlap_hidden(0.05)
    tele.add_overlap_hidden(-1.0)               # ignored
    assert tele.attribution()["seconds"]["overlap_hidden"] == \
        pytest.approx(0.05)
    # present (and writable) even with telemetry disabled
    off = Telemetry(enabled=False)
    off.add_overlap_hidden(0.01)
    assert off.c_overlap_hidden.value == pytest.approx(0.01)
    assert off.attribution() == {"enabled": False}


# ===================== integration: engine modes ======================

@pytest.fixture(scope="module")
def dev_engines(tokenizer, grammar_bundle):
    from dataclasses import replace

    from repro.configs import get_config
    from repro.models.model import build_model
    bundles = {}
    for name in ("json",):
        g, tab, store, _ = grammar_bundle(name)
        bundles[name] = (g, tab, store)
    cfg = get_config("syncode-demo")
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(**kw):
        kw.setdefault("slots", 4)
        return Engine(model, params, tokenizer, bundles, max_len=MAX_LEN,
                      **kw)
    return make


def _reqs(n=3, max_new=10):
    return [Request(rid=i, prompt=b"Q: generate. A:", grammar="json",
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=i) for i in range(n)]


def _ids(states):
    return {s.req.rid: (s.token_ids, s.finish_reason) for s in states}


def _run_loop(eng, reqs):
    from repro.serving.loop import ListSource, StepLoop, make_mode
    loop = StepLoop(eng, make_mode(eng), ListSource(reqs))
    states, stats = loop.run()
    return states, stats, loop.tele


def test_serving_mode_never_syncs(dev_engines):
    """The tentpole no-sync guarantee, dynamically: a full serving-mode
    run (telemetry ON, device timing off) invokes the injected sync
    capability zero times and measures zero device seconds."""
    eng = dev_engines(telemetry=True)
    _, stats, tele = _run_loop(eng, _reqs())
    assert tele.devtime.sync_fn is not None     # devbridge DID bind it
    assert tele.devtime.sync_calls == 0         # ...but it never ran
    assert tele.devtime.seconds("forward") == 0.0
    assert stats.device_forward_s == 0.0
    assert stats.attribution["source"]["forward_kernel"] == \
        "host-dispatch"


def test_devtime_engine_measures_device_intervals(dev_engines):
    eng = dev_engines(telemetry=True, devtime=True)
    _, stats, tele = _run_loop(eng, _reqs())
    assert tele.devtime.sync_calls > 0
    assert stats.device_forward_s > 0.0
    assert stats.device_mask_sample_s > 0.0
    a = stats.attribution
    assert a["device_timing"] is True
    assert a["source"]["forward_kernel"] == "device"
    assert a["source"]["mask_sample_kernel"] == "device"
    # lazy HLO cost estimation attached roofline inputs to the fwd fn
    assert tele.devtime.costs.get("forward", {}).get("flops", 0) > 0
    fam = tele.devtime.summary()["forward"]
    assert fam["achieved_flops_per_s"] > 0


def test_devtime_identity(dev_engines):
    s_on, _ = dev_engines(telemetry=True, devtime=True).generate(_reqs())
    s_off, _ = dev_engines(telemetry=True).generate(_reqs())
    assert _ids(s_on) == _ids(s_off)
