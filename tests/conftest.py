"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only
launch/dryrun.py (run as its own process) forces 512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root too, so `from tests....` imports (conftest, _hypothesis_stub)
# resolve under a bare `pytest` invocation as well as `python -m pytest`
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest


@pytest.fixture(scope="session")
def tokenizer():
    from repro.core.tokenizer import ByteTokenizer
    return ByteTokenizer(1024)


def _bundle(name, tokenizer):
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import build_mask_store
    from repro.core.constrain import GrammarConstraint
    g, tab = load_grammar(name)
    store = build_mask_store(g, tokenizer)
    return g, tab, store, GrammarConstraint(g, tab, store, tokenizer)


_BUNDLES = {}


@pytest.fixture(scope="session")
def grammar_bundle(tokenizer):
    """factory: grammar_bundle(name) -> (grammar, table, store, constraint)"""
    def get(name):
        if name not in _BUNDLES:
            _BUNDLES[name] = _bundle(name, tokenizer)
        return _BUNDLES[name]
    return get
