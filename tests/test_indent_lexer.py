"""Indentation-aware lexing (%indent grammars, core/lexer.py).

The post-lex pass synthesizes NEWLINE/INDENT/DEDENT for python_mini.
Locked-in properties:

  * partial-input safety — NO byte prefix of a valid program may raise:
    a trailing NEWLINE whose lexeme can still grow stays `pending`
    instead of committing an indent decision;
  * commit monotonicity — the committed token stream of any prefix is a
    prefix of the whole input's committed stream (what makes the
    incremental parser's prefix-stack cache sound);
  * INDENT/DEDENT balance at EOF — the closure drains every open level.
"""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.grammars import load_grammar
from repro.core.lexer import (IndentationError_, LexError, lex_partial,
                              postlex_indent)
from repro.core.sampling import GrammarSampler

PROGRAMS = [
    b"x = 1\n",
    b"def f(a, b):\n    return a + b\n",
    b"if x:\n    y = 1\nelse:\n    y = 2\n",
    b"while x < 3:\n    if y:\n        z = f(1)\n    x = x + 1\n",
    b"class C(Base):\n    def m(self):\n        return 1\n    x = 2\n",
    b"x = (1 +\n  2)\n",                     # implicit line joining
    b"# leading comment\nx = 1  # trailing\n",
    b"l = [1, 2,\n      3]\nfor i in l:\n    pass\n",
    b"x = 1 + \\\n  2\n",                    # explicit line continuation
    b"\n\n# blanks and comments first\n\nx = 'str'\n",
]


@pytest.fixture(scope="module")
def pg():
    g, _ = load_grammar("python_mini")
    return g


def _postlex(g, data: bytes, at_eof: bool = False):
    toks, unlexed = lex_partial(g, data)
    return postlex_indent(g, toks, unlexed=unlexed, at_eof=at_eof)


def _synth(g):
    return g.indent_spec  # (NEWLINE, INDENT, DEDENT) terminal names


# ------------------------- partial-input safety -------------------------

@pytest.mark.parametrize("prog", PROGRAMS)
def test_every_prefix_lexes_without_raising(pg, prog):
    for k in range(len(prog) + 1):
        _postlex(pg, prog[:k])              # must not raise


def _assert_monotone(part, full, ctx):
    """part must be a prefix of full, EXCEPT its final token, which may
    still be growing at the cut (lex_partial commits an in-progress
    token once it sits in a final state — "1" before "12", "\\\n"
    before "\\\n  "). Indent decisions (synthetic tokens) never flip."""
    if part == full[:len(part)]:
        return
    assert part[:-1] == full[:len(part) - 1], ctx
    # the divergent tail is a growing LEXEME, never a flipped synthetic
    assert part[-1][1] != b"", ctx


@pytest.mark.parametrize("prog", PROGRAMS)
def test_commit_monotone_across_prefixes(pg, prog):
    """Committed tokens of every prefix form a prefix of the whole
    input's committed stream — indent decisions never flip."""
    full = [(t.type, t.value) for t in _postlex(pg, prog, at_eof=True).tokens]
    for k in range(len(prog) + 1):
        part = [(t.type, t.value) for t in _postlex(pg, prog[:k]).tokens]
        _assert_monotone(part, full, (k, prog[:k]))


def test_open_suite_tail_is_pending_not_committed(pg):
    """After "if x:\\n    " the indent decision must wait: more spaces
    could deepen the line, a newline could blank it."""
    nl_t, ind_t, _ = _synth(pg)
    res = _postlex(pg, b"if x:\n    ")
    assert res.pending is not None
    assert res.pending.type == nl_t
    assert all(t.type != ind_t for t in res.tokens)
    assert res.levels == (0,)
    # the same text terminated by a real token commits NEWLINE + INDENT
    res2 = _postlex(pg, b"if x:\n    y")
    assert res2.pending is None
    types = [t.type for t in res2.tokens]
    assert ind_t in types
    assert res2.levels == (0, 4)


# --------------------------- balance at EOF -----------------------------

@pytest.mark.parametrize("prog", PROGRAMS)
def test_indent_dedent_balance_at_eof(pg, prog):
    nl_t, ind_t, ded_t = _synth(pg)
    res = _postlex(pg, prog, at_eof=True)
    types = [t.type for t in res.tokens]
    assert types.count(ind_t) == types.count(ded_t), prog
    assert res.levels == (0,), prog
    # balance holds at every intermediate point too (DEDENT never
    # outruns INDENT)
    depth = 0
    for t in res.tokens:
        depth += (t.type == ind_t) - (t.type == ded_t)
        assert depth >= 0
    assert depth == 0


def test_blank_and_comment_lines_emit_no_newline(pg):
    nl_t, _, _ = _synth(pg)
    res = _postlex(pg, b"\n\n# c\n\nx = 1\n", at_eof=True)
    first_real = res.tokens[0]
    assert first_real.type != nl_t          # leading NEWLINEs suppressed
    assert first_real.value == b"x"


def test_bracket_joined_newlines_are_dropped(pg):
    nl_t, ind_t, _ = _synth(pg)
    res = _postlex(pg, b"x = (1 +\n        2)\n", at_eof=True)
    types = [t.type for t in res.tokens]
    assert ind_t not in types               # deep continuation, no INDENT
    assert types.count(nl_t) == 1           # only the closing NEWLINE


def test_unmatched_unindent_raises(pg):
    bad = b"if x:\n        y = 1\n    z = 2\n"
    with pytest.raises(IndentationError_):
        _postlex(pg, bad, at_eof=True)
    # ... but only once the offending NEWLINE is COMMITTED; the prefix
    # that ends inside the bad line's indentation is still open
    _postlex(pg, bad[:bad.index(b"z")])     # pending, must not raise


# --------------------- sampled programs (hypothesis) --------------------

@settings(deadline=None, max_examples=25)
@given(st.integers(0, 10 ** 6), st.data())
def test_sampled_program_prefixes_safe(seed, data):
    g, _ = load_grammar("python_mini")
    gs = GrammarSampler(g, seed=seed)
    prog = gs.sample(14, max_bytes=220)
    full = _postlex(g, prog, at_eof=True)
    nl_t, ind_t, ded_t = g.indent_spec
    types = [t.type for t in full.tokens]
    assert types.count(ind_t) == types.count(ded_t)
    assert full.levels == (0,)
    cut = data.draw(st.integers(0, len(prog)))
    part = _postlex(g, prog[:cut])
    committed = [(t.type, t.value) for t in part.tokens]
    whole = [(t.type, t.value) for t in full.tokens]
    _assert_monotone(committed, whole, (cut, prog))
