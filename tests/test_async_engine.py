"""AsyncEngine + streaming server: token-for-token identity with the
synchronous engine (dense / paged / speculative), per-token streaming,
cancellation (slot + KV pages freed immediately), deadlines, live
admission and graceful drain. The async layer drives the SAME StepLoop
as the sync entry points (serving/loop.py), so identity is asserted, not
hoped for."""
import asyncio
import json

import jax
import pytest

from repro.core.decoding import DecodeConfig
from repro.core.grammars import BUILTIN
from repro.serving.async_engine import AsyncEngine
from repro.serving.engine import Engine, Request
from repro.spec import SpecConfig

MAX_LEN = 160


@pytest.fixture(scope="module")
def engines(tokenizer, grammar_bundle):
    from dataclasses import replace

    from repro.configs import get_config
    from repro.models.model import build_model
    bundles = {}
    for name in BUILTIN:
        g, tab, store, _ = grammar_bundle(name)
        bundles[name] = (g, tab, store)
    cfg = get_config("syncode-demo")
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(grammars=None, **kw):
        kw.setdefault("slots", 4)
        bs = ({k: bundles[k] for k in grammars} if grammars is not None
              else bundles)
        return Engine(model, params, tokenizer, bs, max_len=MAX_LEN,
                      **kw)

    return make(), make(paged=True, page_size=8), make


def _reqs(grammar, n=3, max_new=14, method="sample", temperature=1.0,
          prompt=b"Q: generate. A:", seed0=0, deadline=None):
    return [Request(rid=i, prompt=prompt, grammar=grammar,
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method=method,
                                        temperature=temperature),
                    seed=seed0 + i, deadline=deadline) for i in range(n)]


def _assert_identical(sync_states, async_states):
    assert len(sync_states) == len(async_states)
    by_rid = {s.req.rid: s for s in async_states}
    for a in sync_states:
        b = by_rid[a.req.rid]
        assert a.token_ids == b.token_ids, (a.req.rid, a.generated,
                                            b.generated)
        assert a.finish_reason == b.finish_reason


def _run_async(engine, reqs, **kw):
    async def go():
        aeng = AsyncEngine(engine, **kw)
        try:
            return await aeng.generate(reqs)
        finally:
            await aeng.drain()
    return asyncio.run(go())


# ------------------------- mode equivalence ----------------------------

def test_async_dense_identical_to_sync(engines):
    dense, _, _ = engines
    for gname in ("json", "calc"):
        ss, _ = dense.generate(_reqs(gname))
        as_, _ = _run_async(dense, _reqs(gname))
        _assert_identical(ss, as_)


def test_async_dense_greedy_all_grammars(engines):
    dense, _, _ = engines
    for gname in BUILTIN:
        ss, _ = dense.generate(_reqs(gname, method="greedy"))
        as_, _ = _run_async(dense, _reqs(gname, method="greedy"))
        _assert_identical(ss, as_)


def test_async_paged_identical_to_sync(engines):
    _, paged, _ = engines
    ss, _ = paged.generate(_reqs("json", n=6, seed0=3))
    as_, _ = _run_async(paged, _reqs("json", n=6, seed0=3))
    _assert_identical(ss, as_)


def test_async_spec_greedy_identical_to_sync(engines):
    dense, paged, _ = engines
    spec = SpecConfig(literal_jump=False)
    for eng in (dense, paged):
        ss, _ = eng.generate_speculative(_reqs("jsonmsg", method="greedy"),
                                         spec=spec)
        as_, stats = _run_async(eng, _reqs("jsonmsg", method="greedy"),
                                spec=spec)
        _assert_identical(ss, as_)
    assert stats.jump_tokens >= 0


def test_async_more_requests_than_slots(engines):
    dense, _, _ = engines
    n = 2 * dense.slots + 3
    ss, _ = dense.generate(_reqs("json", n=n, seed0=20))
    as_, stats = _run_async(dense, _reqs("json", n=n, seed0=20))
    _assert_identical(ss, as_)
    assert stats.requests == n


# --------------------- overlap on/off equivalence ----------------------

def test_overlap_identical_to_no_overlap(engines):
    _, _, make = engines
    on, off = make(overlap=True), make(overlap=False)
    for gname in ("json", "jsonmsg"):
        a, sa = on.generate(_reqs(gname, n=5, max_new=16))
        b, sb = off.generate(_reqs(gname, n=5, max_new=16))
        _assert_identical(a, b)
    assert sa.overlap_dispatched > 0
    assert sb.overlap_dispatched == 0


def test_overlap_speculative_forwards_reused(engines):
    """Structurally tight masks (schema-forced jsonmsg, the
    indentation-disciplined python_mini) keep the masked greedy argmax
    inside the exact oracle almost every step: most speculative
    forwards must be CONSUMED, not discarded. (Loose-mask grammars like
    plain json under a random-init model land in the hostile regime —
    that side is covered by the gate-bound test below.)"""
    _, _, make = engines
    for gname in ("jsonmsg", "python_mini"):
        eng = make(overlap=True, slots=2)
        _, stats = eng.generate(_reqs(gname, n=2, max_new=24,
                                      method="greedy"))
        assert stats.overlap_dispatched > 0, gname
        assert stats.overlap_hits > stats.overlap_dispatched // 2, (
            gname, stats.overlap_hits, stats.overlap_dispatched)


def test_overlap_gate_bounds_discarded_forwards(engines):
    """The adaptive gate's contract, regime-independent: discarded
    speculative forwards are bounded by warm-up + sparse probes +
    consumed forwards. A workload whose overapproximate mask rejects at
    the exact oracle most steps must not keep paying for forwards it
    keeps discarding."""
    from repro.serving.loop import DenseMode
    _, _, make = engines
    for gname in ("json", "calc", "jsonmsg"):
        eng = make(overlap=True, slots=2)
        _, stats = eng.generate(_reqs(gname, n=2, max_new=24,
                                      method="greedy"))
        misses = stats.overlap_dispatched - stats.overlap_hits
        budget = (DenseMode.OVERLAP_WARMUP
                  + stats.decode_steps // DenseMode.OVERLAP_PROBE
                  + stats.overlap_hits + 2)
        assert misses <= budget, (gname, stats.overlap_dispatched,
                                  stats.overlap_hits, stats.decode_steps)


# ------------------------------ streaming ------------------------------

def test_streamed_tokens_match_batch_output(engines):
    dense, _, _ = engines
    sync_states, _ = dense.generate(_reqs("json", n=3, seed0=7))
    by_rid = {s.req.rid: s for s in sync_states}

    async def go():
        aeng = AsyncEngine(dense)
        handles = [aeng.submit(r) for r in _reqs("json", n=3, seed0=7)]
        try:
            for h in handles:
                ids, text = [], b""
                async for tid, tb in h.tokens():
                    ids.append(tid)
                    text += tb
                st = await h.result()
                ref = by_rid[h.req.rid]
                assert text == ref.generated == st.generated
                from repro.core.tokenizer import EOS_ID
                assert ids == [t for t in ref.token_ids[len(
                    dense._request_ids(h.req)):] if t != EOS_ID]
        finally:
            await aeng.drain()
    asyncio.run(go())


def test_live_admission_between_batches(engines):
    """The persistent loop idles between submissions and serves later
    ones identically (no per-call state leaks across waves)."""
    dense, _, _ = engines
    s1, _ = dense.generate(_reqs("calc", n=2, seed0=40))
    s2, _ = dense.generate(_reqs("json", n=2, seed0=50))

    async def go():
        aeng = AsyncEngine(dense)
        try:
            a1, _ = await aeng.generate(_reqs("calc", n=2, seed0=40))
            await asyncio.sleep(0.3)        # loop goes idle
            a2, _ = await aeng.generate(_reqs("json", n=2, seed0=50))
            return a1, a2
        finally:
            await aeng.drain()
    a1, a2 = asyncio.run(go())
    _assert_identical(s1, a1)
    _assert_identical(s2, a2)


# ------------------------ cancellation / deadlines ---------------------

def test_cancel_mid_decode_frees_slot(engines):
    dense, _, _ = engines

    async def go():
        aeng = AsyncEngine(dense)
        try:
            long = Request(rid=0, prompt=b"Q:", grammar="json",
                           max_new_tokens=120,
                           decode=DecodeConfig(method="sample",
                                               temperature=1.0), seed=1)
            h = aeng.submit(long)
            seen = 0
            async for _tid, _tb in h.tokens():
                seen += 1
                if seen == 3:
                    h.cancel()
            st = await h.result()
            assert st.finish_reason == "cancelled"
            assert st.steps < 120
            # the slot is free again: a fresh request admits and runs
            ss, _ = await aeng.generate(_reqs("json", n=2, seed0=60))
            assert all(s.finish_reason in ("eos", "length", "max_len")
                       for s in ss)
            return st
        finally:
            await aeng.drain()
    asyncio.run(go())


def test_cancel_paged_frees_kv_pages(engines):
    """Cancellation releases the slot's page table immediately;
    refcounts stay consistent (a follow-up wave reuses the pool and
    matches the sync engine exactly)."""
    _, paged, _ = engines
    sync_states, _ = paged.generate(_reqs("json", n=3, seed0=70))

    async def go():
        aeng = AsyncEngine(paged)
        try:
            h = aeng.submit(Request(
                rid=999, prompt=b"Q: generate. A:", grammar="json",
                max_new_tokens=120,
                decode=DecodeConfig(method="sample", temperature=1.0),
                seed=5))
            async for _tid, _tb in h.tokens():
                h.cancel()                   # cancel after first token
            st = await h.result()
            assert st.finish_reason == "cancelled"
            alloc = aeng._loop_obj.mode.alloc
            # per-slot page tables all empty once the slot released
            assert all(len(t) == 0 for t in alloc.tables)
            # every still-referenced page is cache-held, refcount-sane
            assert all(rc >= 0 for rc in alloc.refcount)
            a, _ = await aeng.generate(_reqs("json", n=3, seed0=70))
            return a
        finally:
            await aeng.drain()
    a = asyncio.run(go())
    _assert_identical(sync_states, a)


def test_cancel_queued_request_never_admits(engines):
    dense, _, _ = engines

    async def go():
        aeng = AsyncEngine(dense)
        try:
            # fill every slot with long requests, then queue one more
            longs = [aeng.submit(r) for r in _reqs(
                "json", n=dense.slots, max_new=60, seed0=80)]
            queued = aeng.submit(Request(
                rid=500, prompt=b"Q:", grammar="json", max_new_tokens=5,
                decode=DecodeConfig(method="greedy"), seed=0))
            queued.cancel()
            st = await queued.result()
            assert st.finish_reason == "cancelled"
            assert st.steps == 0 and st.generated == b""
            for h in longs:
                h.cancel()
        finally:
            await aeng.drain()
    asyncio.run(go())


def test_deadline_finishes_with_distinct_reason(engines):
    dense, _, _ = engines

    async def go():
        aeng = AsyncEngine(dense)
        try:
            h = aeng.submit(Request(
                rid=0, prompt=b"Q:", grammar="json", max_new_tokens=500,
                decode=DecodeConfig(method="sample", temperature=1.0),
                seed=3, deadline=0.05))
            st = await h.result()
            assert st.finish_reason == "deadline"
            assert st.steps < 500
            # deadline of a finished-in-time request never fires
            ok = aeng.submit(Request(
                rid=1, prompt=b"Q:", grammar="calc", max_new_tokens=4,
                decode=DecodeConfig(method="greedy"), seed=0,
                deadline=60.0))
            st2 = await ok.result()
            assert st2.finish_reason in ("eos", "length", "max_len")
        finally:
            await aeng.drain()
    asyncio.run(go())


def test_abort_cancels_everything(engines):
    dense, _, _ = engines

    async def go():
        aeng = AsyncEngine(dense)
        # unconstrained greedy decoding is deterministic and (checked)
        # does not emit EOS this quickly, so nothing finishes early
        hs = [aeng.submit(Request(rid=i, prompt=b"Q%d:" % i, grammar=None,
                                  max_new_tokens=4000,
                                  decode=DecodeConfig(method="greedy"),
                                  seed=90 + i)) for i in range(6)]
        await asyncio.sleep(0.1)
        await aeng.abort()
        for h in hs:
            st = await h.result()
            assert st.finish_reason == "cancelled"
    asyncio.run(go())


# ------------------- grammar modes + hot grammar loading ---------------

def test_request_grammar_mode_overrides_engine_default(engines):
    dense, _, make = engines
    req = _reqs("json", n=1)[0]
    assert dense._make_constraint(req).mode == "grammar_mask"
    req.grammar_mode = "grammar_strict"
    assert dense._make_constraint(req).mode == "grammar_strict"
    strict_eng = make(grammar_mode="grammar_strict")
    req.grammar_mode = None                 # falls back to engine default
    assert strict_eng._make_constraint(req).mode == "grammar_strict"
    with pytest.raises(ValueError, match="grammar_mode"):
        make(grammar_mode="nope")


def test_strict_mode_end_to_end(engines):
    """python_mini through the real engine in grammar_strict: every
    output is a valid partial program, every complete one recognized."""
    from repro.core.parser import IncrementalParser
    dense, _, _ = engines
    reqs = _reqs("python_mini", n=3, max_new=18)
    for r in reqs:
        r.grammar_mode = "grammar_strict"
    states, _ = dense.generate(reqs)
    g, tab = dense.bundles["python_mini"][:2]
    p = IncrementalParser(g, tab)
    for s in states:
        p.partial_parse(s.generated)        # must not raise
        if s.finish_reason == "eos":
            assert p.recognize(s.generated)


def test_hot_load_grammar_mid_serving(engines, grammar_bundle):
    """The acceptance criterion: load_grammar() on a LIVE AsyncEngine —
    requests already streaming keep running, and requests submitted
    after the load use the new grammar with no restart, token-for-token
    identical to an engine built with the grammar from the start."""
    _, _, make = engines
    g, tab, store, _ = grammar_bundle("python_mini")
    bundle = (g, tab, store)
    # reference: engine born with both grammars, same insertion order
    ref_eng = make(grammars=("json", "python_mini"))
    py_reqs = _reqs("python_mini", n=2, max_new=12, seed0=5)
    ref_states, _ = ref_eng.generate(py_reqs)

    eng = make(grammars=("json",))
    assert "python_mini" not in eng.bundles

    async def go():
        aeng = AsyncEngine(eng)
        try:
            # keep the loop busy across the load (distinct rid: the
            # python_mini wave below reuses rids 0..1)
            busy_req = _reqs("json", n=1, max_new=40, seed0=9)[0]
            busy_req.rid = 777
            busy = aeng.submit(busy_req)
            await aeng.load_grammar("python_mini", bundle)
            assert "python_mini" in eng.bundles
            after, _ = await aeng.generate(
                _reqs("python_mini", n=2, max_new=12, seed0=5))
            st_busy = await busy.result()
            assert st_busy.finish_reason in ("eos", "length", "max_len")
            return after
        finally:
            await aeng.drain()
    after = asyncio.run(go())
    _assert_identical(ref_states, after)


def test_hot_load_rejects_duplicates_and_undersized_stores(engines,
                                                           grammar_bundle):
    _, _, make = engines
    g, tab, store, _ = grammar_bundle("calc")
    eng = make(grammars=("json", "calc"))

    async def go():
        aeng = AsyncEngine(eng)
        try:
            with pytest.raises(ValueError, match="already registered"):
                await aeng.load_grammar("calc", (g, tab, store))
        finally:
            await aeng.drain()
    asyncio.run(go())


# ----------------------------- HTTP server -----------------------------

async def _http(host, port, method, path, body=b""):
    reader, writer = await asyncio.open_connection(host, port)
    req = (f"{method} {path} HTTP/1.1\r\nHost: x\r\n"
           f"Content-Length: {len(body)}\r\n\r\n").encode() + body
    writer.write(req)
    await writer.drain()
    data = await reader.read()
    writer.close()
    try:
        await writer.wait_closed()
    except (ConnectionError, BrokenPipeError):
        pass
    head, _, rest = data.partition(b"\r\n\r\n")
    status = int(head.split(b" ")[1])
    if b"chunked" in head.lower():
        out, rem = b"", rest
        while rem:
            size, _, rem = rem.partition(b"\r\n")
            n = int(size, 16)
            if n == 0:
                break
            out += rem[:n]
            rem = rem[n + 2:]
        return status, out
    return status, rest


def test_server_streams_and_matches_sync(engines):
    from repro.serving.server import EngineServer
    dense, _, _ = engines
    sync_states, _ = dense.generate(
        [Request(rid=0, prompt=b"say:", grammar="json", max_new_tokens=10,
                 decode=DecodeConfig(method="sample", temperature=1.0),
                 seed=0)])

    async def go():
        aeng = AsyncEngine(dense)
        srv = EngineServer(aeng)
        host, port = await srv.start(port=0)
        try:
            status, body = await _http(
                host, port, "GET", "/healthz")
            assert status == 200
            assert json.loads(body)["ok"] is True

            status, body = await _http(
                host, port, "POST", "/generate",
                json.dumps({"prompt": "say:", "grammar": "json",
                            "max_new_tokens": 10, "method": "sample",
                            "temperature": 1.0, "seed": 0}).encode())
            assert status == 200
            lines = [json.loads(l) for l in body.splitlines() if l]
            final = lines[-1]
            assert final["done"] is True
            streamed = "".join(l["text"] for l in lines[:-1])
            assert streamed == final["text"]
            assert final["text"] == sync_states[0].generated.decode()
            assert final["finish_reason"] == sync_states[0].finish_reason

            status, body = await _http(
                host, port, "POST", "/generate",
                json.dumps({"grammar": "nope"}).encode())
            assert status == 400
        finally:
            await srv.stop(drain=False)
    asyncio.run(go())


def test_server_disconnect_cancels_request(engines):
    from repro.serving.server import EngineServer
    dense, _, _ = engines

    async def go():
        aeng = AsyncEngine(dense)
        srv = EngineServer(aeng)
        host, port = await srv.start(port=0)
        try:
            reader, writer = await asyncio.open_connection(host, port)
            body = json.dumps({"prompt": "Q:", "grammar": "json",
                               "max_new_tokens": 400, "method": "sample",
                               "temperature": 1.0}).encode()
            writer.write((f"POST /generate HTTP/1.1\r\nHost: x\r\n"
                          f"Content-Length: {len(body)}\r\n\r\n"
                          ).encode() + body)
            await writer.drain()
            await reader.readline()          # status line arrives
            writer.close()                   # client walks away
            try:
                await writer.wait_closed()
            except (ConnectionError, BrokenPipeError):
                pass
            # the request must get cancelled and its slot freed
            for _ in range(300):
                await asyncio.sleep(0.02)
                if not aeng._loop_obj.active() and not aeng._handles:
                    break
            assert not aeng._loop_obj.active()
        finally:
            await srv.stop(drain=False)
    asyncio.run(go())


def test_server_grammar_mode_and_hot_load(engines):
    """POST /grammars compiles + hot-loads a grammar into the live
    server; the next /generate can use it. grammar_mode is validated
    and plumbed per-request."""
    from repro.serving.server import EngineServer
    _, _, make = engines
    eng = make(grammars=("json",))
    tiny = 'start: "x" start | "x"\n'

    async def go():
        aeng = AsyncEngine(eng)
        srv = EngineServer(aeng)
        host, port = await srv.start(port=0)
        try:
            # bad grammar_mode -> 400 before touching the engine
            status, body = await _http(
                host, port, "POST", "/generate",
                json.dumps({"grammar": "json",
                            "grammar_mode": "nope"}).encode())
            assert status == 400
            # unknown grammar pre-load -> 400
            status, _ = await _http(
                host, port, "POST", "/generate",
                json.dumps({"grammar": "tiny"}).encode())
            assert status == 400
            # hot-load the grammar
            status, body = await _http(
                host, port, "POST", "/grammars",
                json.dumps({"name": "tiny", "text": tiny}).encode())
            assert status == 200, body
            assert json.loads(body)["ok"] is True
            status, body = await _http(host, port, "GET", "/healthz")
            assert "tiny" in json.loads(body)["grammars"]
            # generate with it, strict mode, no restart
            status, body = await _http(
                host, port, "POST", "/generate",
                json.dumps({"prompt": "go:", "grammar": "tiny",
                            "grammar_mode": "grammar_strict",
                            "max_new_tokens": 6, "stream": False}).encode())
            assert status == 200, body
            final = json.loads(body.splitlines()[-1])
            assert final["done"] is True
            assert set(final["text"]) <= {"x"}
            # duplicate -> 409; uncompilable text -> 400
            status, _ = await _http(
                host, port, "POST", "/grammars",
                json.dumps({"name": "tiny", "text": tiny}).encode())
            assert status == 409
            status, _ = await _http(
                host, port, "POST", "/grammars",
                json.dumps({"name": "bad", "text": "start: %%"}).encode())
            assert status == 400
        finally:
            await srv.stop(drain=False)
    asyncio.run(go())
