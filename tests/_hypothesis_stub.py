"""Graceful degradation when `hypothesis` is not installed.

Test modules import `given / settings / strategies` from here via a
try/except around the real hypothesis import. When hypothesis is present
this module is unused. When it is absent, `@given` tests are collected
but skip at runtime (with a clear reason), while every non-property test
in the same module still runs — `pytest.importorskip` at module level
would throw those away too.

`st` is an "accept-anything" strategy shim so module-level strategy
definitions (e.g. recursive JSON value strategies) still evaluate.
"""
from __future__ import annotations

import pytest


class _AnyStrategy:
    """Absorbs any strategy-building expression and returns itself."""

    def __call__(self, *args, **kwargs):
        return self

    def __getattr__(self, name):
        return self

    def __or__(self, other):
        return self

    def __ror__(self, other):
        return self


st = _AnyStrategy()


def settings(*args, **kwargs):
    def deco(fn):
        return fn
    return deco


def given(*gargs, **gkwargs):
    """Like hypothesis.given, the wrapper's signature drops the
    strategy-filled parameters (positional strategies fill from the right)
    so fixtures and @pytest.mark.parametrize args still resolve."""
    def deco(fn):
        import inspect

        params = list(inspect.signature(fn).parameters.values())
        if gargs:
            params = params[:-len(gargs)] if len(gargs) <= len(params) else []
        params = [p for p in params if p.name not in gkwargs]

        def skipper(*args, **kwargs):
            pytest.skip("hypothesis not installed; property test skipped")
        skipper.__name__ = fn.__name__
        skipper.__doc__ = fn.__doc__
        skipper.__module__ = fn.__module__
        skipper.__signature__ = inspect.Signature(params)
        return skipper
    return deco
