"""Regression: accept sets wider than the base row bucket must NOT be
truncated (the soundness hole: `step_rows` used to cap at MAX_ACCEPT=48
and silently drop the rest, over-constraining the mask and banning
grammar-valid tokens).

The wide grammar below has 62 alternative two-byte literals with 62
distinct first bytes, so the start state's accept set is 62 rows — 14 of
them used to fall off the cap, banning every token that could only start
those alternatives.
"""
import numpy as np
import pytest

from repro.core.constrain import GrammarConstraint, MAX_ACCEPT, accept_width
from repro.core.grammar import Grammar
from repro.core.lr import build_lr_table
from repro.core.mask_store import build_mask_store
from repro.core.tokenizer import ByteTokenizer

# 62 distinct first bytes: A-Z a-z 0-9
_FIRST = ("ABCDEFGHIJKLMNOPQRSTUVWXYZ"
          "abcdefghijklmnopqrstuvwxyz"
          "0123456789")
_LITERALS = [c + "q" for c in _FIRST]

WIDE_GRAMMAR = "start: " + " | ".join(f'"{lit}"' for lit in _LITERALS) + "\n"


@pytest.fixture(scope="module")
def wide_gc():
    tok = ByteTokenizer(1024)
    g = Grammar(WIDE_GRAMMAR, name="wide")
    tab = build_lr_table(g)
    store = build_mask_store(g, tok)
    return GrammarConstraint(g, tab, store, tok), tok


def _byte_token(tok, ch: str) -> int:
    tid = tok.encode(ch.encode())[0]
    assert tok.id_to_bytes[tid][:1] == ch.encode()
    return tid


def test_accept_width_buckets():
    assert accept_width(0) == MAX_ACCEPT
    assert accept_width(MAX_ACCEPT) == MAX_ACCEPT
    assert accept_width(MAX_ACCEPT + 1) == 2 * MAX_ACCEPT
    assert accept_width(3 * MAX_ACCEPT) == 4 * MAX_ACCEPT


def test_step_rows_never_truncates(wide_gc):
    gc, tok = wide_gc
    sm = gc.step_rows(b"")
    n_rows = int((sm.rows >= 0).sum())
    assert sm.num_sequences >= len(_LITERALS)
    assert n_rows > MAX_ACCEPT, "grammar must overflow the base bucket"
    assert sm.rows.shape[0] == accept_width(n_rows)


def test_overflow_rows_keep_valid_tokens(wide_gc):
    """Every alternative's first byte must survive the mask. Under the
    old cap, the rows beyond MAX_ACCEPT were dropped and their
    alternatives' tokens banned."""
    gc, tok = wide_gc
    mask = gc.token_mask(b"")
    for ch in _FIRST:
        tid = _byte_token(tok, ch)
        assert gc.is_valid_extension(b"", tid), ch
        assert mask[tid], f"grammar-valid token {ch!r} banned by the mask"


def test_truncated_mask_would_have_banned_tokens(wide_gc):
    """Sanity that this IS a regression test: re-applying the old cap
    (first MAX_ACCEPT rows only) bans at least one token the exact
    oracle allows."""
    gc, tok = wide_gc
    sm = gc.step_rows(b"")
    old_mask = gc.store.unpack(gc.store.union_rows(sm.rows[:MAX_ACCEPT]))
    banned = [ch for ch in _FIRST
              if gc.is_valid_extension(b"", _byte_token(tok, ch))
              and not old_mask[_byte_token(tok, ch)]]
    assert banned, "old truncation no longer reproducible — update test"


def test_forced_step_not_confused_by_overflow(wide_gc):
    """forced_step must see the FULL union (62 candidates -> 'free'), not
    a capped one that could collapse to a bogus forced token."""
    gc, tok = wide_gc
    kind, token, sm = gc.forced_step(b"")
    assert kind == "free"
    # after the first byte, the literal's second byte is truly forced
    kind, token, _ = gc.forced_step(b"A")
    assert kind == "token"
    assert gc.tokenizer.id_to_bytes[token] == b"q"


def test_step_rows_batch_grows_width(wide_gc):
    gc, tok = wide_gc
    rows, cd, eos, nseq = GrammarConstraint.step_rows_batch(
        [gc, None, gc], [b"", b"", b"Aq"])
    assert cd.shape == (3, gc.store.num_words) and (cd[1] == 0).all()
    assert rows.shape[1] > MAX_ACCEPT
    assert (rows[1] == -1).all()
    # the narrow slot (after "Aq" the sentence can only end) pads out
    assert int((rows[2] >= 0).sum()) <= MAX_ACCEPT
    assert eos[2]


def test_engine_serves_wide_grammar(wide_gc):
    """End-to-end through the batched engine: the [B, A] fused mask+
    sample path must ride the wider bucket and complete validly."""
    import jax
    from dataclasses import replace

    from repro.configs import get_config
    from repro.core.decoding import DecodeConfig
    from repro.core.parser import IncrementalParser
    from repro.models.model import build_model
    from repro.serving.engine import Engine, Request

    gc, tok = wide_gc
    bundles = {"wide": (gc.grammar, gc.parser.table, gc.store)}
    cfg = get_config("syncode-demo")
    cfg = replace(cfg, vocab_size=tok.vocab_size, num_layers=1,
                  d_model=64, d_ff=128, num_heads=2, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, tok, bundles, max_len=64, slots=2)
    reqs = [Request(rid=i, prompt=b"go:", grammar="wide", max_new_tokens=8,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=i) for i in range(3)]
    states, _ = engine.generate(reqs)
    p = IncrementalParser(gc.grammar, gc.parser.table)
    for st in states:
        assert st.finish_reason == "eos"
        assert p.recognize(st.generated), st.generated
