"""Regex -> DFA engine vs Python's `re` (property-based)."""
import re

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.regex import compile_literal, compile_regex

PATTERNS = [
    r"[0-9]+",
    r"[0-9]+\.[0-9]+",
    r"[a-zA-Z_]\w*",
    r'"[^"]*"',
    r"(a|bc)*d",
    r"0x[0-9a-fA-F]+",
    r"a{2,4}b?",
    r"-?\d+(\.\d+)?([eE][-+]?\d+)?",
    r"'[^'\n]*'",
    r"(foo|bar|baz)+",
    r"[^a-z]+",
]


@pytest.mark.parametrize("pat", PATTERNS)
@settings(max_examples=200, deadline=None)
@given(s=st.text(alphabet="abcdefxyz0123456789.\"'-+eE_ \n", max_size=10))
def test_matches_python_re(pat, s):
    dfa = compile_regex(pat)
    got = dfa.accepts(s.encode())
    want = re.fullmatch(pat, s) is not None
    assert got == want, (pat, s)


def test_case_insensitive_literal():
    d = compile_literal("SELECT", ignore_case=True)
    assert d.accepts(b"select") and d.accepts(b"SeLeCt")
    assert not d.accepts(b"selec") and not d.accepts(b"selects")


def test_live_states():
    d = compile_regex(r"[0-9]+\.[0-9]+")
    q = d.walk(d.start, b"12.")
    assert d.is_live(q) and not d.finals[q]
    q2 = d.walk(d.start, b"12.5")
    assert d.finals[q2]
    q3 = d.walk(d.start, b"12.5x")
    assert not d.is_live(q3)


def test_hex_escape():
    d = compile_regex(r"[^\x00-\x1f]+")
    assert d.accepts(b"abc ")
    assert not d.accepts(b"a\x01b")


def test_minimized_transition_table_shape():
    d = compile_regex(r"(a|b)*abb")
    assert d.trans.shape[1] == 256
    assert d.trans.dtype == np.int32
    # dead sink exists and self-loops
    dead = [q for q in range(d.num_states) if not d.live[q]]
    for q in dead:
        assert set(d.trans[q].tolist()) <= set(dead)
