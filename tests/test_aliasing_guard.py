"""Zero-copy aliasing guards.

On CPU, `jnp.asarray` (and a jitted call taking numpy args directly)
may zero-copy alias host numpy memory. A host buffer that is mutated in
place after being shipped to an ASYNC device computation is then
mutated under the computation's feet — root-caused in PR 5 from a
5.47-magnitude logits drift in chunked-prefill runs.

The source-level guards here are reprolint RL001 (src/repro/analysis/),
the same rule `make lint` runs over the whole tree — there is exactly
ONE implementation of the invariant. These tests keep the original
failure stories as regression tests:

 1. the serving step-loop dispatch sites must keep shipping PRIVATE
    copies of the long-lived, mutated-in-place cursor arrays
    (cur_tok / feed_pos) — proven by running RL001 against an overlay
    where the .copy() has been "cleaned up", which must fail loudly
    with the PR 5 story;
 2. the fused mask+select dispatch must keep copying the admit()-
    mutated decode-config arrays (greedy/temp/top_k/top_p) — enforced
    through the `# reprolint: mutated-inflight=` declarations on the
    dispatch functions;
 3. the training pipelines must return freshly allocated batches (the
    training loop ships them with a bare jnp.asarray on the strength
    of its `# reprolint: fresh-batch` contract — see training/data.py).
"""
from pathlib import Path

import numpy as np

from repro.analysis import lint

ROOT = Path(__file__).resolve().parents[1]
LOOP = "src/repro/serving/loop.py"
ENGINE = "src/repro/serving/engine.py"
TRAIN = "src/repro/training/train_loop.py"


def _rl001(path, overlay=None):
    return lint(ROOT, paths=(path,), select=["RL001"], overlay=overlay)


def _overlay(rel, old, new, count=0):
    src = (ROOT / rel).read_text()
    assert old in src, f"expected {old!r} in {rel} — did the site move?"
    return {rel: src.replace(old, new) if not count
            else src.replace(old, new, count)}


# ===================== serving tree clean at HEAD ======================

def test_serving_dispatch_sites_clean_at_head():
    """RL001 over the whole serving package: every dispatch of a
    mutated-in-place buffer ships a private copy today."""
    report = _rl001("src/repro/serving")
    assert report.ok, report.render_human()


# ============ deleting a .copy() fails with the PR 5 story =============

def test_deleting_feed_pos_copy_at_the_paged_feed_fires():
    """PagedMode's chunked-prefill span feed mutates feed_pos right
    after dispatch WITHOUT a sync. Removing the .copy() must re-flag
    the exact PR 5 bug."""
    ov = _overlay(LOOP, "jnp.asarray(loop.feed_pos.copy())",
                  "jnp.asarray(loop.feed_pos)")
    report = _rl001(LOOP, overlay=ov)
    hits = report.by_rule("RL001")
    assert hits, "RL001 must fire when the feed_pos copy is deleted"
    assert all(f.path == LOOP for f in hits)
    assert any("feed_pos" in f.message and "PR 5" in f.message
               for f in hits), [f.message for f in hits]


def test_deleting_cur_tok_copy_at_the_dense_decode_fires():
    """DenseMode.step mutates cur_tok after the resolve; the dispatch
    must keep its private copy."""
    ov = _overlay(LOOP, "jnp.asarray(self.cur_tok.copy())",
                  "jnp.asarray(self.cur_tok)")
    report = _rl001(LOOP, overlay=ov)
    assert any("cur_tok" in f.message for f in report.by_rule("RL001")), \
        report.render_human()


def test_deleting_spec_feed_pos_copy_fires():
    """SpecMode's span feed has the same prefill-drain hazard."""
    ov = _overlay(LOOP, "jnp.asarray(feed_pos.copy())",
                  "jnp.asarray(feed_pos)")
    report = _rl001(LOOP, overlay=ov)
    assert any("feed_pos" in f.message
               for f in report.by_rule("RL001")), report.render_human()


# ====== admit()-mutated decode configs: the mutated-inflight wall ======

def test_deleting_a_config_copy_in_the_fused_dispatch_fires():
    """The fused mask+sample dispatch passes NUMPY arrays into jitted
    calls directly (the jnp.asarray round-trip costs ~25x the dispatch
    on CPU), which widens the aliasing hazard: jit may zero-copy alias
    the host buffer too. The long-lived decode-config arrays
    (greedy/temp/top_k/top_p) are mutated in place by admit() while the
    dispatch is in flight — `# reprolint: mutated-inflight=` declares
    that, so every un-copied dispatch of them is a finding."""
    ov = _overlay(ENGINE,
                  "need_mask, greedy.copy(), temp.copy(),\n"
                  "                        top_k.copy(), top_p.copy(), noise)",
                  "need_mask, greedy, temp.copy(),\n"
                  "                        top_k.copy(), top_p.copy(), noise)")
    report = _rl001(ENGINE, overlay=ov)
    hits = report.by_rule("RL001")
    assert any("greedy" in f.message and "mutated-inflight" in f.message
               for f in hits), report.render_human()


def test_deleting_a_config_copy_in_the_spec_span_dispatch_fires():
    ov = _overlay(LOOP, "loop.greedy.copy(), loop.temp.copy()",
                  "loop.greedy, loop.temp.copy()")
    report = _rl001(LOOP, overlay=ov)
    assert any("loop.greedy" in f.message
               for f in report.by_rule("RL001")), report.render_human()


def test_fused_dispatch_safe_under_config_mutation():
    """Semantic form of the guard above: dispatch the fused sampled
    path with numpy configs, clobber every config array in place
    immediately (before any sync — what admit() does on the overlap
    path), and require the resolved ids to match an isolated re-run."""
    import jax.numpy as jnp

    from repro.kernels.fused_select.ops import fused_mask_select
    from repro.kernels.fused_select.ref import gumbel_noise
    rng = np.random.default_rng(0)
    B, V, R = 4, 512, 32
    store = rng.integers(0, 2 ** 32, (R, V // 32), dtype=np.uint32)
    rows = rng.integers(-1, R, (B, 8)).astype(np.int32)
    logits = rng.normal(size=(B, V)).astype(np.float32)
    cd = np.zeros((B, V // 32), np.uint32)
    eos = np.ones(B, bool)
    cons = np.ones(B, bool)
    keys = rng.integers(0, 2 ** 32, (B, 2), dtype=np.uint32)
    noise = gumbel_noise(jnp.asarray(keys), V)
    greedy = np.zeros(B, bool)
    temp = np.full(B, 0.8, np.float32)
    top_k = np.full(B, 8, np.int32)
    top_p = np.full(B, 0.9, np.float32)
    ids, _ = fused_mask_select(jnp.asarray(logits), jnp.asarray(store),
                               rows, cd, eos, cons, greedy.copy(),
                               temp.copy(), top_k.copy(), top_p.copy(),
                               noise=noise)
    # in-place mutation right after dispatch, as admit() would do
    greedy[:] = True
    temp[:] = 99.0
    top_k[:] = 1
    top_p[:] = 0.01
    want, _ = fused_mask_select(jnp.asarray(logits), jnp.asarray(store),
                                rows, cd, eos, cons,
                                np.zeros(B, bool),
                                np.full(B, 0.8, np.float32),
                                np.full(B, 8, np.int32),
                                np.full(B, 0.9, np.float32), noise=noise)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


# ============== training: the fresh-batch contract =====================

def test_training_tree_clean_under_fresh_batch_contract():
    """RL001 over the training/launch/benchmark paths (the ROADMAP
    aliasing-audit sweep, mechanized): clean at HEAD."""
    for path in ("src/repro/training", "src/repro/launch", "benchmarks"):
        report = _rl001(path)
        assert report.ok, report.render_human()


def test_removing_the_fresh_batch_annotation_fires():
    """The training loop ships `next(data_iter)` batches with a bare
    jnp.asarray on the strength of the `# reprolint: fresh-batch`
    contract. Without the annotation the producer is opaque and RL001
    must demand a copy."""
    ov = _overlay(TRAIN, "# reprolint: fresh-batch", "# (contract gone)")
    report = _rl001(TRAIN, overlay=ov)
    hits = report.by_rule("RL001")
    assert any("opaque producer" in f.message for f in hits), \
        report.render_human()


def test_grammar_pipeline_batches_are_fresh(grammar_bundle, tokenizer):
    """Successive GrammarDataPipeline batches must not share memory:
    the training loop ships them with a bare jnp.asarray. This is the
    runtime half of the fresh-batch contract the annotation names."""
    from repro.training.data import GrammarDataPipeline
    g, _, _, _ = grammar_bundle("calc")
    pipe = GrammarDataPipeline(g, tokenizer, seq_len=16, batch_size=2,
                               seed=0)
    b1 = next(pipe)
    snap = {k: v.copy() for k, v in b1.items()}
    b2 = next(pipe)
    for k in b1:
        assert not np.shares_memory(b1[k], b2[k]), k
        # producing the next batch must not have mutated the previous one
        np.testing.assert_array_equal(b1[k], snap[k])


def test_random_pipeline_batches_are_fresh():
    from repro.configs import get_config
    from repro.training.data import RandomTokenPipeline
    pipe = RandomTokenPipeline(get_config("syncode-demo"), seq_len=8,
                               batch_size=2, seed=0)
    b1, b2 = next(pipe), next(pipe)
    for k in b1:
        assert not np.shares_memory(b1[k], b2[k]), k
