"""Zero-copy aliasing guards.

On CPU, `jnp.asarray` may zero-copy alias host numpy memory. A host
buffer that is mutated in place after being shipped to an ASYNC device
computation is then mutated under the computation's feet — root-caused
in PR 5 from a 5.47-magnitude logits drift in chunked-prefill runs.
Two guards hold the line:

 1. the serving step-loop dispatch sites must keep shipping PRIVATE
    copies of the long-lived, mutated-in-place cursor arrays
    (cur_tok / feed_pos) — asserted against the source so a cleanup
    that "removes the redundant .copy()" fails loudly with the story;
 2. the training pipelines must return freshly allocated batches (the
    training loop ships them with a bare jnp.asarray on the strength
    of that contract — see training/data.py).
"""
import re

import numpy as np


def _loop_source():
    import inspect

    import repro.serving.loop as loop
    return inspect.getsource(loop)


def _engine_source():
    import inspect

    import repro.serving.engine as engine
    return inspect.getsource(engine)


def test_step_loop_ships_copies_of_mutated_cursors():
    """Every decode/feed dispatch that passes a long-lived, in-place
    mutated cursor array through jnp.asarray must pass a .copy().

    DenseMode.step mutates cur_tok and feed_pos right after the resolve
    sync; PagedMode/SpecMode mutate feed_pos during prefill-drain steps
    that never sync. If any of these sites loses its .copy(), the async
    computation can read the NEXT step's cursors."""
    src = _loop_source()
    # dense decode: both cursors copied
    assert re.search(r"jnp\.asarray\(self\.cur_tok\.copy\(\)\)", src), \
        "DenseMode dispatch must ship cur_tok.copy()"
    # feed_pos copies: dense decode + paged span feed + spec span feed
    n_feed = len(re.findall(r"jnp\.asarray\((?:loop\.)?feed_pos\.copy\(\)\)",
                            src))
    assert n_feed >= 3, (
        f"expected >= 3 feed_pos.copy() dispatch sites in serving/loop.py "
        f"(dense, paged, spec), found {n_feed} — see the aliasing note at "
        f"the paged span feed")
    # the explanatory comment must survive too (it carries the root cause)
    assert "zero-copy alias" in src


def test_fused_dispatch_ships_copies_of_decode_configs():
    """The fused mask+select dispatch passes NUMPY arrays into jitted
    calls directly (the jnp.asarray round-trip costs ~25x the dispatch
    on CPU), which widens the aliasing hazard: jit may zero-copy alias
    the host buffer too. Per-step arrays (rows, cd, eos, need_mask,
    keys, noise) are freshly allocated each step and safe; the
    long-lived decode-config arrays (greedy/temp/top_k/top_p) are
    mutated in place by admit() and MUST ship private copies — in the
    engine's sampled dispatch and in SpecMode's span dispatch."""
    esrc = _engine_source()
    for arr in ("greedy", "temp", "top_k", "top_p"):
        assert re.search(rf"\b{arr}\.copy\(\)", esrc), (
            f"engine _select_dispatch must ship {arr}.copy() — admit() "
            f"mutates it in place while the device call is in flight")
    lsrc = _loop_source()
    for arr in ("greedy", "temp", "top_k", "top_p"):
        assert re.search(rf"loop\.{arr}\.copy\(\)", lsrc), (
            f"SpecMode span dispatch must ship loop.{arr}.copy()")


def test_fused_dispatch_safe_under_config_mutation():
    """Semantic form of the guard above: dispatch the fused sampled
    path with numpy configs, clobber every config array in place
    immediately (before any sync — what admit() does on the overlap
    path), and require the resolved ids to match an isolated re-run."""
    import jax.numpy as jnp

    from repro.kernels.fused_select.ops import fused_mask_select
    from repro.kernels.fused_select.ref import gumbel_noise
    rng = np.random.default_rng(0)
    B, V, R = 4, 512, 32
    store = rng.integers(0, 2 ** 32, (R, V // 32), dtype=np.uint32)
    rows = rng.integers(-1, R, (B, 8)).astype(np.int32)
    logits = rng.normal(size=(B, V)).astype(np.float32)
    cd = np.zeros((B, V // 32), np.uint32)
    eos = np.ones(B, bool)
    cons = np.ones(B, bool)
    keys = rng.integers(0, 2 ** 32, (B, 2), dtype=np.uint32)
    noise = gumbel_noise(jnp.asarray(keys), V)
    greedy = np.zeros(B, bool)
    temp = np.full(B, 0.8, np.float32)
    top_k = np.full(B, 8, np.int32)
    top_p = np.full(B, 0.9, np.float32)
    ids, _ = fused_mask_select(jnp.asarray(logits), jnp.asarray(store),
                               rows, cd, eos, cons, greedy.copy(),
                               temp.copy(), top_k.copy(), top_p.copy(),
                               noise=noise)
    # in-place mutation right after dispatch, as admit() would do
    greedy[:] = True
    temp[:] = 99.0
    top_k[:] = 1
    top_p[:] = 0.01
    want, _ = fused_mask_select(jnp.asarray(logits), jnp.asarray(store),
                                rows, cd, eos, cons,
                                np.zeros(B, bool),
                                np.full(B, 0.8, np.float32),
                                np.full(B, 8, np.int32),
                                np.full(B, 0.9, np.float32), noise=noise)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(want))


def test_grammar_pipeline_batches_are_fresh(grammar_bundle, tokenizer):
    """Successive GrammarDataPipeline batches must not share memory:
    the training loop ships them with a bare jnp.asarray."""
    from repro.training.data import GrammarDataPipeline
    g, _, _, _ = grammar_bundle("calc")
    pipe = GrammarDataPipeline(g, tokenizer, seq_len=16, batch_size=2,
                               seed=0)
    b1 = next(pipe)
    snap = {k: v.copy() for k, v in b1.items()}
    b2 = next(pipe)
    for k in b1:
        assert not np.shares_memory(b1[k], b2[k]), k
        # producing the next batch must not have mutated the previous one
        np.testing.assert_array_equal(b1[k], snap[k])


def test_random_pipeline_batches_are_fresh():
    from repro.configs import get_config
    from repro.training.data import RandomTokenPipeline
    pipe = RandomTokenPipeline(get_config("syncode-demo"), seq_len=8,
                               batch_size=2, seed=0)
    b1, b2 = next(pipe), next(pipe)
    for k in b1:
        assert not np.shares_memory(b1[k], b2[k]), k
