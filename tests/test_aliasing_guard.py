"""Zero-copy aliasing guards.

On CPU, `jnp.asarray` may zero-copy alias host numpy memory. A host
buffer that is mutated in place after being shipped to an ASYNC device
computation is then mutated under the computation's feet — root-caused
in PR 5 from a 5.47-magnitude logits drift in chunked-prefill runs.
Two guards hold the line:

 1. the serving step-loop dispatch sites must keep shipping PRIVATE
    copies of the long-lived, mutated-in-place cursor arrays
    (cur_tok / feed_pos) — asserted against the source so a cleanup
    that "removes the redundant .copy()" fails loudly with the story;
 2. the training pipelines must return freshly allocated batches (the
    training loop ships them with a bare jnp.asarray on the strength
    of that contract — see training/data.py).
"""
import re

import numpy as np


def _loop_source():
    import inspect

    import repro.serving.loop as loop
    return inspect.getsource(loop)


def test_step_loop_ships_copies_of_mutated_cursors():
    """Every decode/feed dispatch that passes a long-lived, in-place
    mutated cursor array through jnp.asarray must pass a .copy().

    DenseMode.step mutates cur_tok and feed_pos right after the resolve
    sync; PagedMode/SpecMode mutate feed_pos during prefill-drain steps
    that never sync. If any of these sites loses its .copy(), the async
    computation can read the NEXT step's cursors."""
    src = _loop_source()
    # dense decode: both cursors copied
    assert re.search(r"jnp\.asarray\(self\.cur_tok\.copy\(\)\)", src), \
        "DenseMode dispatch must ship cur_tok.copy()"
    # feed_pos copies: dense decode + paged span feed + spec span feed
    n_feed = len(re.findall(r"jnp\.asarray\((?:loop\.)?feed_pos\.copy\(\)\)",
                            src))
    assert n_feed >= 3, (
        f"expected >= 3 feed_pos.copy() dispatch sites in serving/loop.py "
        f"(dense, paged, spec), found {n_feed} — see the aliasing note at "
        f"the paged span feed")
    # the explanatory comment must survive too (it carries the root cause)
    assert "zero-copy alias" in src


def test_grammar_pipeline_batches_are_fresh(grammar_bundle, tokenizer):
    """Successive GrammarDataPipeline batches must not share memory:
    the training loop ships them with a bare jnp.asarray."""
    from repro.training.data import GrammarDataPipeline
    g, _, _, _ = grammar_bundle("calc")
    pipe = GrammarDataPipeline(g, tokenizer, seq_len=16, batch_size=2,
                               seed=0)
    b1 = next(pipe)
    snap = {k: v.copy() for k, v in b1.items()}
    b2 = next(pipe)
    for k in b1:
        assert not np.shares_memory(b1[k], b2[k]), k
        # producing the next batch must not have mutated the previous one
        np.testing.assert_array_equal(b1[k], snap[k])


def test_random_pipeline_batches_are_fresh():
    from repro.configs import get_config
    from repro.training.data import RandomTokenPipeline
    pipe = RandomTokenPipeline(get_config("syncode-demo"), seq_len=8,
                               batch_size=2, seed=0)
    b1, b2 = next(pipe), next(pipe)
    for k in b1:
        assert not np.shares_memory(b1[k], b2[k]), k
