"""DFA mask store: vectorized construction vs a direct pure-Python dmatch
oracle (paper Def. 10), plus the soundness property (paper Thm. 1) on
grammar-sampled valid strings."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.grammars import BUILTIN, load_grammar
from repro.core.sampling import GrammarSampler
from repro.core.tokenizer import EOS_ID


# ---------------- direct dmatch oracle (slow, obviously-correct) --------

def dmatch_oracle(grammar, terminal, q, token: bytes, next_terminal=None):
    """Def. 10 with Λ = () or (τ',), written naively."""
    dfa = grammar.terminals[terminal].dfa
    # cond 1: walk ends live
    st = q
    states = [st]
    for b in token:
        st = int(dfa.trans[st, b])
        states.append(st)
    if dfa.live[st]:
        return True
    for i in range(len(token) + 1):
        if not dfa.finals[states[i]]:
            continue
        rest = token[i:]
        if next_terminal is None:
            # cond 2: needs nonempty rest
            if len(rest) > 0:
                return True
        else:
            # cond 3: dmatch(rest, q0', ()) — cond1 or cond2 recursively
            d2 = grammar.terminals[next_terminal].dfa
            st2 = d2.start
            states2 = [st2]
            for b in rest:
                st2 = int(d2.trans[st2, b])
                states2.append(st2)
            if d2.live[st2]:
                return True
            if any(d2.finals[states2[j]] for j in range(len(rest))):
                return True
    return False


@pytest.mark.parametrize("name", ["calc", "json"])
def test_store_matches_dmatch_oracle(name, grammar_bundle, tokenizer):
    g, tab, store, gc = grammar_bundle(name)
    rng = np.random.default_rng(0)
    toks = tokenizer.token_bytes()
    token_ids = rng.choice(np.arange(3, tokenizer.vocab_size), size=60,
                           replace=False)
    stride = store.row_stride
    terms = g.terminal_names
    for t1 in terms:
        dfa = g.terminals[t1].dfa
        qs = [q for q in range(dfa.num_states) if dfa.live[q]]
        for q in qs[:6]:
            row0 = store.unpack(store.packed[store.row_m0(t1, q)])
            for tid in token_ids[:25]:
                want = dmatch_oracle(g, t1, q, toks[tid])
                assert bool(row0[tid]) == want, (t1, q, toks[tid], "M0")
            for t2 in (terms[0], terms[len(terms) // 2], terms[-1]):
                row1 = store.unpack(store.packed[store.row_m1(t1, q, t2)])
                for tid in token_ids[25:45]:
                    want = dmatch_oracle(g, t1, q, toks[tid], t2)
                    assert bool(row1[tid]) == want, (t1, q, toks[tid], t2)


# ---------------- Thm. 1 soundness on valid continuations ---------------

@pytest.mark.parametrize("name", BUILTIN)
def test_mask_soundness_on_valid_strings(name, grammar_bundle, tokenizer):
    g, tab, store, gc = grammar_bundle(name)
    gs = GrammarSampler(g, seed=11)
    checked = 0
    for _ in range(8):
        s = gs.sample(18, max_bytes=250)
        ids = tokenizer.encode(s)
        prefix = b""
        for tid in ids:
            mask = gc.token_mask(prefix)
            assert mask[tid], (
                f"sound mask must keep valid token: {prefix!r} + "
                f"{tokenizer.id_to_bytes[tid]!r}")
            prefix += tokenizer.id_to_bytes[tid]
            checked += 1
        assert gc.token_mask(s)[EOS_ID], f"EOS must be allowed after {s!r}"
    assert checked > 15


def test_specials_never_allowed(grammar_bundle):
    g, tab, store, gc = grammar_bundle("json")
    m = gc.token_mask(b"")
    assert not m[0] and not m[2]  # PAD, BOS
    assert not m[EOS_ID]          # empty string is not valid JSON


def test_store_rows_layout(grammar_bundle, tokenizer):
    g, tab, store, gc = grammar_bundle("calc")
    # two row families (grammar_mask, grammar_strict) over the same
    # state addressing; strict rows start at strict_offset
    R = g.total_dfa_states * (len(g.terminal_names) + 1)
    assert store.packed.shape[0] == 2 * R
    assert store.strict_offset == R
    assert store.row_m0("INT", 0, strict=True) == store.row_m0("INT", 0) + R
    assert store.packed.dtype == np.uint32
    assert store.packed.shape[1] * 32 >= tokenizer.vocab_size


def test_eos_only_when_complete(grammar_bundle):
    _, _, _, gc = grammar_bundle("calc")
    assert gc.step_rows(b"1+2").eos_allowed
    assert not gc.step_rows(b"1+").eos_allowed
    assert not gc.step_rows(b"math_sqrt(3").eos_allowed
    assert gc.step_rows(b"math_sqrt(3)").eos_allowed


# ------------------- cache fingerprint + atomic write -------------------

def test_fingerprint_covers_all_token_bytes(tokenizer):
    """Two vocabs sharing the first 64 tokens AND total byte length must
    not collide onto the same cached store (the old fingerprint hashed
    only id_to_bytes[:64] + the total length)."""
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import _fingerprint
    from repro.core.tokenizer import ByteTokenizer
    g, _ = load_grammar("calc")
    a = ByteTokenizer(512)
    b = ByteTokenizer(512)
    # swap two late tokens' bytes: same prefix, same total length
    i, j = 400, 401
    assert a.id_to_bytes[i] != a.id_to_bytes[j]
    b.id_to_bytes[i], b.id_to_bytes[j] = b.id_to_bytes[j], b.id_to_bytes[i]
    assert _fingerprint(g, a) != _fingerprint(g, b)
    assert _fingerprint(g, a) == _fingerprint(g, ByteTokenizer(512))


def test_cache_roundtrip_atomic(tmp_path, tokenizer):
    """The .npz cache is written via temp-file + os.replace: the final
    path appears complete, no temp litter stays behind, and a reload hits
    the cache with identical packed rows."""
    import os
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import build_mask_store
    g, _ = load_grammar("calc")
    store = build_mask_store(g, tokenizer, cache_dir=str(tmp_path))
    assert not store.meta["cached"]
    files = os.listdir(tmp_path)
    assert len(files) == 1 and files[0].endswith(".npz")
    assert not any(".tmp" in f for f in files)
    store2 = build_mask_store(g, tokenizer, cache_dir=str(tmp_path))
    assert store2.meta["cached"]
    np.testing.assert_array_equal(store.packed, store2.packed)


def test_fingerprint_includes_layout_version(tokenizer, monkeypatch):
    """A cache written under an older packed-word layout must MISS (the
    fingerprint embeds STORE_LAYOUT_VERSION + word geometry), never load
    as wrong masks."""
    from repro.core import mask_store as ms
    from repro.core.grammars import load_grammar
    g, _ = load_grammar("calc")
    fp_now = ms._fingerprint(g, tokenizer)
    monkeypatch.setattr(ms, "STORE_LAYOUT_VERSION",
                        ms.STORE_LAYOUT_VERSION + 1)
    assert ms._fingerprint(g, tokenizer) != fp_now


def test_stale_layout_cache_misses_on_disk(tmp_path, tokenizer, monkeypatch):
    """End-to-end: a store cached under layout N is ignored (rebuilt,
    fresh file) after the layout version bumps."""
    import os
    from repro.core import mask_store as ms
    from repro.core.grammars import load_grammar
    g, _ = load_grammar("calc")
    s1 = ms.build_mask_store(g, tokenizer, cache_dir=str(tmp_path))
    assert len(os.listdir(tmp_path)) == 1
    monkeypatch.setattr(ms, "STORE_LAYOUT_VERSION",
                        ms.STORE_LAYOUT_VERSION + 1)
    s2 = ms.build_mask_store(g, tokenizer, cache_dir=str(tmp_path))
    assert not s2.meta["cached"]                 # stale cache missed
    assert len(os.listdir(tmp_path)) == 2        # republished under new fp
    np.testing.assert_array_equal(s1.packed, s2.packed)


def test_concurrent_multiprocess_cache_publish(tmp_path):
    """Two processes racing to build + publish the same store must both
    succeed, leave exactly one readable .npz and no temp litter — the
    per-process mkstemp + os.replace protocol."""
    import os
    import subprocess
    import sys
    code = (
        "import sys; sys.path.insert(0, {src!r})\n"
        "from repro.core.grammars import load_grammar\n"
        "from repro.core.mask_store import build_mask_store\n"
        "from repro.core.tokenizer import ByteTokenizer\n"
        "g, _ = load_grammar('calc')\n"
        "s = build_mask_store(g, ByteTokenizer(512), cache_dir={cd!r})\n"
        "print(s.packed.sum())\n"
    ).format(src=os.path.join(os.path.dirname(__file__), "..", "src"),
             cd=str(tmp_path))
    procs = [subprocess.Popen([sys.executable, "-c", code],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE)
             for _ in range(3)]
    outs = [p.communicate(timeout=300) for p in procs]
    assert all(p.returncode == 0 for p in procs), outs
    sums = {o[0].strip() for o in outs}
    assert len(sums) == 1                        # identical stores
    files = os.listdir(tmp_path)
    assert len(files) == 1 and files[0].endswith(".npz"), files
    # the published file is a complete, loadable npz
    np.load(os.path.join(tmp_path, files[0]))["packed"]
