"""Sharding rules, HLO cost analyzer, and a real (small-mesh) dry-run in
a subprocess with forced host devices."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P


def _fake_mesh():
    # single-device "mesh" with the production axis names for rule tests
    return jax.make_mesh((1, 1), ("data", "model"))


class _MeshShape:
    """Duck-typed mesh exposing .shape and .axis_names for rule tests."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_param_spec_rules():
    from repro.distributed.sharding import param_spec
    mesh = _MeshShape({"data": 16, "model": 16})
    assert param_spec("['embed_block']['embed']", (163840, 7168), mesh) \
        == P("model", None)
    assert param_spec("['groups'][0][0]['attn']['wq']", (61, 7168, 8192),
                      mesh) == P(None, None, "model")
    assert param_spec("['groups'][0][0]['attn']['wo']", (61, 8192, 7168),
                      mesh) == P(None, "model", None)
    assert param_spec("['groups'][0][0]['moe']['w_gate']",
                      (60, 384, 7168, 2048), mesh) == \
        P(None, "model", None, None)
    # non-divisible head dim -> replicated (smollm: 15 heads)
    assert param_spec("['groups'][0][0]['attn']['wq']", (32, 960, 960),
                      mesh) == P(None, None, "model")
    assert param_spec("['groups'][0][0]['attn']['wq']", (32, 960, 900),
                      mesh) == P(None, None, None)
    # fsdp adds a data axis on the largest free divisible dim
    assert param_spec("['groups'][0][0]['attn']['wq']", (61, 7168, 8192),
                      mesh, fsdp=True) == P(None, "data", "model")


def test_hlo_cost_scan_trip_scaling():
    from repro.distributed.hlo_cost import roofline_counts

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jnp.ones((256, 256))
    comp = jax.jit(f).lower(x, x).compile()
    rc = roofline_counts(comp.as_text())
    expect = 7 * 2 * 256 ** 3
    assert abs(rc["flops"] - expect) / expect < 0.05, rc["flops"]


def test_collective_accounting_ring_factors():
    from repro.distributed.hlo_stats import collective_stats
    fake = """
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag = f32[4096]{0} all-gather(%y), replica_groups={{0,1,2,3}}
"""
    st = collective_stats(fake)
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["wire_bytes"] == int(2 * 3 / 4 * 4096)
    assert st["all-gather"]["wire_bytes"] == int(3 / 4 * 16384)


DRYRUN_SNIPPET = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, __SRC__)
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.model import build_model
from repro.distributed.api import use_sharding
from repro.distributed.sharding import (activation_rules, batch_shardings,
                                        cache_shardings, params_shardings)
from repro.launch.shapes import batch_specs

cfg = get_config(__ARCH__).reduced(vocab_size=512, d_model=256)
model = build_model(cfg)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
p_sh = params_shardings(params, mesh)
rules = activation_rules(mesh, cfg, 4)
bspec = batch_specs(cfg, 64, 4, with_labels=True)
b_sh = batch_shardings(bspec, mesh)

def loss(p, b):
    return model.loss(p, b)[0]

with use_sharding(mesh, rules):
    lowered = jax.jit(loss, in_shardings=(p_sh, b_sh)).lower(params, bspec)
compiled = lowered.compile()
assert compiled.memory_analysis() is not None
caches = jax.eval_shape(lambda: model.init_decode_caches(4, 64))
c_sh = cache_shardings(caches, mesh, cfg)
tok = jax.ShapeDtypeStruct((4,), jax.numpy.int32)
t_sh = batch_shardings(dict(t=tok), mesh)["t"]
with use_sharding(mesh, rules):
    dec = jax.jit(model.decode_step,
                  in_shardings=(p_sh, c_sh, t_sh, t_sh)
                  ).lower(params, caches, tok, tok)
dec.compile()
print("MESH_DRYRUN_OK")
"""


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "qwen3-moe-30b-a3b",
                                  "mamba2-370m", "recurrentgemma-9b"])
def test_sharded_lower_compile_8dev(arch):
    """Reduced configs must lower+compile train loss AND decode on a real
    (8 placeholder device) mesh — the mini version of the production
    dry-run, runnable inside the test suite."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    code = DRYRUN_SNIPPET.replace("__SRC__", repr(os.path.abspath(src))) \
        .replace("__ARCH__", repr(arch))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH_DRYRUN_OK" in r.stdout
