"""Per-kernel validation: pallas_call (interpret=True on CPU) vs pure-jnp
ref.py oracles, swept over shapes/dtypes + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.masked_logits.kernel import masked_logits
from repro.kernels.masked_logits.ref import masked_logits_ref


# ------------------------------ masked_logits ------------------------------

@pytest.mark.parametrize("B,V,R,A,block_v", [
    (1, 512, 32, 4, 512),
    (4, 2048, 300, 12, 512),
    (3, 1024, 64, 48, 1024),
    (2, 4096, 128, 8, 2048),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_logits_matches_ref(B, V, R, A, block_v, dtype):
    rng = np.random.default_rng(B * V + A)
    store = rng.integers(0, 2 ** 32, size=(R, V // 32), dtype=np.uint32)
    rows = rng.integers(-1, R, size=(B, A)).astype(np.int32)
    logits = rng.normal(size=(B, V)).astype(np.float32)
    eos = rng.integers(0, 2, size=(B,)).astype(bool)
    cd = rng.integers(0, 2 ** 32, size=(B, V // 32), dtype=np.uint32)
    args = (jnp.asarray(logits, dtype), jnp.asarray(store),
            jnp.asarray(rows), jnp.asarray(eos))
    ref = masked_logits_ref(*args, cd=jnp.asarray(cd))
    out = masked_logits(*args, jnp.asarray(cd), block_v=block_v,
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(ref, np.float32),
                                  np.asarray(out, np.float32))


@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 4),
    A=st.integers(1, 16),
    seed=st.integers(0, 2 ** 16),
)
def test_masked_logits_property(B, A, seed):
    V, R = 512, 40
    rng = np.random.default_rng(seed)
    store = rng.integers(0, 2 ** 32, size=(R, V // 32), dtype=np.uint32)
    rows = rng.integers(-1, R, size=(B, A)).astype(np.int32)
    logits = rng.normal(size=(B, V)).astype(np.float32)
    eos = rng.integers(0, 2, size=(B,)).astype(bool)
    cd = rng.integers(0, 2 ** 32, size=(B, V // 32), dtype=np.uint32)
    args = (jnp.asarray(logits), jnp.asarray(store), jnp.asarray(rows),
            jnp.asarray(eos))
    out = np.asarray(masked_logits(*args, jnp.asarray(cd), block_v=256,
                                   interpret=True))
    ref = np.asarray(masked_logits_ref(*args, cd=jnp.asarray(cd)))
    np.testing.assert_array_equal(out, ref)
    # property: every unmasked position was allowed by some row, the
    # context-dependent residue overlay, or EOS
    keep = out > -1e29
    union = np.zeros(V, dtype=bool)
    for b in range(B):
        union[:] = np.unpackbits(cd[b].view(np.uint8),
                                 bitorder="little")[:V].astype(bool)
        for r in rows[b]:
            if r >= 0:
                bits = np.unpackbits(store[r].view(np.uint8),
                                     bitorder="little")[:V].astype(bool)
                union |= bits
        if eos[b]:
            union[1] = True
        assert np.array_equal(keep[b], union)


# ------------------------------ flash_attention ----------------------------

@pytest.mark.parametrize("B,Sq,Sk,H,K,Dh,bq,bk", [
    (1, 128, 128, 4, 4, 64, 64, 64),       # MHA square
    (2, 128, 128, 8, 2, 64, 32, 64),       # GQA
    (1, 64, 256, 4, 1, 32, 64, 64),        # MQA, Sk > Sq (decode-ish)
    (2, 256, 256, 6, 3, 128, 128, 128),    # MXU-aligned tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, Sq, Sk, H, K, Dh, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(Sq + Sk), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype=dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, Dh), dtype=dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, Dh), dtype=dtype)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                          interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, K, Dh = 2, 128, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, Dh), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, Dh), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, Dh), dtype=jnp.float32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, Sq, Sk, H, K, Dh = 1, 64, 128, 2, 2, 64
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (B, Sk, K, Dh), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (B, Sk, K, Dh), dtype=jnp.float32)
    ref = attention_ref(q, k, v, causal=False)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=64,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    Sq=st.sampled_from([32, 64, 96]),
    H=st.sampled_from([2, 4]),
    K=st.sampled_from([1, 2]),
    seed=st.integers(0, 1000),
)
def test_flash_attention_property(Sq, H, K, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, Dh = 1, 32
    q = jax.random.normal(ks[0], (B, Sq, H, Dh), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (B, Sq, K, Dh), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (B, Sq, K, Dh), dtype=jnp.float32)
    ref = attention_ref(q, k, v, causal=True)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
