"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each assigned family (2 layers, d_model<=512, <=4 experts)
runs one forward/train step and one prefill+decode step on CPU, asserting
output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model

B, S = 2, 32


def _batch(cfg, rng):
    ks = jax.random.split(rng, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.arch_type == "vlm":
        batch["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.num_image_tokens, cfg.d_model),
            dtype=jnp.bfloat16)
    if cfg.arch_type == "audio":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.audio_frames, cfg.d_model), dtype=jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    # at least one grad must be nonzero and all finite
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l, dtype=np.float32)))
               for l in leaves), arch
    assert any(float(jnp.abs(l.astype(jnp.float32)).max()) > 0
               for l in leaves), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, caches = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, dtype=np.float32)))
    tok = jnp.argmax(logits[:, -1], axis=-1)
    step = jax.jit(model.decode_step)
    lg, caches = step(params, caches, tok, jnp.full((B,), S, jnp.int32))
    assert lg.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg, dtype=np.float32)))
    lg2, caches = step(params, caches,
                       jnp.argmax(lg, -1), jnp.full((B,), S + 1, jnp.int32))
    assert np.all(np.isfinite(np.asarray(lg2, dtype=np.float32)))


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-370m",
                                  "recurrentgemma-9b", "whisper-base",
                                  "qwen3-moe-30b-a3b",
                                  "llama-3.2-vision-90b"])
def test_decode_matches_train_forward(arch):
    """Prefill+decode of token t must equal the train forward's logits at
    the same position (cache correctness). SSM recurrences accumulate
    bf16 rounding differently step-by-step vs chunked, hence the wider
    tolerance there (exactness in f32 is covered by test_ssm_numerics)."""
    tol = 0.5 if arch in ("mamba2-370m", "recurrentgemma-9b") else 0.08
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    full_logits, _ = jax.jit(model.train_logits)(params, batch)

    toks = batch["tokens"]
    pre_batch = dict(batch, tokens=toks[:, : S - 4])
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len=S))(params, pre_batch)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32),
        np.asarray(full_logits[:, S - 5], np.float32),
        rtol=tol, atol=tol)
    step = jax.jit(model.decode_step)
    for i in range(S - 4, S):
        lg, caches = step(params, caches, toks[:, i],
                          jnp.full((B,), i, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(full_logits[:, i], np.float32),
            rtol=tol, atol=tol, err_msg=f"{arch} step {i}")


def test_sliding_window_decode_ring_buffer():
    cfg = get_config("internlm2-1.8b").reduced(sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, caches = jax.jit(model.prefill)(params, batch)
    # cache length must be the window, not S
    k = caches[0][0]["k"]
    assert k.shape[2] == 8  # [count, B, L=window, K, Dh]
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], -1)
    for i in range(S, S + 12):
        lg, caches = step(params, caches, tok, jnp.full((B,), i, jnp.int32))
        tok = jnp.argmax(lg, -1)
        assert np.all(np.isfinite(np.asarray(lg, np.float32)))


def test_ssm_numerics_f32_exact():
    """Chunked SSD == naive sequential recurrence in f32 (oracle check)."""
    from repro.models.ssm import (init_ssm, init_ssm_cache, ssm_decode,
                                  ssm_prefill, ssm_train)
    cfg = get_config("mamba2-370m").reduced(dtype="float32")
    p = init_ssm(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          dtype=jnp.float32) * 0.5
    y_train = ssm_train(p, x, cfg)
    cache = init_ssm_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(64):
        y, cache = ssm_decode(p, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    y_pre, c2 = ssm_prefill(p, x[:, :32], cfg)
    y_d, _ = ssm_decode(p, x[:, 32:33], c2, cfg)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_train[:, 32:33]),
                               rtol=1e-4, atol=1e-4)


def test_rglru_numerics_f32_exact():
    from repro.models.rglru import (init_rglru, init_rglru_cache,
                                    rglru_decode, rglru_prefill, rglru_train)
    cfg = get_config("recurrentgemma-9b").reduced(dtype="float32")
    p = init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, cfg.d_model),
                          dtype=jnp.float32) * 0.5
    y_train = rglru_train(p, x, cfg)
    cache = init_rglru_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(48):
        y, cache = rglru_decode(p, x[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    y_pre, c2 = rglru_prefill(p, x[:, :20], cfg)
    y_d, _ = rglru_decode(p, x[:, 20:21], c2, cfg)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_train[:, 20:21]),
                               rtol=1e-4, atol=1e-4)


def test_chunked_attention_matches_direct():
    from repro.models.common import chunked_attention
    rng = jax.random.PRNGKey(0)
    B, Sq, Sk, H, K, Dh = 2, 37, 37, 6, 3, 16
    q = jax.random.normal(rng, (B, Sq, H, Dh), dtype=jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sk, K, Dh),
                          dtype=jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sk, K, Dh),
                          dtype=jnp.float32)
    direct = chunked_attention(q, k, v, causal=True, chunk=4096)
    chunked = chunked_attention(q, k, v, causal=True, chunk=8)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked),
                               rtol=2e-5, atol=2e-5)
    # sliding window agreement
    d2 = chunked_attention(q, k, v, causal=True, window=9, chunk=4096)
    c2 = chunked_attention(q, k, v, causal=True, window=9, chunk=8)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(c2),
                               rtol=2e-5, atol=2e-5)
