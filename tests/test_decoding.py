"""Decoding algorithms + composability with masks (paper's generality
claim: greedy/sampling/beam all operate on V_k)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.decoding import (DecodeConfig, NEG_INF, apply_bool_mask,
                                 beam_search, greedy, sample, select_batch,
                                 union_packed_rows, unpack_mask_words)


def test_greedy_respects_mask():
    logits = jnp.asarray([[5.0, 1.0, 3.0]])
    mask = jnp.asarray([[False, True, True]])
    assert int(greedy(apply_bool_mask(logits, mask))[0]) == 2


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       temp=st.floats(0.2, 2.0),
       k=st.integers(1, 8))
def test_sampling_never_picks_masked(seed, temp, k):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32))
    mask = jnp.asarray(rng.integers(0, 2, size=(2, 32)).astype(bool))
    mask = mask.at[:, 0].set(True)  # at least one allowed
    masked = apply_bool_mask(logits, mask)
    t = sample(masked, jax.random.PRNGKey(seed), temperature=temp, top_k=k)
    for b in range(2):
        assert bool(mask[b, int(t[b])])


def test_top_p_limits_support():
    logits = jnp.asarray([[10.0, 1.0, 0.5, 0.1]])
    picks = set()
    for s in range(50):
        t = sample(logits, jax.random.PRNGKey(s), top_p=0.5)
        picks.add(int(t[0]))
    assert picks == {0}


def test_unpack_roundtrip():
    rng = np.random.default_rng(0)
    words = jnp.asarray(rng.integers(0, 2 ** 32, (3, 4), dtype=np.uint32))
    bits = unpack_mask_words(words, 128)
    ref = np.unpackbits(np.asarray(words).view(np.uint8),
                        bitorder="little").reshape(3, 128)
    np.testing.assert_array_equal(np.asarray(bits), ref.astype(bool))


def test_union_packed_rows_matches_numpy():
    rng = np.random.default_rng(1)
    store = rng.integers(0, 2 ** 32, (20, 4), dtype=np.uint32)
    rows = rng.integers(-1, 20, (5, 6)).astype(np.int32)
    out = np.asarray(union_packed_rows(jnp.asarray(store),
                                       jnp.asarray(rows)))
    for b in range(5):
        want = np.zeros(4, np.uint32)
        for r in rows[b]:
            if r >= 0:
                want |= store[r]
        np.testing.assert_array_equal(out[b], want)


def test_beam_search_with_mask():
    """Toy LM over 4 tokens; beam must find the highest-scoring sequence
    among mask-allowed ones and stop at EOS (id 1)."""
    table = {
        (): np.asarray([0.1, 0.0, 2.0, 1.9]),
        (2,): np.asarray([0.0, 3.0, 0.1, 0.2]),
        (3,): np.asarray([0.0, 5.0, 0.1, 0.2]),
    }

    def step(state, toks):
        logp = table.get(tuple(toks), np.asarray([0.0, 4.0, 0.0, 0.0]))
        lp = logp - np.log(np.exp(logp).sum())
        lp[0] = -1e30  # mask token 0 (grammar mask composes here)
        return lp, state

    beams = beam_search(step, None, beam_width=2, max_steps=4, eos_id=1)
    best = beams[0][0]
    assert best[-1] == 1 and 0 not in best
    assert best[0] == 3  # (3,)->EOS scores higher than (2,)->EOS


# ----------------------- batched per-row selector --------------------------

def _batch_params(configs):
    g, t, k, p = DecodeConfig.batch_arrays(configs)
    return (jnp.asarray(g), jnp.asarray(t), jnp.asarray(k), jnp.asarray(p))


def _keys(n, seed=0):
    return jnp.asarray(
        np.stack([np.full(n, seed, np.uint32),
                  np.arange(n, dtype=np.uint32)], axis=1))


def test_select_batch_never_picks_masked():
    rng = np.random.default_rng(0)
    B, V = 6, 64
    logits = jnp.asarray(rng.normal(size=(B, V)).astype(np.float32))
    mask = rng.integers(0, 2, size=(B, V)).astype(bool)
    mask[:, 0] = True
    masked = apply_bool_mask(logits, jnp.asarray(mask))
    cfgs = [DecodeConfig(method="sample", temperature=0.5 + 0.2 * b)
            for b in range(B)]
    for s in range(8):
        ids = np.asarray(select_batch(masked, _keys(B, s),
                                      *_batch_params(cfgs)))
        for b in range(B):
            assert mask[b, ids[b]], (b, ids[b])


def test_select_batch_greedy_rows_match_argmax():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    cfgs = [DecodeConfig(method="greedy"),
            DecodeConfig(method="sample", temperature=2.0),
            DecodeConfig(method="greedy"),
            DecodeConfig(method="sample", top_k=3)]
    ids = np.asarray(select_batch(logits, _keys(4), *_batch_params(cfgs)))
    want = np.asarray(jnp.argmax(logits, axis=-1))
    assert ids[0] == want[0] and ids[2] == want[2]


def test_select_batch_per_row_top_k():
    """Row 0 has top_k=1 (must take the max); row 1 unrestricted."""
    logits = jnp.asarray([[0.0, 5.0, 4.9, 4.8],
                          [0.0, 5.0, 4.9, 4.8]])
    cfgs = [DecodeConfig(method="sample", temperature=1.0, top_k=1),
            DecodeConfig(method="sample", temperature=1.0)]
    picks0 = set()
    for s in range(30):
        ids = np.asarray(select_batch(logits, _keys(2, s),
                                      *_batch_params(cfgs)))
        picks0.add(int(ids[0]))
    assert picks0 == {1}


def test_select_batch_per_row_top_p():
    """A dominant token with top_p=0.5 is the only possible pick."""
    logits = jnp.asarray([[10.0, 1.0, 0.5, 0.1]])
    cfgs = [DecodeConfig(method="sample", top_p=0.5)]
    picks = set()
    for s in range(30):
        ids = np.asarray(select_batch(logits, _keys(1, s),
                                      *_batch_params(cfgs)))
        picks.add(int(ids[0]))
    assert picks == {0}


def test_batch_arrays_roundtrip():
    g, t, k, p = DecodeConfig.batch_arrays(
        [DecodeConfig(method="greedy"),
         DecodeConfig(method="sample", temperature=0.7, top_k=5, top_p=0.9)])
    np.testing.assert_array_equal(g, [True, False])
    np.testing.assert_allclose(t, [1.0, 0.7])
    np.testing.assert_array_equal(k, [0, 5])
    np.testing.assert_allclose(p, [1.0, 0.9])
    with pytest.raises(ValueError):
        DecodeConfig.batch_arrays([DecodeConfig(method="beam")])


def test_decode_config_dispatch():
    logits = jnp.asarray([[1.0, 9.0, 2.0]])
    assert int(DecodeConfig(method="greedy").select(logits)[0]) == 1
    t = DecodeConfig(method="sample", temperature=0.01).select(
        logits, jax.random.PRNGKey(0))
    assert int(t[0]) == 1
